"""Kernel microbenchmarks + the engine's own roofline model.

Bulk bitwise ops have arithmetic intensity ~#ops / 12 bytes, so on the
TPU target they are HBM-bound: ideal time = bytes / 819 GB/s. We report
measured CPU wall time (interpret mode - correctness signal only) AND the
modeled TPU roofline time per call, plus the fusion win: a fused
expression of k ops touches (k_inputs+1) buffers instead of 3 per op
(the AAP-chain/RowClone copy-avoidance analogue, Section 3.1.4).

Also measures the ambit_sim device model's batched execution path against
the legacy per-row loop (kern_ambit_batched_6op): the before/after speedup
of the (n_rows, words) vectorization + compiled-program cache."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]

HBM_BW = 819e9


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def ambit_batched_speedup(n_rows: int = 1024, n_bits: int = 2048) -> List[Row]:
    """Batched ambit_sim execution vs the legacy per-row loop (the seed
    behavior, kept as batch_rows=False): one 6-op expression evaluated over
    ``n_rows`` subarray rows. Records the before/after speedup the batched
    simulator + compile cache deliver - the acceptance bar is >= 20x."""
    from repro.core import BitVector, BulkBitwiseEngine, Expr

    x, y, z = Expr.var("x"), Expr.var("y"), Expr.var("z")
    expr = ((x & y) | ~z) ^ ((x | y) & z)  # and,or,not,or,and,xor = 6 ops
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (3, n_rows, n_bits)).astype(bool)
    env = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xyz")}

    batched = BulkBitwiseEngine("ambit_sim")
    per_row = BulkBitwiseEngine("ambit_sim", batch_rows=False)
    us_b = _time(lambda: batched.eval(expr, env))
    us_p = _time(lambda: per_row.eval(expr, env), reps=1)
    st = batched.last_stats
    assert np.array_equal(np.asarray(batched.eval(expr, env).bits()),
                          np.asarray(per_row.eval(expr, env).bits()))
    return [("kern_ambit_batched_6op", us_b,
             f"rows={n_rows} per_row={us_p:.0f}us "
             f"speedup={us_p / us_b:.1f}x aap={st.aap_count} "
             f"dram_model_ns={st.ns:.0f}")]


CHANNEL_BW = 34e9  # 2-channel DDR3 model (Section 7) for host round-trips


def pim_resident_chain(n_ops: int = 6, rows: int = 128) -> List[Row]:
    """Resident vs non-resident execution of a query_and_all-style chain
    (Section 8.1 shape): ``n_ops`` dependent ANDs over a batch of ``rows``
    row-sized (65,536-bit) bitvectors at real 8 KB geometry. The
    non-resident baseline pays a host write of every operand and a host
    read of every intermediate per op, and executes ops serially; the
    resident path uploads once, chains in-DRAM through the placement-aware
    planner (row groups across banks in parallel), and reads back only the
    final result. The headline is the DRAM cost model: op time + channel
    time for the host traffic each path actually generates."""
    from repro.core import BitVector, BulkBitwiseEngine
    from repro.pim import AmbitRuntime

    rng = np.random.default_rng(0)
    n_bits = 65536  # one full DRAM row per batch row
    bits = rng.integers(0, 2, (n_ops + 1, rows, n_bits)).astype(bool)
    vecs = [BitVector.from_bits(b) for b in bits]

    eng = BulkBitwiseEngine("ambit_sim")

    def host_chain():
        acc, nbytes, ns = vecs[0], 0, 0.0
        for bv in vecs[1:]:
            acc = eng.and_(acc, bv)
            nbytes += eng.last_stats.bytes_touched
            ns += eng.last_stats.ns
        return nbytes, ns

    def resident_chain():
        rt = AmbitRuntime(banks=8, subarrays=4, seed=1)
        rs = []
        for bv in vecs:
            rs.append(rt.put(bv, near=rs[0].slots if rs else None))
        acc = rs[0]
        for r in rs[1:]:
            prev = acc
            acc = rt.and_(acc, r)
            if prev is not rs[0]:
                rt.free(prev)        # intermediates die in-DRAM
        rt.get(acc)
        return rt

    us_host = _time(host_chain, reps=2)
    us_res = _time(resident_chain, reps=2)
    (host_bytes, host_ns), rt = host_chain(), resident_chain()
    assert rt.host_reads == 1        # zero intermediate read-backs
    res_bytes = rt.session_stats.bytes_touched
    host_model = host_ns + host_bytes / CHANNEL_BW * 1e9
    res_model = rt.session_stats.ns + res_bytes / CHANNEL_BW * 1e9
    return [("kern_pim_resident_chain", us_res,
             f"ops={n_ops} rows={rows} model_speedup="
             f"{host_model / res_model:.1f}x "
             f"(dram {host_ns / rt.session_stats.ns:.1f}x, traffic "
             f"{host_bytes / res_bytes:.1f}x: {res_bytes} vs {host_bytes} B) "
             f"host_wall={us_host:.0f}us")]


def pim_sharded_scan(n_ops: int = 6, rows: int = 64,
                     devices: int = 4) -> List[Row]:
    """Sharded multi-device scaling: the same ``n_ops``-AND resident chain
    over a batch of ``rows`` row-sized (65,536-bit) bitvectors, on one
    device vs a ``devices``-device PimCluster with round-robin chunk
    placement. Chunks stripe across devices, so each device executes
    1/devices of every op and the cluster planner reports
    max-over-devices time - near-linear scaling as long as operands stay
    chunk-aligned (the ``near=`` chain guarantees that, so the chain pays
    ZERO inter-device transfers). The kernel then ANDs in one
    deliberately mis-placed operand (packed onto device 0): the cluster's
    cross-device colocation moves its chunks, and the ledger records the
    **measured** inter-device rows/bytes plus the channel ns the move
    re-introduced - the traffic the paper's single-chip story never
    sees."""
    from repro.core import BitVector
    from repro.pim import AmbitRuntime, PACKED

    rng = np.random.default_rng(0)
    n_bits = 65536  # one full 8 KB DRAM row per logical row
    bits = rng.integers(0, 2, (n_ops + 1, rows, n_bits)).astype(bool)
    vecs = [BitVector.from_bits(b) for b in bits]

    def chain(n_devices):
        rt = AmbitRuntime(banks=4, subarrays=2, devices=n_devices, seed=1)
        rs = []
        for bv in vecs:
            rs.append(rt.put(bv, near=rs[0].slots if rs else None))
        acc = rs[0]
        for r in rs[1:]:
            prev = acc
            acc = rt.and_(acc, r)
            if prev is not rs[0]:
                rt.free(prev)
        rt.get(acc)
        return rt, acc

    us_1 = _time(lambda: chain(1), reps=1)
    us_n = _time(lambda: chain(devices), reps=1)
    (rt1, _), (rtn, acc) = chain(1), chain(devices)
    ns_1, ns_n = rt1.session_stats.ns, rtn.session_stats.ns
    assert rtn.store.ledger.inter_device_bytes == 0  # aligned chain: free

    # Mis-placed operand: packed onto one device, colocated on first use.
    mask = rtn.store.put(BitVector.from_bits(bits[0]), placement=PACKED)
    rtn.and_(acc, mask)
    led = rtn.store.ledger
    return [("kern_pim_sharded_scan", us_n,
             f"devices={devices} ops={n_ops} rows={rows} "
             f"dram_speedup={ns_1 / ns_n:.1f}x "
             f"({ns_1:.0f} vs {ns_n:.0f} ns) "
             f"misplaced_op: inter_dev_rows={led.inter_device_rows} "
             f"bytes={led.inter_device_bytes} (measured) "
             f"channel_ns={led.inter_device_ns:.0f} "
             f"single_dev_wall={us_1:.0f}us")]


def pim_async_multiquery(n_queries: int = 4, n_ops: int = 3,
                         rows: int = 8) -> List[Row]:
    """Async multi-query scheduler: ``n_queries`` independent sessions,
    each an ``n_ops``-AND expression over its own operands, placed so the
    queries occupy disjoint banks (single device) or disjoint devices
    (4-device cluster). Serial ``eval`` pays sum-over-queries DRAM time;
    ``submit``+``drain`` packs the bank/device-disjoint queries into ONE
    epoch, so drain time is the max over resources - the paper's
    bank-level parallelism lifted from row groups of one query to whole
    concurrent sessions. The acceptance bar is >= 3x DRAM-op time at 4
    disjoint queries with bit-identical results and identical summed
    energy/AAPs, on both configs."""
    import itertools

    from repro.core import BitVector, Expr
    from repro.pim import AmbitRuntime

    n_bits = 65536          # one full 8 KB DRAM row per logical row
    banks, subarrays = n_queries, 2
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (n_queries, n_ops + 1, rows, n_bits)
                        ).astype(bool)
    expr = Expr.var("v0")
    for k in range(1, n_ops + 1):
        expr = expr & Expr.var(f"v{k}")
    want = [np.bitwise_and.reduce(bits[q]) for q in range(n_queries)]

    def load(rt, devices):
        """Query q's operands confined to bank q (1 device) or device q
        (cluster), chunk-aligned so no staging/transfers are needed."""
        envs = []
        for q in range(n_queries):
            vecs = []
            for k in range(n_ops + 1):
                bv = BitVector.from_bits(bits[q, k])
                if vecs:
                    near = vecs[0].slots
                elif devices == 1:
                    near = [(q, s, 0) for s in range(subarrays)]
                else:
                    near = [(q, (i % banks, (i // banks) % subarrays, 0))
                            for i in range(rows)]
                vecs.append(rt.put(bv, near=near))
            envs.append({f"v{k}": v for k, v in enumerate(vecs)})
        return envs

    out: List[Row] = []
    for devices in (1, 4):
        dev_kw = dict(banks=banks, subarrays=subarrays, seed=1)
        rt_s = AmbitRuntime(devices=1 if devices == 1 else devices, **dev_kw)
        envs_s = load(rt_s, devices)
        serial_res, serial_ns, serial_e, serial_aap = [], 0.0, 0.0, 0
        t0 = time.perf_counter()
        for env in envs_s:
            r = rt_s.eval(expr, env)
            serial_ns += rt_s.last_stats.ns
            serial_e += rt_s.last_stats.energy_nj
            serial_aap += rt_s.last_stats.aap_count
            serial_res.append(np.asarray(rt_s.get(r).bits()))
        us_serial = (time.perf_counter() - t0) * 1e6

        rt_a = AmbitRuntime(devices=1 if devices == 1 else devices, **dev_kw)
        envs_a = load(rt_a, devices)
        t0 = time.perf_counter()
        tickets = [rt_a.submit(expr, env) for env in envs_a]
        rt_a.drain()
        us_async = (time.perf_counter() - t0) * 1e6
        drain = rt_a.last_drain
        async_res = [np.asarray(rt_a.get(t.result).bits()) for t in tickets]

        for w, s, a in zip(want, serial_res, async_res):
            assert np.array_equal(s, w) and np.array_equal(a, w)
        assert drain.stats.energy_nj == serial_e      # conservation-exact
        assert drain.stats.aap_count == serial_aap
        speedup = serial_ns / drain.stats.ns
        assert speedup >= 3.0, f"epoch overlap only {speedup:.2f}x"
        epochs = len(drain.epochs)
        n_res = len(set(itertools.chain.from_iterable(
            e.resources for e in drain.epochs)))
        out.append((f"kern_pim_async_multiquery_d{devices}", us_async,
                    f"queries={n_queries} ops={n_ops} rows={rows} "
                    f"dram_speedup={speedup:.1f}x "
                    f"({serial_ns:.0f} vs {drain.stats.ns:.0f} ns) "
                    f"epochs={epochs} resources={n_res} "
                    f"serial_wall={us_serial:.0f}us"))
    return out


def pim_optimizer(n_tenants: int = 6, n_queries: int = 24) -> List[Row]:
    """Cost-based multi-query optimizer on the TPC-H-flavoured suite:
    ``n_queries`` multi-predicate scans from a Zipfian tenant mix over
    shared-prefix range pools (apps.bitweaving_db). Unoptimized drain
    executes every submitted comparator tree; ``drain(optimize=True)``
    CSE-shares the pooled comparator subtrees across tickets (one
    materialization, DAG references downstream). The acceptance bar is
    >= 1.5x DRAM-op time reduction with bit-exact results vs the numpy
    oracle and ``opt_*`` counters reconciled against the drain ledger.
    A second optimized round resubmits the same mix: every query must
    be served from the result cache with ZERO device ops."""
    from repro.apps.bitweaving_db import (TpchTable, predicate_plan,
                                          zipf_tenant_queries)
    from repro.core import DRAMGeometry
    from repro.pim import AmbitRuntime

    geom = DRAMGeometry(rows_per_subarray=64)

    def build():
        rt = AmbitRuntime(geom, banks=4, devices=1, subarrays=4,
                          words=4, seed=1)
        table = TpchTable.synthesize(n_rows=rt.store.device.words * 64,
                                     seed=2)
        queries = zipf_tenant_queries(table, n_tenants=n_tenants,
                                      n_queries=n_queries, seed=3)
        return rt, table, queries

    def submit_all(rt, table, queries):
        return [rt.submit(*predicate_plan(table, specs, rt))
                for _, specs in queries]

    def check(rt, table, queries, tickets):
        for (_, specs), t in zip(queries, tickets):
            got = np.asarray(rt.get(t.result).bits()).ravel()
            got = got[:table.n_rows].astype(bool)
            assert np.array_equal(got, table.oracle(specs)), specs

    rt_u, table_u, queries = build()
    t0 = time.perf_counter()
    tu = submit_all(rt_u, table_u, queries)
    rt_u.drain()
    us_unopt = (time.perf_counter() - t0) * 1e6
    check(rt_u, table_u, queries, tu)
    su = rt_u.last_drain.stats

    rt_o, table_o, _ = build()
    t0 = time.perf_counter()
    to = submit_all(rt_o, table_o, queries)
    rt_o.drain(optimize=True)
    us_opt = (time.perf_counter() - t0) * 1e6
    check(rt_o, table_o, queries, to)
    so = rt_o.last_drain.stats
    rep = rt_o.last_drain.opt

    # opt_* counters reconcile bit-exactly with the drain's OptReport
    m = rt_o.store.metrics
    assert m.counter("opt_cse_hits").total() == rep.cse_hits
    assert m.counter("opt_cache_misses").total() == rep.cache_misses
    assert rep.cse_hits > 0 and so.aap_count < su.aap_count
    speedup = su.ns / so.ns
    aap_red = su.aap_count / so.aap_count
    assert speedup >= 1.5, f"optimizer saved only {speedup:.2f}x"

    # round 2: the same mix again - served entirely from the result cache
    t2 = submit_all(rt_o, table_o, queries)
    rt_o.drain(optimize=True)
    check(rt_o, table_o, queries, t2)
    rep2 = rt_o.last_drain.opt
    assert rep2.cache_hits == n_queries
    assert rt_o.last_drain.stats.aap_count == 0
    assert m.counter("opt_cache_hits").total() == rep2.cache_hits

    return [("kern_pim_optimizer", us_opt,
             f"queries={n_queries} tenants={n_tenants} "
             f"dram_speedup={speedup:.1f}x "
             f"({su.ns:.0f} vs {so.ns:.0f} ns) aap_reduction="
             f"{aap_red:.1f}x ({su.aap_count} vs {so.aap_count}) "
             f"cse_hits={rep.cse_hits} cse_mat={rep.cse_materialized} "
             f"cache_hits={rep2.cache_hits} "
             f"unopt_wall={us_unopt:.0f}us")]


def pallas_resident_chain(n_ops: int = 6, rows: int = 64,
                          n_queries: int = 4) -> List[Row]:
    """Accelerator-resident DeviceStore vs the non-resident jnp path: a
    ``n_ops``-AND dependent chain over ``rows`` x 8192-bit operands. The
    non-resident engine ships every operand host->device and the result
    back on EVERY op; the resident path uploads each operand once,
    chains on-device through ``out=`` rebinds (donated buffers - no
    allocation churn), and reads back only the final result - measured
    ``bytes_touched`` must drop >= 2x. Then ``n_queries`` same-shape
    queries submit+drain on the pallas backend: the epoch dispatches as
    ONE stacked fused kernel (call-count probe), bit-identical to serial
    eval."""
    from repro.core import BitVector, BulkBitwiseEngine, Expr
    from repro.kernels import ops as kops
    from repro.pim import AmbitRuntime

    rng = np.random.default_rng(0)
    n_bits = 8192
    bits = rng.integers(0, 2, (n_ops + 1, rows, n_bits)).astype(bool)
    vecs = [BitVector.from_bits(b) for b in bits]

    eng = BulkBitwiseEngine("jnp")

    def host_chain():
        acc, nbytes = vecs[0], 0
        for bv in vecs[1:]:
            acc = eng.and_(acc, bv)
            nbytes += eng.last_stats.bytes_touched
        return acc, nbytes

    x, y = Expr.var("x"), Expr.var("y")

    def resident_chain():
        rt = AmbitRuntime(backend="pallas")
        hs = [rt.put(bv) for bv in vecs]
        acc = rt.and_(hs[0], hs[1])
        for h in hs[2:]:                 # donated in-place rebinds
            rt.eval(x & y, {"x": acc, "y": h}, out=acc)
        rt.get(acc)
        return rt, acc

    us_host = _time(lambda: host_chain(), reps=2)
    us_res = _time(lambda: resident_chain(), reps=2)
    (host_acc, host_bytes), (rt, acc) = host_chain(), resident_chain()
    res_bytes = rt.session_stats.bytes_touched
    assert np.array_equal(np.asarray(rt.get(acc).bits()),
                          np.asarray(host_acc.bits()))
    assert host_bytes >= 2 * res_bytes, (host_bytes, res_bytes)

    # multi-query drain: one fused stacked kernel per epoch
    rt2 = AmbitRuntime(backend="pallas")
    qbits = rng.integers(0, 2, (n_queries, 2, rows, n_bits)).astype(bool)
    envs = [{"x": rt2.put(BitVector.from_bits(qb[0])),
             "y": rt2.put(BitVector.from_bits(qb[1]))} for qb in qbits]
    kops.fused_dispatch_reset()
    tickets = [rt2.submit(x & y, env) for env in envs]
    rt2.drain()
    epochs = len(rt2.last_drain.epochs)
    dispatches = kops.fused_dispatch_count()
    assert epochs == 1 and dispatches == 1, (epochs, dispatches)
    for t, qb in zip(tickets, qbits):
        assert np.array_equal(np.asarray(rt2.get(t.result).bits()),
                              qb[0] & qb[1])
    return [("kern_pallas_resident_chain", us_res,
             f"ops={n_ops} rows={rows} "
             f"traffic={host_bytes / res_bytes:.1f}x "
             f"res_bytes={res_bytes} host_bytes={host_bytes} "
             f"queries={n_queries} epochs={epochs} "
             f"fused_dispatches={dispatches} host_wall={us_host:.0f}us")]


def kernels_micro() -> List[Row]:
    from repro.core import expr as E
    from repro.kernels import ops, ref

    rows: List[Row] = []
    rows.extend(ambit_batched_speedup())
    rows.extend(pim_resident_chain())
    rows.extend(pallas_resident_chain())
    rows.extend(pim_sharded_scan())
    rows.extend(pim_async_multiquery())
    rows.extend(pim_optimizer())
    rng = np.random.default_rng(0)
    shape = (256, 4096)  # 4 MB packed = 128 Mbit operands
    nbytes = int(np.prod(shape)) * 4
    arrs = {k: jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))
            for k in "abc"}

    x, y, z = E.Expr.var("a"), E.Expr.var("b"), E.Expr.var("c")
    single = x & y
    fused = ((x & y) | ~z) ^ (x | z)

    us1 = _time(lambda: ops.bitwise_eval(single, arrs))
    usf = _time(lambda: ops.bitwise_eval(fused, arrs))
    ideal1 = 3 * nbytes / HBM_BW * 1e6
    # fused: 3 inputs + 1 output vs 4 ops x 3 buffers unfused
    ideal_f = 4 * nbytes / HBM_BW * 1e6
    ideal_unfused = 4 * 3 * nbytes / HBM_BW * 1e6
    rows.append(("kern_bitwise_and", us1,
                 f"tpu_roofline={ideal1:.1f}us bytes={3*nbytes}"))
    rows.append(("kern_bitwise_fused4", usf,
                 f"tpu_roofline={ideal_f:.1f}us vs_unfused="
                 f"{ideal_unfused:.1f}us fusion_win="
                 f"{ideal_unfused/ideal_f:.1f}x"))

    us = _time(lambda: ops.popcount(arrs["a"]))
    rows.append(("kern_popcount", us,
                 f"tpu_roofline={nbytes/HBM_BW*1e6:.1f}us"))

    vals = rng.integers(0, 2**12, 2**20).astype(np.uint32)
    planes = ref.bitslice(jnp.asarray(vals), 12)
    us = _time(lambda: ops.bitweaving_scan(planes, 100, 3000))
    pb = int(planes.size) * 4
    rows.append(("kern_bitweaving_b12", us,
                 f"tpu_roofline={pb/HBM_BW*1e6:.2f}us "
                 f"vs_int32_scan={4*2**20/HBM_BW*1e6:.2f}us "
                 f"traffic_saving={4*2**20/pb:.1f}x"))

    m = n = 256
    k = 4096
    from repro.core.bitvector import pack_bits
    a = pack_bits(jnp.asarray(rng.integers(0, 2, (m, k)), jnp.uint32))
    b = pack_bits(jnp.asarray(rng.integers(0, 2, (n, k)), jnp.uint32))
    us_vpu = _time(lambda: ops.binary_matmul(a, b, k))
    us_mxu = _time(lambda: ops.binary_matmul_mxu(a, b, k))
    xnor_ops = m * n * (k // 32) * 3  # xor+popcount+add per word
    mxu_flops = 2 * m * n * k
    rows.append(("kern_binary_matmul_vpu", us_vpu,
                 f"word_ops={xnor_ops:.3g} packed_bytes={(m+n)*k//8}"))
    rows.append(("kern_binary_matmul_mxu", us_mxu,
                 f"mxu_flops={mxu_flops:.3g} "
                 f"tpu_mxu_time={mxu_flops/197e12*1e6:.2f}us"))
    return rows
