"""Structural benchmark diff: compare two ``run.py --json`` outputs.

Wall-clock numbers vary across machines; the *structure* of a benchmark
run must not. Two runs are compared on:

  * the set of row names (a renamed/dropped benchmark is a regression
    signal in itself), and
  * every ``key=value`` token in the derived metadata whose value is a
    pure integer - op counts, ledger bytes, DRAM-model ns, epoch and
    resource counts, measured row transfers. These are deterministic
    model outputs; anything wall-clock-derived is formatted as a float /
    ``...us`` / ``...x`` token and is deliberately ignored.

Usage: ``python -m benchmarks.compare current.json baseline.json``
``--only PREFIX`` restricts both runs to row names starting with PREFIX
(so a partial run - e.g. ``--sections refresh`` - can be diffed against
the full committed baseline without missing-row noise).
Exit status 1 with a readable diff when the structures diverge.
"""

from __future__ import annotations

import argparse
import json
import re
from typing import Dict, Optional

_INT = re.compile(r"^-?\d+$")
_TOKEN = re.compile(r"([A-Za-z_][\w.]*)=(\S+)")

# Observability tokens every row of a family MUST carry (name-prefix ->
# required integer tokens). A serving row that silently stops reporting
# packing efficiency or bank utilization is a regression even if the
# baseline predates the token, so presence is checked on the *current*
# run, not just diffed.
_REQUIRED_TOKENS = {
    "serve_": ("pack_eff_pct", "bank_busy_pct"),
    # reliability rows must keep reporting the recovery ledger - a
    # fault run with no retries/quarantines recorded means the
    # injection path silently stopped firing
    "faults_serve_": ("faults", "retries", "quarantined", "mismatches"),
    # optimizer rows must keep reporting CSE/cache reconciliation -
    # losing a counter silently would blind the opt-determinism job
    "kern_pim_optimizer": ("cse_hits", "cse_mat", "cache_hits"),
}


def structural(doc: dict) -> Dict[str, Dict[str, int]]:
    """name -> {derived integer tokens} for one run.py --json document."""
    out: Dict[str, Dict[str, int]] = {}
    for row in doc["rows"]:
        toks = {}
        for key, val in _TOKEN.findall(row.get("derived", "")):
            if _INT.match(val):
                toks[key] = int(val)
        out[row["name"]] = toks
    return out


def diff(current: dict, baseline: dict,
         only: Optional[str] = None) -> list:
    cur, base = structural(current), structural(baseline)
    if only is not None:
        cur = {n: t for n, t in cur.items() if n.startswith(only)}
        base = {n: t for n, t in base.items() if n.startswith(only)}
    problems = []
    for name in sorted(cur):
        for prefix, required in _REQUIRED_TOKENS.items():
            if not name.startswith(prefix):
                continue
            for key in required:
                if key not in cur[name]:
                    problems.append(
                        f"{name}: required token {key}= missing from "
                        f"current run")
    for name in sorted(set(base) - set(cur)):
        problems.append(f"missing benchmark row: {name}")
    for name in sorted(set(cur) - set(base)):
        problems.append(f"new benchmark row not in baseline: {name} "
                        f"(re-generate the baseline)")
    for name in sorted(set(cur) & set(base)):
        ct, bt = cur[name], base[name]
        for key in sorted(set(ct) | set(bt)):
            if ct.get(key) != bt.get(key):
                problems.append(
                    f"{name}: {key}={ct.get(key)} vs baseline "
                    f"{key}={bt.get(key)}")
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="structural benchmark diff (see module docstring)")
    ap.add_argument("current", help="run.py --json output to check")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--only", metavar="PREFIX", default=None,
                    help="compare only row names starting with PREFIX")
    args = ap.parse_args(argv)
    with open(args.current) as fh:
        current = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    problems = diff(current, baseline, only=args.only)
    if problems:
        for p in problems:
            print(p)
        raise SystemExit(f"{len(problems)} structural difference(s)")
    n = len(structural(current))
    scope = f" (prefix {args.only!r})" if args.only else ""
    print(f"OK: benchmark rows structurally identical to baseline{scope} "
          f"({n} rows in current run)")


if __name__ == "__main__":
    main()
