"""Application benchmarks: Fig. 22 bitmap index, Fig. 23 BitWeaving,
Fig. 24 set operations. Each compares the Ambit DRAM-model execution time
(through the bit-accurate simulator / AAP cost model) against the
channel-bound CPU baseline model, plus measured wall time on the jnp
engine for the same computation (correctness + host-side throughput)."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

AAP_NS = 49.0
ROW_BITS = 65536
BANKS = 8       # Ambit bank-level parallelism (Fig. 21 config)
CPU_BW = 34e9   # 2-channel DDR3-2133 model (Section 7)
CACHE_BW = 200e9
CACHE_BYTES = 2 * 1024 * 1024  # L2 (Table 5)


def _cpu_bw(working_set: float) -> float:
    """Two-tier bandwidth: the paper's Fig. 23 jumps happen where the
    working set stops fitting in the on-chip cache."""
    return CACHE_BW if working_set <= CACHE_BYTES else CPU_BW


def _cpu_ns(n_bits: int, n_ops: int, srcs: int = 2) -> float:
    ws = (srcs + 1) * n_bits / 8
    return (ws * n_ops) / _cpu_bw(ws) * 1e9


def _ambit_ns(n_bits: int, n_ops: int, aaps: int = 4) -> float:
    rows = max(1, (n_bits + ROW_BITS - 1) // ROW_BITS)
    rows_per_bank = max(1, (rows + BANKS - 1) // BANKS)
    return n_ops * rows_per_bank * aaps * AAP_NS


def fig22_bitmap() -> List[Row]:
    from repro.apps.bitmap_index import BitmapIndex
    from repro.core import BulkBitwiseEngine

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for n_users, weeks in ((2**20, 4), (2**22, 8)):
        eng = BulkBitwiseEngine("jnp")
        idx = BitmapIndex(n_users, eng)
        wk_names = [f"week{i}" for i in range(weeks)]
        for w in wk_names:
            idx.add(w, rng.choice(n_users, n_users // 4, replace=False))
        idx.add("male", rng.choice(n_users, n_users // 2, replace=False))
        t0 = time.perf_counter()
        uniq, per_week, _ = idx.weekly_active_query(wk_names, "male")
        wall_us = (time.perf_counter() - t0) * 1e6
        # paper-units: 2w bulk ops (w-1 ANDs + w ANDs) + popcounts
        n_ops = 2 * weeks - 1
        amb = _ambit_ns(n_users, n_ops)
        cpu = _cpu_ns(n_users, n_ops)
        rows.append((f"fig22_u{n_users//2**20}M_w{weeks}", wall_us,
                     f"uniq={uniq} ambit={amb/1e3:.1f}us cpu={cpu/1e3:.1f}us "
                     f"speedup={cpu/amb:.1f}x paper~6x(end-to-end)"))
    return rows


def fig23_bitweaving() -> List[Row]:
    """Fig. 23: 'select count(*) where c1<=v<=c2' speedup vs a SIMD CPU.

    Model (paper-consistent): the scan is (6b+1) bulk ops over r-bit
    planes on both systems; the final bitcount runs on the CPU in both.
    Speedup grows with b (bitcount fraction shrinks) and jumps when the
    CPU working set (b*r/8 bytes) spills the 2 MB cache - the two effects
    the paper highlights. One correctness-verified scan (r=2^20) anchors
    the model; larger r are model-only."""
    from repro.apps.bitweaving_db import BitWeavingColumn

    rows: List[Row] = []
    rng = np.random.default_rng(1)
    eng_n = 2**20
    speedups = []
    for b in (4, 8, 12, 16):
        vals = rng.integers(0, 2**b, eng_n).astype(np.uint32)
        col = BitWeavingColumn.from_values(vals, b)
        c1, c2 = int(2**b * 0.25), int(2**b * 0.75)
        t0 = time.perf_counter()
        cnt = col.count_between(c1, c2, use_kernel=False)
        wall_us = (time.perf_counter() - t0) * 1e6
        assert cnt == col.oracle_count(vals, c1, c2)
        for r in (2**20, 2**26, 2**30):
            n_ops = 6 * b + 1
            ws = b * r / 8  # planes working set on the CPU
            cpu_scan = (3 * r / 8 * n_ops) / _cpu_bw(ws) * 1e9
            bitcount = 2 * (r / 8) / CPU_BW * 1e9  # result pass (both)
            amb_scan = _ambit_ns(r, n_ops)
            speed = (cpu_scan + bitcount) / (amb_scan + bitcount)
            speedups.append(speed)
            if r == 2**20:
                rows.append((f"fig23_b{b}_r1M", wall_us,
                             f"count={cnt} speedup={speed:.1f}x"))
            else:
                rows.append((f"fig23_b{b}_r{r//2**20}M", 0.0,
                             f"speedup={speed:.1f}x"))
    rows.append(("fig23_range", 0.0,
                 f"model {min(speedups):.1f}-{max(speedups):.1f}x "
                 f"mean {np.mean(speedups):.1f}x; "
                 f"paper 1.8-11.8x mean 7.0x"))
    return rows


def fig24_sets() -> List[Row]:
    from repro.apps.bitsets import BitSetOps, SortedSetOps
    from repro.core import BulkBitwiseEngine

    rows: List[Row] = []
    rng = np.random.default_rng(2)
    domain, m = 512 * 1024, 15
    eng = BulkBitwiseEngine("jnp")
    bs = BitSetOps(domain, eng)
    for e in (16, 64, 1024, 16384):
        arrs = [np.sort(rng.choice(domain, e, replace=False))
                for _ in range(m)]
        bsets = [bs.make(a) for a in arrs]
        for opname in ("union", "intersection"):
            t0 = time.perf_counter()
            got = getattr(bs, opname)(bsets)
            bits = np.nonzero(np.asarray(got.bits()))[0]
            bit_us = (time.perf_counter() - t0) * 1e6
            t0 = time.perf_counter()
            ref = getattr(SortedSetOps, opname)(arrs)
            ref_us = (time.perf_counter() - t0) * 1e6
            assert np.array_equal(bits, ref), (opname, e)
            amb_ns = _ambit_ns(domain, m - 1)
            rows.append((f"fig24_{opname}_e{e}", bit_us,
                         f"sorted_baseline={ref_us:.0f}us "
                         f"ambit_model={amb_ns/1e3:.1f}us "
                         f"paper~3x_vs_rbtree"))
    return rows
