"""Closed-loop serving benchmark: Zipfian multi-tenant query replay
through ``serve.QueryFrontend`` (admission quotas + continuous-batching
window) over ``AmbitRuntime.submit/drain``.

Thousands of simulated tenants each keep one query outstanding
(closed-loop: the next arrival fires at the previous completion's
simulated-clock instant), drawn Zipfian over a shared catalog - the
bitmap-index AND queries of Section 8.1 and the BitWeaving range scans
of Section 8.2. Every completion is checked bit-exact against a serial
numpy evaluation (the ``mismatches=0`` token is a structural assertion
CI diffs).

All serving metrics are **ledger-derived**, never wall clock: the
simulated clock advances by the drain timeline - measured DRAM-model ns
on ``ambit_sim``, the deterministic HBM-roofline epoch model on the
accelerator backends - so queries/sec and p50/p99 latency are
bit-reproducible across machines and live in the structural
(integer-token) part of each row. Wall time lives only in the ``us``
column.
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _make_tracer(trace_dir: Optional[str]):
    """A live Tracer when tracing was requested, else None (the runtime
    then defaults to the shared zero-overhead NULL_TRACER)."""
    if trace_dir is None:
        return None
    from repro.obs import Tracer
    return Tracer()


def _finish_trace(tracer, trace_dir: Optional[str], name: str) -> None:
    if tracer is None or trace_dir is None:
        return
    import os

    from repro.obs import write_chrome_trace
    os.makedirs(trace_dir, exist_ok=True)
    write_chrome_trace(tracer, os.path.join(trace_dir, f"{name}.json"))


def _obs_tokens(rt, rep, max_batch: int) -> str:
    """Structural observability tokens (integers, so compare.py diffs
    them): epoch-packing efficiency and mean per-bank busy%% over the
    serving span. Backends without per-bank accounting (the fused
    accelerator path) report bank_busy_pct=0 - deterministically."""
    pack = (100.0 * rep.completed / (rep.epochs * max_batch)
            if rep.epochs else 0.0)
    busy = rt.metrics.counter("bank_busy_ns")
    bank = (100.0 * busy.total() / (len(busy.series) * rep.span_ns)
            if busy.series and rep.span_ns > 0 else 0.0)
    return (f"pack_eff_pct={int(round(pack))} "
            f"bank_busy_pct={int(round(bank))}")


def _zipf_pairs(rng: np.ndarray, n_items: int, n_tenants: int,
                s: float = 1.1) -> List[Tuple[int, int]]:
    """Assign each tenant a (distinct) catalog pair, Zipfian over pairs:
    pair rank r gets weight 1/r^s, so a few hot pairs dominate - the
    skew that makes batching windows pack well."""
    pairs = [(i, j) for i in range(n_items) for j in range(i + 1, n_items)]
    w = 1.0 / np.arange(1, len(pairs) + 1, dtype=np.float64) ** s
    idx = rng.choice(len(pairs), size=n_tenants, p=w / w.sum())
    return [pairs[i] for i in idx]


def _serve_bitmaps(backend: str, n_tenants: int, n_queries: int,
                   n_users: int, n_items: int, max_batch: int,
                   window_ns: float, trace_dir: Optional[str] = None,
                   **rt_kwargs) -> Row:
    from repro.core import BitVector, Expr
    from repro.pim.runtime import AmbitRuntime
    from repro.serve import QueryFrontend, run_closed_loop

    rng = np.random.default_rng(0)
    tracer = _make_tracer(trace_dir)
    rt = AmbitRuntime(backend=backend, tracer=tracer, **rt_kwargs)
    raw = {f"m{i}": rng.integers(0, 2, n_users).astype(np.uint8)
           for i in range(n_items)}
    hs = {k: rt.put(BitVector.from_bits(v), name=k)
          for k, v in raw.items()}
    # one fixed expression shape: the DevicePlanner stacks same-shape
    # queries into ONE fused launch per epoch (and its jit cache is
    # keyed on the expression, so serving stays compile-light)
    expr = Expr.var("x") & Expr.var("y")
    tenants = [f"t{i}" for i in range(n_tenants)]
    pair_of = dict(zip(tenants, _zipf_pairs(rng, n_items, n_tenants)))
    expected = {}

    def next_query(tenant, k):
        i, j = pair_of[tenant]
        a, b = f"m{i}", f"m{j}"
        expected[tenant] = int((raw[a] & raw[b]).sum())
        return expr, {"x": hs[a], "y": hs[b]}

    mism = 0

    def check(q):
        nonlocal mism
        if rt.popcount(q.result) != expected[q.tenant]:
            mism += 1
        rt.free(q.result)

    fe = QueryFrontend(rt, window_ns=window_ns, max_batch=max_batch)
    t0 = time.perf_counter()
    done = run_closed_loop(fe, tenants, next_query, n_queries,
                           on_complete=check)
    wall_us = (time.perf_counter() - t0) * 1e6
    rep = fe.report()
    derived = (f"tenants={n_tenants} queries={done} drains={rep.drains} "
               f"fill={rep.fill_drains} deadline={rep.deadline_drains} "
               f"flush={rep.flush_drains} epochs={rep.epochs} "
               f"p50_ns={int(rep.p50_ns)} p99_ns={int(rep.p99_ns)} "
               f"qps={rep.qps:.1f} mismatches={mism} "
               + _obs_tokens(rt, rep, max_batch))
    _finish_trace(tracer, trace_dir, f"serve_bitmap_{backend}")
    return f"serve_bitmap_{backend}", wall_us, derived


def _serve_bitweaving(n_tenants: int, n_queries: int, n_rows: int,
                      bits: int, max_batch: int,
                      window_ns: float, trace_dir: Optional[str] = None,
                      **rt_kwargs) -> Row:
    from repro.apps.bitweaving_db import BitWeavingColumn, scan_plan
    from repro.pim.runtime import AmbitRuntime
    from repro.serve import QueryFrontend, run_closed_loop

    rng = np.random.default_rng(1)
    values = rng.integers(0, 2 ** bits, n_rows).astype(np.uint32)
    col = BitWeavingColumn.from_values(values, bits)
    tracer = _make_tracer(trace_dir)
    rt = AmbitRuntime(tracer=tracer, **rt_kwargs)
    tenants = [f"t{i}" for i in range(n_tenants)]
    # Zipfian over range predicates: rank-r predicate weight 1/r^1.1
    preds = [(c1, min(2 ** bits - 1, c1 + w))
             for w in (1, 2, 4) for c1 in range(0, 2 ** bits - 1, 2)]
    wts = 1.0 / np.arange(1, len(preds) + 1, dtype=np.float64) ** 1.1
    pred_of = dict(zip(tenants, (
        preds[i] for i in rng.choice(len(preds), size=n_tenants,
                                     p=wts / wts.sum()))))
    expected = {}

    def next_query(tenant, k):
        c1, c2 = pred_of[tenant]
        expected[tenant] = int(((values >= c1) & (values <= c2)).sum())
        return scan_plan(col, c1, c2, rt)

    mism = 0

    def check(q):
        nonlocal mism
        # selection bits beyond n_rows stay zero (plane tails are zero),
        # so the resident popcount is exact without a host read-back
        if rt.popcount(q.result) != expected[q.tenant]:
            mism += 1
        rt.free(q.result)

    fe = QueryFrontend(rt, window_ns=window_ns, max_batch=max_batch)
    t0 = time.perf_counter()
    done = run_closed_loop(fe, tenants, next_query, n_queries,
                           on_complete=check)
    wall_us = (time.perf_counter() - t0) * 1e6
    rep = fe.report()
    derived = (f"tenants={n_tenants} queries={done} drains={rep.drains} "
               f"fill={rep.fill_drains} deadline={rep.deadline_drains} "
               f"flush={rep.flush_drains} epochs={rep.epochs} "
               f"p50_ns={int(rep.p50_ns)} p99_ns={int(rep.p99_ns)} "
               f"qps={rep.qps:.1f} mismatches={mism} "
               + _obs_tokens(rt, rep, max_batch))
    _finish_trace(tracer, trace_dir, "serve_bitweaving_ambit_sim")
    return "serve_bitweaving_ambit_sim", wall_us, derived


def serve_closed_loop(trace_dir: Optional[str] = None) -> List[Row]:
    rows: List[Row] = []
    # DRAM model: measured per-epoch ns drive the clock
    rows.append(_serve_bitmaps(
        "ambit_sim", n_tenants=1024, n_queries=2048, n_users=256,
        n_items=12, max_batch=16, window_ns=5_000.0,
        banks=4, subarrays=2, words=2, trace_dir=trace_dir))
    # accelerator backend: deterministic HBM-roofline epoch cost model
    rows.append(_serve_bitmaps(
        "pallas", n_tenants=1024, n_queries=1100, n_users=4096,
        n_items=12, max_batch=16, window_ns=50_000.0,
        trace_dir=trace_dir))
    rows.append(_serve_bitweaving(
        n_tenants=1024, n_queries=1000, n_rows=192, bits=4,
        max_batch=16, window_ns=5_000.0,
        banks=4, subarrays=2, words=2, trace_dir=trace_dir))
    return rows


def main(argv=None) -> None:
    """Standalone entry point so CI can re-run JUST the serving section
    with tracing on (the trace-determinism job runs it twice and diffs
    the trace JSON byte-for-byte)."""
    import argparse

    ap = argparse.ArgumentParser(
        description="closed-loop serving benchmark")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="write Chrome/Perfetto trace JSON per row "
                         "into DIR")
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI trace-determinism job)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.quick:
        rows = [_serve_bitmaps(
            "ambit_sim", n_tenants=64, n_queries=192, n_users=256,
            n_items=8, max_batch=8, window_ns=5_000.0,
            banks=4, subarrays=2, words=2, trace_dir=args.trace)]
    else:
        rows = serve_closed_loop(trace_dir=args.trace)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
