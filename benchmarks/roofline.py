"""Aggregate dry-run artifacts into the roofline table (SSRoofline).

Reads artifacts/dryrun/*.json produced by repro.launch.dryrun and emits
a markdown table + CSV rows. Single-pod mesh only for the table (the
multi-pod pass proves the pod axis shards; both are summarized)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Tuple

Row = Tuple[str, float, str]

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")
OPT_DIR = os.environ.get("DRYRUN_OPT_DIR", "artifacts/dryrun_opt")


def load_cells(mesh: str = "single_pod_16x16",
               directory: str = ARTIFACT_DIR) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            cells.append(r)
    return cells


def bottleneck_note(cell: Dict) -> str:
    dom = cell["dominant"]
    if dom == "compute_s":
        return "raise MXU utilization (larger per-chip matmuls/microbatch)"
    if dom == "memory_s":
        return ("cut activation materialization: custom-VJP flash attention,"
                " bf16 residuals, fused norms")
    return "reshard to cut collectives (seq-parallel psum->reduce-scatter)"


def markdown_table(mesh: str = "single_pod_16x16") -> str:
    cells = load_cells(mesh)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        t = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{c['dominant'].replace('_s','')} | {c['model_flops']:.3g} | "
            f"{c['useful_flops_ratio']:.3f} | "
            f"{c.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def roofline_rows() -> List[Row]:
    rows: List[Row] = []
    for tag, directory in (("base", ARTIFACT_DIR), ("opt", OPT_DIR)):
        if not os.path.isdir(directory):
            continue
        baseline = {} if tag == "opt" else None
        if tag == "opt":
            for c in load_cells("single_pod_16x16", ARTIFACT_DIR):
                baseline[(c["arch"], c["shape"])] = max(
                    c["roofline"].values())
        for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
            cells = load_cells(mesh, directory)
            if not cells:
                continue
            n_dom = {"compute_s": 0, "memory_s": 0, "collective_s": 0}
            for c in cells:
                n_dom[c["dominant"]] += 1
            rows.append((f"roofline_{tag}_{mesh}", 0.0,
                         f"cells={len(cells)} "
                         f"compute-bound={n_dom['compute_s']}"
                         f" memory-bound={n_dom['memory_s']} "
                         f"collective-bound={n_dom['collective_s']}"))
            for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
                t = c["roofline"]
                extra = ""
                if tag == "opt" and mesh == "single_pod_16x16":
                    b = baseline.get((c["arch"], c["shape"]))
                    if b:
                        extra = f" binding_speedup={b/max(t.values()):.1f}x"
                rows.append((
                    f"cell_{tag}_{c['arch']}_{c['shape']}_"
                    f"{mesh.split('_')[0]}", 0.0,
                    f"comp={t['compute_s']:.3g}s mem={t['memory_s']:.3g}s "
                    f"coll={t['collective_s']:.3g}s dom="
                    f"{c['dominant'].replace('_s','')} "
                    f"useful={c['useful_flops_ratio']:.3f} "
                    f"frac={c.get('roofline_fraction', 0):.4f}" + extra))
    return rows
