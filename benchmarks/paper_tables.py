"""Paper-table benchmarks: Fig. 20 programs, Table 3 reliability, Fig. 21
throughput, Table 4 energy. Each returns a list of CSV rows
(name, us_per_call, derived)."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def fig20_programs() -> List[Row]:
    """AAP/AP counts per op: paper-faithful templates vs the optimizing
    compiler, verified bit-exact on the device simulator."""
    from repro.core import AmbitSubarray, Expr, compile_expr, eval_expr

    x, y, z = Expr.var("x"), Expr.var("y"), Expr.var("z")
    cases = {
        "and": x & y, "or": x | y, "nand": ~(x & y), "nor": ~(x | y),
        "xor": x ^ y, "xnor": ~(x ^ y), "not": ~x,
        "and3_chain": (x & y) & z,
        "maj_expr": (x & y) | (y & z) | (z & x),
    }
    rng = np.random.default_rng(0)
    env = {k: rng.integers(0, 2**64, 4, dtype=np.uint64) for k in "xyz"}
    rows: List[Row] = []
    for name, e in cases.items():
        t0 = time.perf_counter()
        comp_n = compile_expr(e, {"x": 0, "y": 1, "z": 2}, 3, optimize=False)
        comp_o = compile_expr(e, {"x": 0, "y": 1, "z": 2}, 3, optimize=True)
        us = (time.perf_counter() - t0) * 1e6
        sub = AmbitSubarray(words=4)
        for i, k in enumerate("xyz"):
            sub.write_row(i, env[k])
        sub.run(comp_o.program)
        ok = np.array_equal(sub.read_row(3), eval_expr(e, env))
        rows.append((f"fig20_{name}", us,
                     f"aap {comp_n.n_aap}->{comp_o.n_aap} "
                     f"ns {comp_n.stats.ns:.0f}->{comp_o.stats.ns:.0f} "
                     f"bitexact={ok}"))
    return rows


def fig20_batched() -> List[Row]:
    """Fig. 20 ops executed through the batched ambit_sim engine path:
    many subarray rows per eval, one compiled program per expression shape
    (LRU compile cache). Results are verified against the jnp backend and
    the wall-clock rate (device-model rows/s) is reported alongside the
    modeled DRAM latency."""
    import time

    from repro.core import (BitVector, BulkBitwiseEngine, Expr,
                            compile_cache_clear, compile_cache_info, maj)

    x, y, z = Expr.var("x"), Expr.var("y"), Expr.var("z")
    cases = {
        "and": x & y, "xor": x ^ y, "xnor": ~(x ^ y),
        "maj_expr": maj(x, y, z) ^ (x | ~z),
    }
    n_rows, n_bits = 256, 8192
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, (3, n_rows, n_bits)).astype(bool)
    env = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xyz")}
    sim = BulkBitwiseEngine("ambit_sim")
    ref = BulkBitwiseEngine("jnp")
    compile_cache_clear()
    rows: List[Row] = []
    for name, e in cases.items():
        sim.eval(e, env)  # populate the compile cache
        t0 = time.perf_counter()
        out = sim.eval(e, env)
        us = (time.perf_counter() - t0) * 1e6
        ok = bool(np.array_equal(np.asarray(out.bits()),
                                 np.asarray(ref.eval(e, env).bits())))
        st = sim.last_stats
        rows.append((f"fig20b_{name}", us,
                     f"rows={n_rows} rows_per_s={n_rows / (us * 1e-6):.3g} "
                     f"dram_ns={st.ns:.0f} bitexact={ok}"))
    info = compile_cache_info()
    rows.append(("fig20b_compile_cache", 0.0,
                 f"hits={info.hits} misses={info.misses} "
                 f"(one compile per expression shape)"))
    return rows


def table3_variation() -> List[Row]:
    from repro.core import TABLE3_PAPER
    from repro.core.analog import tra_failure_rate, tra_worst_case_margin

    rows: List[Row] = []
    for v, paper in TABLE3_PAPER.items():
        t0 = time.perf_counter()
        model = tra_failure_rate(v, n_trials=100_000)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table3_var{int(v*100):02d}", us,
                     f"model={model:.4f} paper={paper:.4f}"))
    rows.append(("table3_worst_case_margin", 0.0,
                 f"model={tra_worst_case_margin():.3f} paper~0.06"))
    return rows


# Fig. 21 throughput model ---------------------------------------------------

AAP_NS = 49.0
AP_NS = 50.0
ROW_BYTES = 8192
OP_COST = {  # (n_aap, n_ap) per Figure 20
    "not": (2, 0), "and": (4, 0), "or": (4, 0), "nand": (5, 0),
    "nor": (5, 0), "xor": (5, 2), "xnor": (6, 2),
}
CHANNEL_BW = {  # result-limited GB/s for 2-src ops = BW/3
    "skylake": 2 * 17.07e9,     # 2x DDR3-2133 64-bit
    "gtx745": 28.8e9,           # 128-bit DDR3-1800
    "hmc": 320e9,               # 32 vaults x 10 GB/s
}
PAPER_RATIOS = {"skylake": 44.9, "gtx745": 32.0, "hmc": 2.4}


def ambit_throughput(op: str, banks: int = 8,
                     row_bytes: int = ROW_BYTES) -> float:
    n_aap, n_ap = OP_COST[op]
    ns = n_aap * AAP_NS + n_ap * AP_NS
    return banks * row_bytes / (ns * 1e-9)


def fig21_throughput() -> List[Row]:
    rows: List[Row] = []
    ratios = {k: [] for k in CHANNEL_BW}
    for op in OP_COST:
        n_src = 1 if op == "not" else 2
        amb = ambit_throughput(op)
        derived = [f"ambit8={amb/1e9:.0f}GB/s"]
        for sysname, bw in CHANNEL_BW.items():
            base = bw / (n_src + 1)
            ratios[sysname].append(amb / base)
            derived.append(f"{sysname}={base/1e9:.1f}GB/s x{amb/base:.1f}")
        rows.append((f"fig21_{op}", 0.0, " ".join(derived)))
    for sysname in CHANNEL_BW:
        mean = float(np.mean(ratios[sysname]))
        rows.append((f"fig21_mean_vs_{sysname}", 0.0,
                     f"model={mean:.1f}x paper={PAPER_RATIOS[sysname]}x"))
    # Ambit-3D vs HMC: 256 banks, HMC-like ~1 KB effective row buffer
    amb3d = np.mean([ambit_throughput(op, banks=256, row_bytes=1024)
                     for op in OP_COST])
    hmc = np.mean([CHANNEL_BW["hmc"] / (3 if op != "not" else 2)
                   for op in OP_COST])
    rows.append(("fig21_ambit3d_vs_hmc", 0.0,
                 f"model={amb3d/hmc:.1f}x paper=9.7x"))
    return rows


def table4_energy() -> List[Row]:
    from repro.core import (TABLE4_PAPER, ddr3_energy_nj_per_kb,
                            op_energy_nj_per_kb)

    rows: List[Row] = []
    for op in ("not", "and", "nand", "xor", "xnor"):
        m_amb = op_energy_nj_per_kb(op)
        m_ddr = ddr3_energy_nj_per_kb(op)
        p_amb = TABLE4_PAPER["ambit"][op]
        p_ddr = TABLE4_PAPER["ddr3"][op]
        rows.append((f"table4_{op}", 0.0,
                     f"ambit {m_amb:.2f} (paper {p_amb}) "
                     f"ddr3 {m_ddr:.1f} (paper {p_ddr}) "
                     f"reduction {m_ddr/m_amb:.1f}x (paper "
                     f"{p_ddr/p_amb:.1f}x)"))
    return rows
