"""Reliability benchmarks: the price of surviving faults.

Two questions the reliability layer must answer with numbers:

  * what does TMR protection cost when nothing goes wrong - the 3x
    storage is by construction, but parity checks and replica-wise
    execution also tax every query (``faults_tmr_overhead``);
  * what do retries cost when rows actually fail - the closed-loop
    Zipfian serving mix re-run under a fixed stuck-row rate, reporting
    the latency tail shift and the recovery ledger
    (``faults_serve_r001`` at 0.1%%, ``faults_serve_r010`` at 1%%).

Everything structural (fault counts, retries, quarantined rows, latency
percentiles, mismatches) is ledger-derived and seed-deterministic, so
the rows diff bit-exact across machines; wall time lives only in the
``us`` column. Fault injection uses structural RNG keys, never
``hash()`` - the same rows come out under any ``PYTHONHASHSEED``.
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _counter(rt, name: str) -> int:
    c = rt.metrics.snapshot()["counters"]
    return int(sum(v for k, v in c.items()
                   if k == name or k.startswith(name + "{")))


def _tmr_overhead(**rt_kwargs) -> Row:
    """Fault-free TMR tax: replica-wise execution + parity checks vs the
    plain path, same query mix, same device shape."""
    from repro.core import BitVector, Expr
    from repro.pim.faults import FaultConfig, FaultInjector
    from repro.pim.runtime import AmbitRuntime

    X, Y = Expr.var("x"), Expr.var("y")
    rng = np.random.default_rng(0)
    raw = [rng.integers(0, 2, 512).astype(np.uint8) for _ in range(4)]
    mism = 0
    t0 = time.perf_counter()
    stats = {}
    for tag, protect in (("plain", False), ("tmr", True)):
        inj = FaultInjector(FaultConfig(seed=0))    # idle: zero rates
        rt = AmbitRuntime(fault_injector=inj, **rt_kwargs)
        up0 = rt.store.bytes_to_device
        hs = [rt.put(BitVector.from_bits(v), protect=protect)
              for v in raw]
        upload = rt.store.bytes_to_device - up0
        for k in range(12):
            i, j = k % 4, (k + 1) % 4
            r = rt.eval(X ^ Y, {"x": hs[i], "y": hs[j]})
            got = np.asarray(rt.get(r).bits())
            if not bool((got == (raw[i] ^ raw[j])).all()):
                mism += 1
            rt.free(r)
        stats[tag] = (upload, rt.session_stats.aap_count,
                      rt.session_stats.ns)
    wall_us = (time.perf_counter() - t0) * 1e6
    (up_p, aap_p, ns_p), (up_t, aap_t, ns_t) = stats["plain"], stats["tmr"]
    derived = (f"storage_x={int(round(up_t / up_p))} "
               f"aap_plain={aap_p} aap_tmr={aap_t} "
               f"aap_tax_pct={int(round(100.0 * (aap_t - aap_p) / aap_p))} "
               f"ns_tax_pct={int(round(100.0 * (ns_t - ns_p) / ns_p))} "
               f"mismatches={mism}")
    return "faults_tmr_overhead", wall_us, derived


def _serve_faulty(rate: float, n_tenants: int, n_queries: int,
                  n_users: int, n_items: int, max_batch: int,
                  window_ns: float, **rt_kwargs) -> Row:
    """The serve_closed_loop bitmap mix re-run under a fixed stuck-row
    rate: every completion still bit-exact, the latency tail carries
    the retry/backoff cost, and the recovery ledger is part of the row."""
    from repro.core import BitVector, Expr
    from repro.pim.faults import FaultConfig, FaultInjector
    from repro.pim.runtime import AmbitRuntime
    from repro.serve import QueryFrontend, run_closed_loop

    rng = np.random.default_rng(0)
    inj = FaultInjector(FaultConfig(seed=23, stuck_row_rate=rate))
    rt = AmbitRuntime(fault_injector=inj, **rt_kwargs)
    rt.reliability.max_retries = 8
    raw = {f"m{i}": rng.integers(0, 2, n_users).astype(np.uint8)
           for i in range(n_items)}
    hs = {k: rt.put(BitVector.from_bits(v), name=k)
          for k, v in raw.items()}
    expr = Expr.var("x") & Expr.var("y")
    tenants = [f"t{i}" for i in range(n_tenants)]
    pairs = [(i, j) for i in range(n_items) for j in range(i + 1, n_items)]
    w = 1.0 / np.arange(1, len(pairs) + 1, dtype=np.float64) ** 1.1
    pair_of = dict(zip(tenants, (
        pairs[i] for i in rng.choice(len(pairs), size=n_tenants,
                                     p=w / w.sum()))))
    expected = {}

    def next_query(tenant, k):
        i, j = pair_of[tenant]
        a, b = f"m{i}", f"m{j}"
        expected[tenant] = int((raw[a] & raw[b]).sum())
        return expr, {"x": hs[a], "y": hs[b]}

    mism = 0
    max_ns = 0.0

    def check(q):
        nonlocal mism, max_ns
        if not q.ok or rt.popcount(q.result) != expected[q.tenant]:
            mism += 1
        max_ns = max(max_ns, q.latency_ns)
        rt.free(q.result)

    fe = QueryFrontend(rt, window_ns=window_ns, max_batch=max_batch)
    t0 = time.perf_counter()
    done = run_closed_loop(fe, tenants, next_query, n_queries,
                           on_complete=check)
    wall_us = (time.perf_counter() - t0) * 1e6
    rep = fe.report()
    derived = (f"queries={done} errors={rep.errors} mismatches={mism} "
               f"faults={_counter(rt, 'fault_injected')} "
               f"retries={_counter(rt, 'ticket_retries')} "
               f"quarantined={_counter(rt, 'quarantined_rows')} "
               f"p50_ns={int(rep.p50_ns)} p99_ns={int(rep.p99_ns)} "
               f"max_ns={int(max_ns)} qps={rep.qps:.1f}")
    tag = f"r{int(round(rate * 1000)):03d}"
    return f"faults_serve_{tag}", wall_us, derived


def faults(trace_dir: Optional[str] = None) -> List[Row]:
    rows: List[Row] = []
    rows.append(_tmr_overhead(banks=4, subarrays=2, words=2))
    for rate in (0.001, 0.01):
        rows.append(_serve_faulty(
            rate, n_tenants=512, n_queries=1024, n_users=2048,
            n_items=12, max_batch=16, window_ns=5_000.0,
            banks=4, subarrays=2, words=2))
    return rows
