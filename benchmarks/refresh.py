"""Refresh-interference benchmarks + the timing-rule oracle.

Quantifies what DRAM refresh costs Ambit at realistic geometry (DDR3
8Gb-class: tREFI=7.8us, tRFC=350ns - banks lose ~4.7% of wall clock in
steady state) and exercises the timing checker over the canonical
command streams:

  refresh_rule_table      every canonical program (Figure-20 templates +
                          compiled expressions, optimized and naive, plus
                          a PSM copy) replayed against the 8-rule DDR
                          timing table - must be violation-free;
  refresh_overhead_model  the closed-form steady-state refresh tax;
  refresh_resident_chain  a planner chain at 8-bank geometry with the
                          per-bank ``refresh_stolen_ns`` ledger reconciled
                          bit-exactly across OpStats, the metrics registry
                          and the trace export;
  refresh_aware_drain     the same multi-query drain with and without
                          ``refresh=True``: wall-clock stretch = the
                          refresh windows the epoch timeline crossed.

All structural (integer) derived tokens are deterministic simulated-model
values, so benchmarks/compare.py diffs them across machines.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _mk_runtime(tracer=None):
    from repro.pim import AmbitRuntime

    return AmbitRuntime(backend="ambit_sim", banks=8, subarrays=4,
                        words=128, tracer=tracer)


def _bitvectors(n, rows, n_bits, seed=0):
    from repro.core import BitVector

    rng = np.random.default_rng(seed)
    return [BitVector.from_bits(
        rng.integers(0, 2, (rows, n_bits)).astype(bool)) for _ in range(n)]


def rule_table() -> Row:
    from repro.core.timing_checker import (TimingChecker, canonical_programs,
                                           schedule_program,
                                           schedule_psm_copy)

    checker = TimingChecker()
    t0 = time.perf_counter()
    progs = canonical_programs()
    n_cmds, n_viol = 0, 0
    for _, prog in progs:
        events = schedule_program(prog)
        n_cmds += len(events)
        n_viol += len(checker.check(events))
    psm = schedule_psm_copy(128)    # one full 8 KB row
    n_cmds += len(psm)
    n_viol += len(checker.check(psm))
    us = (time.perf_counter() - t0) * 1e6
    assert n_viol == 0, f"{n_viol} timing violations in canonical streams"
    return ("refresh_rule_table", us,
            f"programs={len(progs) + 1} commands={n_cmds} "
            f"violations={n_viol}")


def overhead_model() -> Row:
    from repro.core.timing import DEFAULT_TIMING

    t = DEFAULT_TIMING
    bp = round(1e4 * t.refresh_overhead)    # basis points
    return ("refresh_overhead_model", 0.0,
            f"tREFI_ns={t.tREFI:.0f} tRFC_ns={t.tRFC:.0f} "
            f"steady_state_overhead_bp={bp}")


def resident_chain(n_ops: int = 6, rows: int = 64) -> Row:
    """Chained ANDs through the placement-aware planner; the per-bank
    refresh tax must reconcile bit-exactly across the three surfaces."""
    from repro.obs import Tracer

    from repro.core.engine import OpStats

    tr = Tracer(enabled=True)
    rt = _mk_runtime(tracer=tr)
    n_bits = rt.store.device.words * 64
    vecs = _bitvectors(n_ops + 1, rows, n_bits)
    t0 = time.perf_counter()
    acc = rt.put(vecs[0], name="acc")
    expect_bank = {}            # replayed per-bank tax, call order
    expect = OpStats()          # replayed ledger, call order
    for i in range(n_ops):
        acc = rt.and_(acc, rt.put(vecs[i + 1]))
        for b, st in sorted(rt.planner.last_report.per_bank.items()):
            expect_bank[b] = expect_bank.get(b, 0.0) + st.refresh_stolen_ns
        expect += rt.last_stats
    us = (time.perf_counter() - t0) * 1e6

    # Bit-exact three-way reconciliation: the ledger, the metric series
    # and the trace spans all accumulate the planner's single per-call
    # per-bank figure in the same order, so equality is ==, not approx.
    assert rt.session_stats.refresh_stolen_ns == expect.refresh_stolen_ns
    series = rt.metrics.counters.get("refresh_stolen_ns").series
    for b, want in sorted(expect_bank.items()):
        key = (("bank", str(b)), ("device", "0"))
        assert series.get(key) == want, (b, series.get(key), want)
        got = 0.0
        for e in tr.events:
            if e.cat == "refresh" and e.track == ("device0", f"bank{b}"):
                got += e.dur_ns
        assert got == want, (b, got, want)
    busy = sum(rt.metrics.counters.get("bank_busy_ns").series.values())
    ledger = expect.refresh_stolen_ns
    return ("refresh_resident_chain", us,
            f"ops={n_ops} rows={rows} banks={len(series)} "
            f"busy_ns={round(busy)} stolen_ns={round(ledger)} "
            f"reconciled=1")


def aware_drain(queries: int = 4, rows: int = 48) -> Row:
    """Identical submit sets drained refresh-blind vs refresh-aware: the
    wall-clock delta is exactly the refresh windows the timeline paused
    through; the conservation ledger (ns/energy/AAPs) is untouched."""
    from repro.core import expr as E

    def run(refresh):
        rt = _mk_runtime()
        n_bits = rt.store.device.words * 64
        vecs = _bitvectors(2 * queries, rows, n_bits, seed=1)
        hs = [rt.put(v) for v in vecs]
        ab = E.Expr.var("a") & E.Expr.var("b")
        for q in range(queries):
            rt.submit(ab, {"a": hs[2 * q], "b": hs[2 * q + 1]})
        rt.drain(refresh=refresh)
        return rt.last_drain

    t0 = time.perf_counter()
    plain = run(False)
    aware = run(True)
    us = (time.perf_counter() - t0) * 1e6
    assert plain.stats.ns == aware.stats.ns          # ledger untouched
    assert aware.refresh_stall_ns == \
        aware.wall_ns - plain.wall_ns                # stretch == stall
    windows = round(aware.refresh_stall_ns / 350.0)
    return ("refresh_aware_drain", us,
            f"queries={queries} epochs={len(plain.epochs)} "
            f"wall_ns={round(plain.wall_ns)} "
            f"wall_refresh_ns={round(aware.wall_ns)} "
            f"stall_ns={round(aware.refresh_stall_ns)} "
            f"windows={windows}")


def refresh() -> List[Row]:
    return [rule_table(), overhead_model(), resident_chain(), aware_drain()]
