"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  fig20_*   AAP program counts (compiler opt) + bit-exactness
  fig20b_*  batched ambit_sim engine path (rows/s + compile cache)
  table3_*  TRA failure rate vs process variation (Monte Carlo)
  fig21_*   raw throughput model vs Skylake/GTX745/HMC (+Ambit-3D)
  table4_*  energy nJ/KB vs DDR3 baseline
  fig22_*   bitmap index queries        (Section 8.1)
  fig23_*   BitWeaving predicate scans  (Section 8.2)
  fig24_*   bitvector set operations    (Section 8.3)
  kern_*    Pallas kernel micro + engine roofline model
  refresh_* DRAM timing-rule oracle + refresh-interference model
  serve_*   closed-loop multi-tenant serving (continuous batching)
  faults_*  reliability: TMR tax + serving under injected faults
  roofline_* / cell_*  dry-run roofline aggregation (SSRoofline)

Machine-readable output: ``--json out.json`` additionally writes every
row as ``{"section", "name", "us", "derived"}`` records (schema 1).
Wall-clock lives only in ``us`` and non-integer derived tokens, so the
structural fields (names, op counts, ledger bytes/ns) diff cleanly
across machines - see benchmarks/compare.py and the committed
BENCH_kernels.json baseline. ``--sections kernels_micro`` (comma list,
substring match on section function names) restricts the run.
``--trace DIR`` threads a simulated-clock Tracer through the sections
that support it (serving) and writes Chrome/Perfetto trace JSON per
row into DIR - summarise with ``python tools/trace_report.py``.
"""

import argparse
import functools
import json
import sys


def sections(trace_dir=None):
    from . import (faults, kernels_micro, paper_apps, paper_tables,
                   refresh, roofline, serve_closed_loop)

    serve = serve_closed_loop.serve_closed_loop
    if trace_dir is not None:
        traced = functools.partial(serve, trace_dir=trace_dir)
        functools.update_wrapper(traced, serve)
        serve = traced
    return [
        paper_tables.fig20_programs,
        paper_tables.fig20_batched,
        paper_tables.table3_variation,
        paper_tables.fig21_throughput,
        paper_tables.table4_energy,
        paper_apps.fig22_bitmap,
        paper_apps.fig23_bitweaving,
        paper_apps.fig24_sets,
        kernels_micro.kernels_micro,
        refresh.refresh,
        serve,
        faults.faults,
        roofline.roofline_rows,
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="benchmark harness (see module docstring)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON records")
    ap.add_argument("--sections", default=None,
                    help="comma-separated substring filter on section "
                         "function names (e.g. 'kernels_micro')")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="write Chrome/Perfetto trace JSON per serving "
                         "row into DIR")
    args = ap.parse_args(argv)

    wanted = None
    if args.sections:
        wanted = [s.strip() for s in args.sections.split(",") if s.strip()]

    print("name,us_per_call,derived")
    rows, failures = [], 0
    for fn in sections(trace_dir=args.trace):
        if wanted is not None and \
                not any(w in fn.__name__ for w in wanted):
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
                rows.append({"section": fn.__name__, "name": name,
                             "us": round(us, 2), "derived": derived})
        except Exception as e:  # keep the harness robust
            failures += 1
            print(f"{fn.__name__},0.0,ERROR {type(e).__name__}: {e}")
            sys.stderr.write(f"benchmark {fn.__name__} failed: {e}\n")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"schema": 1, "rows": rows}, fh, indent=1,
                      sort_keys=True)
            fh.write("\n")
    if failures:
        raise SystemExit(f"{failures} benchmark section(s) failed")


if __name__ == "__main__":
    main()
