"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  fig20_*   AAP program counts (compiler opt) + bit-exactness
  fig20b_*  batched ambit_sim engine path (rows/s + compile cache)
  table3_*  TRA failure rate vs process variation (Monte Carlo)
  fig21_*   raw throughput model vs Skylake/GTX745/HMC (+Ambit-3D)
  table4_*  energy nJ/KB vs DDR3 baseline
  fig22_*   bitmap index queries        (Section 8.1)
  fig23_*   BitWeaving predicate scans  (Section 8.2)
  fig24_*   bitvector set operations    (Section 8.3)
  kern_*    Pallas kernel micro + engine roofline model
  roofline_* / cell_*  dry-run roofline aggregation (SSRoofline)
"""

import sys


def main() -> None:
    from . import kernels_micro, paper_apps, paper_tables, roofline

    sections = [
        paper_tables.fig20_programs,
        paper_tables.fig20_batched,
        paper_tables.table3_variation,
        paper_tables.fig21_throughput,
        paper_tables.table4_energy,
        paper_apps.fig22_bitmap,
        paper_apps.fig23_bitweaving,
        paper_apps.fig24_sets,
        kernels_micro.kernels_micro,
        roofline.roofline_rows,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for fn in sections:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the harness robust
            failures += 1
            print(f"{fn.__name__},0.0,ERROR {type(e).__name__}: {e}")
            sys.stderr.write(f"benchmark {fn.__name__} failed: {e}\n")
    if failures:
        raise SystemExit(f"{failures} benchmark section(s) failed")


if __name__ == "__main__":
    main()
