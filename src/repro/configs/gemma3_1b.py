"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 - 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense", n_layers=26, d_model=1152,
    n_heads=4, n_kv_heads=1, d_ff=6912, vocab=262144, d_head=256,
    rope_theta=10000.0, global_rope_theta=1_000_000.0,
    sliding_window=1024, global_every=6,  # layers 5,11,17,23 global (5:1)
    tie_embeddings=True, scale_embeddings=True, act="gelu",
)
