"""Architecture configuration schema + input-shape sets.

One ArchConfig per assigned architecture (exact dims from the assignment
table); .reduced() yields a family-preserving small config for CPU smoke
tests. The four input-shape sets (train_4k / prefill_32k / decode_32k /
long_500k) are defined here with their applicability rules (DESIGN.md
SS5: long_500k only for sub-quadratic families).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Expert-count padding granularity: 16 = TP-axis EP (training);
    # serving cells may raise it to data*model (e.g. 256) for 2D expert
    # sharding, where weights stay resident and tokens are gathered
    # (EXPERIMENTS.md SSPerf hillclimb 3).
    pad_to: int = 16


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | audio | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # defaults to d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_kind: str = "rope"               # rope | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False
    scale_embeddings: bool = False        # gemma: x *= sqrt(d_model)
    norm_eps: float = 1e-6
    act: str = "silu"
    # sliding-window pattern: window size + global-attention period
    # (every `global_every`-th layer is global; 0 = all global/full)
    sliding_window: int = 0
    global_every: int = 0
    global_rope_theta: Optional[float] = None
    # MoE / SSM / hybrid extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0            # zamba2: shared block period
    # encoder-decoder (whisper): encoder frames are stub embeddings
    enc_dec: bool = False
    n_frames: int = 1500
    n_enc_layers: int = 0
    # vlm stub frontend
    vision_tokens: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / mostly-sliding-window)."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.global_every > 0)

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        pattern = max(self.global_every, self.shared_attn_every, 1)
        n_layers = max(2 * pattern, 2)
        kv = max(1, min(self.n_kv_heads, 2))
        heads = max(kv * 2, 4)
        moe = (MoEConfig(n_experts=8, top_k=2, d_ff_expert=32)
               if self.moe else None)
        ssm = (SSMConfig(d_state=16, expand=2, head_dim=16, chunk=16)
               if self.ssm else None)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=64, n_heads=heads,
            n_kv_heads=kv, d_head=16, d_ff=128, vocab=512,
            mrope_sections=(2, 3, 3),  # sums to d_head/2 = 8
            sliding_window=min(self.sliding_window, 32) if self.sliding_window
            else 0, moe=moe, ssm=ssm, n_frames=24,
            n_enc_layers=2 if self.enc_dec else 0, vision_tokens=8)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k needs sub-quadratic attention;
    all archs in the pool have a decode path (whisper decodes with its
    decoder stack)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
