"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 -
encoder-decoder; conv frontend is a STUB (input_specs provides precomputed
frame embeddings (B, 1500, d_model)). [arXiv:2212.04356]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, d_head=64,
    rope_kind="none",  # whisper uses sinusoidal abs positions
    tie_embeddings=True,
    act="gelu", enc_dec=True, n_frames=1500, n_enc_layers=12,
)
