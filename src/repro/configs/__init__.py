"""Assigned-architecture registry: --arch <id> -> ArchConfig."""

from .base import SHAPES, ArchConfig, MoEConfig, SSMConfig, ShapeConfig, \
    shape_applicable
from . import (deepseek_67b, gemma3_1b, granite_moe_3b_a800m, internlm2_20b,
               mamba2_780m, qwen2_5_3b, qwen2_vl_7b, qwen3_moe_235b_a22b,
               whisper_small, zamba2_2_7b)

REGISTRY = {
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "deepseek-67b": deepseek_67b.CONFIG,
    "gemma3-1b": gemma3_1b.CONFIG,
    "internlm2-20b": internlm2_20b.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
    "zamba2-2.7b": zamba2_2_7b.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "qwen2-vl-7b": qwen2_vl_7b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ArchConfig", "MoEConfig", "REGISTRY", "SHAPES", "SSMConfig",
           "ShapeConfig", "get_config", "shape_applicable"]
