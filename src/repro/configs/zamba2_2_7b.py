"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 - Mamba2 backbone + weight-tied shared attention
block invoked every 6 layers. [arXiv:2411.15242]"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, d_head=80,
    rope_theta=10000.0,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    shared_attn_every=6,
)
