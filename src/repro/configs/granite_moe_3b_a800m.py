"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155, d_head=64,
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
)
