"""DeviceStore: the accelerator twin of PimStore.

PRs 2-4 gave the *simulated* DRAM path residency - operands live in device
rows, chains never cross the channel, and the ledger measures only real
transfers. The performance backends ("jnp"/"pallas") still ferried every
operand host->device->host on each eval: exactly the traffic Ambit (and
Buddy-RAM's row-resident operand model) exists to elide. This module
closes that gap:

  * ``DeviceBitVector`` / ``DeviceStore`` - bitvectors ``put`` once live
    as jax device arrays behind the SAME handle API as PimStore
    (put/get/free/pin, dirty tracking, LRU spill to host under a
    ``capacity_bytes`` budget). ``OpStats.bytes_touched`` is zero for
    resident operands; only faulted-in / spilled bytes are charged, so
    the ledger is honest for the fast path the same way PR 2 made it
    honest for ambit_sim.

  * ``DevicePlanner`` - the QueryPlanner analogue: one whole expression
    tree evaluates as ONE fused dispatch over resident device arrays
    (jitted-callable LRU in core.engine mirroring ``_compile_cached``),
    results stay resident (dirty: no host read-back until ``get``), and
    ``out=``-style rebinds donate the destination's buffer to XLA
    (``jax.jit(..., donate_argnums=...)``) so chained queries update
    storage in place without allocation churn.

  * epoch-stacked execution - ``execute_epoch`` dispatches a whole
    scheduler epoch of shape-compatible queries as ONE stacked
    ``pallas_call`` (operand tiles stacked along a query axis), one
    kernel launch per epoch instead of one per query.

The DRAM-model fields of the ledger (ns / energy / AAPs) stay zero here:
the accelerator path measures *traffic*, the ambit_sim path measures the
paper's device physics. Both share OpStats so apps and benchmarks compare
them directly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import expr as E
from ..core.bitvector import BitVector
from ..core.engine import (OpStats, _device_compiled,
                           _device_compiled_stacked)
from ..core.simulator import AmbitError
from .store import LruSpillBase


@dataclasses.dataclass(eq=False)
class DeviceBitVector:
    """Handle to a bitvector resident on the accelerator as a packed
    uint32 device array. Compares (and hashes) by identity.

    ``spilled`` handles hold no device buffer (LRU-evicted under the
    capacity budget) but stay fully usable: the host copy is current,
    ``get`` is free, and ``ensure_resident`` re-uploads on demand.
    ``pinned`` handles are never chosen as eviction victims."""

    store: "DeviceStore"
    n_bits: int
    shape: Tuple[int, ...]       # leading (batch) dims of the host layout
    words32: int                 # packed uint32 words per logical row
    _dev: Optional[jnp.ndarray] = None   # shape + (words32,) uint32
    dirty: bool = False
    pinned: bool = False
    spilled: bool = False
    name: Optional[str] = None
    _host: Optional[BitVector] = None
    # True when the store created _dev itself (planner results): only
    # such buffers may be donated to XLA - a put() buffer is shared with
    # the caller's BitVector, and donating it would invalidate memory
    # the caller still references.
    _private: bool = False

    @property
    def device_bytes(self) -> int:
        n_rows = int(np.prod(self.shape)) if self.shape else 1
        return n_rows * self.words32 * 4

    @property
    def slots(self) -> list:
        """Placement-API compatibility: accelerator arrays have no row
        homes, so apps' ``near=handle.slots`` chains degrade to None."""
        return []

    @property
    def freed(self) -> bool:
        return self._dev is None and not self.spilled

    def get(self) -> BitVector:
        return self.store.get(self)

    def free(self) -> None:
        self.store.free(self)

    def __repr__(self):
        nm = f" {self.name!r}" if self.name else ""
        flags = (" pinned" if self.pinned else "") + \
            (" spilled" if self.spilled else "")
        return (f"<DeviceBitVector{nm} n_bits={self.n_bits} "
                f"bytes={self.device_bytes} dirty={self.dirty}{flags}>")


class DeviceStore(LruSpillBase):
    """put/get/free lifecycle for bitvectors resident on one accelerator.

    Mirrors PimStore's ledger contract: ``bytes_to_device`` /
    ``bytes_from_device`` count only genuine host<->accelerator
    transfers (uploads at put/fault-in, read-backs of dirty data), and
    the LRU spills the coldest unpinned handle when ``capacity_bytes``
    would be exceeded - clean victims for free, dirty ones read back
    through the ledger first."""

    _handle_desc = "device bitvector"
    _obs_name = "device_store"

    def __init__(self, backend: str = "jnp",
                 capacity_bytes: Optional[int] = None):
        if backend not in ("jnp", "pallas"):
            raise ValueError(
                f"DeviceStore backends are 'jnp'/'pallas', got {backend!r} "
                "(the DRAM model path is PimStore)")
        self.backend = backend
        self.capacity_bytes = capacity_bytes
        self.resident_bytes = 0
        self.host_writes = 0
        self.host_reads = 0
        self.bytes_to_device = 0
        self.bytes_from_device = 0
        self._lru_init()

    # -- LruSpillBase hooks ---------------------------------------------------

    def _owner_of(self, rbv: DeviceBitVector):
        return rbv.store

    def _resident_storage(self, rbv: DeviceBitVector) -> bool:
        return rbv._dev is not None

    def _release_rows(self, rbv: DeviceBitVector) -> None:
        if rbv._dev is not None:
            self.resident_bytes -= rbv.device_bytes
        rbv._dev = None

    def _move_storage(self, out: DeviceBitVector,
                      res: DeviceBitVector) -> None:
        out._dev, res._dev = res._dev, None   # byte count rides along
        out._private = res._private

    def _read_back(self, rbv: DeviceBitVector) -> BitVector:
        # Materialize on the host (np.asarray forces the D2H transfer):
        # wrapping the device array itself would keep accelerator memory
        # alive past spill, silently breaking the capacity budget.
        out = BitVector(np.asarray(rbv._dev), rbv.n_bits)
        rbv._host = out
        rbv.dirty = False
        self._charge_io("from_device", self._io_cause or "read_back",
                        rbv.device_bytes)
        return out

    def spill(self, rbv: DeviceBitVector, _force_held: bool = False) -> None:
        super().spill(rbv, _force_held=_force_held)
        # Clean victims skip _read_back, but their host copy may still
        # wrap a device array (put() shares the caller's buffer): pin the
        # copy to host memory so the spill really releases the device.
        if isinstance(rbv._host.data, jnp.ndarray):
            rbv._host = BitVector(np.asarray(rbv._host.data), rbv.n_bits)

    # -- capacity -------------------------------------------------------------

    def _make_room(self, nbytes: int,
                   protect: Iterable[DeviceBitVector] = ()) -> None:
        if self.capacity_bytes is None:
            return
        while self.resident_bytes + nbytes > self.capacity_bytes:
            if not self._evict_lru(protect):
                raise AmbitError(
                    f"device capacity full ({self.resident_bytes}/"
                    f"{self.capacity_bytes} B resident) and every device "
                    f"bitvector is pinned or in use")

    def adopt(self, rbv: DeviceBitVector) -> DeviceBitVector:
        """Track an externally built handle (planner results) in the LRU
        and the capacity ledger, like any put() handle."""
        self.resident_bytes += rbv.device_bytes
        self._register(rbv)
        return rbv

    # -- lifecycle ------------------------------------------------------------

    def put(self, bv: BitVector, policy=None, near=None,
            name: Optional[str] = None,
            pin: bool = False) -> DeviceBitVector:
        """Upload a host BitVector (``near``/``policy`` are accepted for
        PimStore API compatibility; an accelerator has no row placement)."""
        del policy, near
        data = jnp.asarray(bv.data, jnp.uint32)
        rbv = DeviceBitVector(
            store=self, n_bits=bv.n_bits, shape=tuple(data.shape[:-1]),
            words32=int(data.shape[-1]), _dev=None, dirty=False,
            name=name, _host=bv)
        self._make_room(rbv.device_bytes)
        rbv._dev = data
        self.adopt(rbv)
        self._charge_io("to_device", "upload", rbv.device_bytes)
        if pin:
            try:
                self.pin(rbv)
            except AmbitError:          # over budget: undo the upload
                self.free(rbv)
                raise
        return rbv

    def ensure_resident(self, rbv: DeviceBitVector,
                        protect: Iterable[DeviceBitVector] = ()
                        ) -> DeviceBitVector:
        """Fault a spilled handle back onto the accelerator (charged as a
        fresh upload). Live handles just refresh recency."""
        self._check_handle(rbv)
        if not rbv.spilled:
            self._touch(rbv)
            return rbv
        self._make_room(rbv.device_bytes, protect=(rbv, *protect))
        rbv._dev = jnp.asarray(rbv._host.data, jnp.uint32)
        rbv._private = False        # conservatively non-donatable again
        rbv.spilled = False
        rbv.dirty = False
        self.adopt(rbv)
        self._charge_io("to_device", "fault_in", rbv.device_bytes)
        self._invalidate(rbv)   # placement changed: generation bumps
        return rbv

    # -- device-side reduction -------------------------------------------------

    def popcount(self, rbv: DeviceBitVector) -> int:
        """Count set bits WITHOUT reading the bitvector back: the
        reduction runs on the accelerator (pallas popcount kernel on the
        pallas backend, ``lax.population_count`` on jnp) and only the
        int32 total crosses to the host - 4 ledger bytes instead of the
        whole array. Device arrays are tail-masked by construction
        (put data comes from packed BitVectors; planner results are
        masked in ``_device_compiled``), so the full-array count is
        exact. Spilled handles count their current host copy for free."""
        self._check_handle(rbv)
        if rbv.spilled:
            return int(np.asarray(rbv._host.popcount()).sum())
        self._touch(rbv)
        dev = rbv._dev.reshape(-1, rbv.words32)
        if self.backend == "pallas":
            from ..kernels import ops as kops
            total = int(jnp.sum(kops.popcount(dev)))
        else:
            total = int(jax.lax.population_count(dev).sum())
        self._charge_io("from_device", "popcount", 4)   # one int32 scalar
        return total


@dataclasses.dataclass
class DeviceReport:
    """What one accelerator planner execution (or epoch) did. ``per_bank``
    stays empty - an accelerator dispatch has no per-bank DRAM ledger -
    and exists so the async scheduler's accounting path is uniform."""

    queries: int = 0
    kernel_launches: int = 0
    donated: int = 0                # out= buffers donated to XLA
    per_bank: Dict[Tuple[int, int], OpStats] = dataclasses.field(
        default_factory=dict)
    stats: OpStats = dataclasses.field(default_factory=OpStats)


class DevicePlanner:
    """Whole-Expr execution over DeviceStore handles: the accelerator
    analogue of QueryPlanner, sharing its ``execute`` / ``footprint`` /
    ``last_report`` surface so AmbitRuntime and AsyncScheduler drive
    either interchangeably."""

    def __init__(self, store: DeviceStore):
        self.store = store
        self.backend = store.backend
        self.kernel_launches = 0
        self.last_report: Optional[DeviceReport] = None

    # -- scheduler hooks ------------------------------------------------------

    def footprint(self, env: Dict[str, DeviceBitVector]) -> frozenset:
        """An accelerator epoch is one fused launch, not a set of banks:
        queries never contend for (device, bank) resources, so epoch
        admission is governed purely by data hazards and the stack key."""
        return frozenset()

    def stack_key(self, expression: E.Expr, env: Dict[str, object]):
        """Queries sharing this key stack into ONE kernel launch: same
        expression DAG, operand names, and operand geometry. Ticket
        operands (results of earlier queries) inherit the geometry of
        their producers, so any concrete handle in the DAG decides."""
        handle = self._any_handle(env)
        if handle is None:
            return (expression, tuple(sorted(env)))
        return (expression, tuple(sorted(env)), handle.n_bits,
                handle.shape, handle.words32)

    def _any_handle(self, env: Dict[str, object]):
        for nm in sorted(env):
            v = env[nm]
            if isinstance(v, DeviceBitVector):
                return v
            sub = getattr(v, "env", None)   # a Ticket: recurse
            if sub is not None:
                h = self._any_handle(sub)
                if h is not None:
                    return h
        return None

    # -- execution ------------------------------------------------------------

    def _validate(self, env: Dict[str, DeviceBitVector]):
        if not env:
            raise ValueError("planner needs at least one operand")
        names = sorted(env)
        first = env[names[0]]
        for nm in names:
            rbv = env[nm]
            self.store._check_live(rbv)
            if (rbv.n_bits, rbv.shape, rbv.words32) != (
                    first.n_bits, first.shape, first.words32):
                raise ValueError(
                    "bbop operands must be row-aligned and equal-sized "
                    "(Section 5.3)")
            self.store._touch(rbv)
        return names, first

    def execute(self, expression: E.Expr,
                env: Dict[str, DeviceBitVector],
                out_name: Optional[str] = None,
                donate_to: Optional[DeviceBitVector] = None
                ) -> DeviceBitVector:
        """One fused dispatch over resident operands; the result stays
        resident (dirty). ``donate_to`` - the handle an ``out=`` rebind
        will overwrite - donates its buffer to XLA when it is exactly one
        of the operands, so the chained update reuses its storage."""
        names, first = self._validate(env)
        donate_idx = None
        if donate_to is not None and donate_to._private:
            # only store-created buffers donate (a put() buffer is shared
            # with the caller's BitVector); aliased twice is also unsafe
            matches = [k for k, nm in enumerate(names)
                       if env[nm] is donate_to]
            if len(matches) == 1:
                donate_idx = matches[0]
        fn = _device_compiled(expression, tuple(names), self.backend,
                              first.n_bits, donate_idx)
        out_dev = fn(*[env[nm]._dev for nm in names])
        # Budget the result AFTER the dispatch consumed the operand
        # buffers: cold operands are now legal spill victims, so an
        # exact-fit capacity still runs arbitrarily long chains. A
        # donated destination must survive until the rebind.
        self.store._make_room(
            first.device_bytes,
            protect=() if donate_idx is None else (donate_to,))
        self.kernel_launches += 1
        if self.backend == "pallas":
            from ..kernels import ops as kops
            kops._count_dispatch()
        res = DeviceBitVector(
            store=self.store, n_bits=first.n_bits, shape=first.shape,
            words32=first.words32, _dev=out_dev, dirty=True, name=out_name,
            _private=True)
        self.store.adopt(res)
        self.last_report = DeviceReport(
            queries=1, kernel_launches=1,
            donated=0 if donate_idx is None else 1, stats=OpStats())
        self._record_dispatch(queries=1,
                              donated=0 if donate_idx is None else 1)
        return res

    def _record_dispatch(self, queries: int, donated: int = 0) -> None:
        m = self.store.metrics
        m.counter("fused_dispatches").inc(1)
        m.counter("fused_queries").inc(queries)
        if donated:
            m.counter("donated_buffers").inc(donated)
        tr = self.store.tracer
        if tr.enabled:
            tr.instant(("device_store", "dispatch"), "fused_dispatch",
                       "dispatch", args={"queries": queries,
                                         "backend": self.backend,
                                         "donated": donated})

    def execute_epoch(self, jobs: Sequence[tuple]) -> List[DeviceBitVector]:
        """Dispatch one scheduler epoch - ``(expression, env, out_name,
        out_handle)`` jobs sharing a stack key - as ONE stacked kernel
        launch. Singleton epochs take the unstacked path so ``out=``
        chains keep their buffer donation."""
        if len(jobs) == 1:
            expression, env, out_name, out = jobs[0]
            donate = out if out is not None and \
                any(v is out for v in env.values()) else None
            res = self.execute(expression, env, out_name=out_name,
                               donate_to=donate)
            return [res]
        expression, env0, _, _ = jobs[0]
        names, first = self._validate(env0)
        for _, env, _, _ in jobs[1:]:
            jnames, jfirst = self._validate(env)
            if jnames != names or (jfirst.n_bits, jfirst.shape) != (
                    first.n_bits, first.shape):
                raise AmbitError(
                    "epoch jobs must share (expression, names, shape) - "
                    "the scheduler's stack key guarantees this")
        fn = _device_compiled_stacked(expression, tuple(names),
                                      self.backend, first.n_bits)
        n_rows = int(np.prod(first.shape)) if first.shape else 1
        stacks = [
            jnp.stack([job[1][nm]._dev.reshape(n_rows, first.words32)
                       for job in jobs]) for nm in names]
        out3 = fn(*stacks)              # (queries, rows, words32)
        self.store._make_room(len(jobs) * first.device_bytes)
        self.kernel_launches += 1
        if self.backend == "pallas":
            from ..kernels import ops as kops
            kops._count_dispatch()
        results = []
        for k, (_, _, out_name, _) in enumerate(jobs):
            res = DeviceBitVector(
                store=self.store, n_bits=first.n_bits, shape=first.shape,
                words32=first.words32,
                _dev=out3[k].reshape(first.shape + (first.words32,)),
                dirty=True, name=out_name, _private=True)
            self.store.adopt(res)
            results.append(res)
        self.last_report = DeviceReport(queries=len(jobs),
                                        kernel_launches=1, stats=OpStats())
        self._record_dispatch(queries=len(jobs))
        return results
