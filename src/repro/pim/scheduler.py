"""AsyncScheduler: overlap independent queries across banks and devices.

The paper's core claim is internal parallelism - every bank (and, one
level up, every device of a cluster) can run a bbop concurrently - yet
``QueryPlanner.execute`` serves ONE query at a time: it already reports
max-over-banks time *within* a query, but a second user's session waits
for the first. The scheduler converts the runtime to a queued execution
model that overlaps independent sessions:

  * ``submit(expr, env)`` enqueues a query and returns a ``Ticket``.
    Operands are *held* from the moment they are queued: the LRU spiller
    prefers any unheld victim and ``free`` refuses them, so a
    queued-but-not-executed operand is never evicted while anything else
    can make room (under genuine capacity pressure the coldest queued
    operand spills last-resort and faults back in when its query runs,
    charged to that query's ticket). Environment
    values may be other tickets (multi-root DAGs: a later query consumes
    an earlier query's result without a drain in between), and ``out=``
    rebinds the result into an existing handle in place.

  * ``drain()`` packs the queue into **epochs** by the ``(device, bank)``
    resources each query's operands occupy: queries touching disjoint
    banks land in the same epoch and run concurrently, so epoch time is
    the max over resources of the time charged to each resource - not the
    sum over queries. Conflicts force later epochs: overlapping bank
    footprints (a bank runs one bbop at a time), reading a handle an
    earlier query writes, and two queries writing the same destination
    handle never share an epoch; submit order is the deterministic
    tiebreak throughout (greedy first-fit in ticket order, no hash-order
    iteration anywhere).

Accounting is conservation-exact: queries execute in submit order under
the hood (epochs are a packing/accounting construct, never a reorder),
so summed energy and AAP counts are *identical* to serial ``eval`` of the
same queries, results are bit-identical, and reported time is the sum of
epoch maxima - always <= the serial sum, with equality when every query
contends for one bank. Cross-device channel transfers serialize within
an epoch (their ns adds on top of the epoch's compute max), and a
spilled operand faulting back in during ``drain`` is charged to that
query's ticket stats.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..core import expr as E
from ..core.engine import OpStats
from ..core.simulator import AmbitError
from ..core.timing import refresh_schedule
from .faults import FaultError

Resource = Tuple[int, int]          # (device index, bank index)

QUEUED = "queued"
DONE = "done"
FAILED = "failed"
CANCELLED = "cancelled"


@dataclasses.dataclass(eq=False)
class Ticket:
    """One submitted query. ``result`` and ``stats`` are populated by the
    drain that executes it; ``epoch`` is its position in the drain's
    epoch schedule. Tickets order (and resolve ties) by ``index``, the
    global submit sequence number."""

    scheduler: "AsyncScheduler"
    index: int
    expression: E.Expr
    env: Dict[str, object]          # name -> handle or Ticket
    out: Optional[object] = None    # existing handle to rebind in place
    out_name: Optional[str] = None
    state: str = QUEUED
    epoch: int = -1
    result: Optional[object] = None
    stats: OpStats = dataclasses.field(default_factory=OpStats)
    # per-resource ns this query charged, measured from the planner's
    # per-bank ledger deltas (keys normalized to (device, bank))
    resource_ns: Dict[Resource, float] = dataclasses.field(
        default_factory=dict)
    channel_ns: float = 0.0         # serialized cross-device transfer time
    # Simulated-clock timestamps (serving frontends): ``submitted_ns`` is
    # the ``now_ns`` the query was enqueued at; ``started_ns`` /
    # ``finished_ns`` are assigned by the drain that executes it from the
    # cumulative epoch timeline (measured epoch ns, or the drain's
    # ``epoch_cost`` model). -1.0 until the drain runs.
    submitted_ns: float = 0.0
    started_ns: float = -1.0
    finished_ns: float = -1.0
    # Optimizer provenance (drain(optimize=True)): the expression as
    # submitted when the pass rewrote it, whether this ticket is a
    # synthetic scratch materialization of a shared subtree, and whether
    # it was served from the result cache without executing.
    rewritten_from: Optional[E.Expr] = None
    synthetic: bool = False
    cache_hit: bool = False
    # Reliability (repro.pim.faults): a ticket whose recovery failed
    # lands in FAILED (or CANCELLED when a dependency failed) with the
    # fault message in ``error`` instead of crashing the drain;
    # ``retries``/``backoff_ns`` bill the recovery attempts the
    # reliability layer spent on it (backoff stretches the drain
    # timeline, never the conservation-exact work ledgers).
    error: Optional[str] = None
    retries: int = 0
    backoff_ns: float = 0.0
    # Why this query did not land in epoch 0: the packing constraints
    # that bound it (recorded by ``_form_epochs``). Each entry is one of
    # ``dep:#N`` (reads ticket N's result), ``read-after-write:<name>``,
    # ``write-conflict`` (out= destination clash), ``bank-conflict``
    # (resource overlap with an earlier epoch), ``stack-shape`` (epoch
    # key mismatch on a stacking backend). Empty = ran in the first
    # epoch it was eligible for with nothing in its way.
    deferred: List[str] = dataclasses.field(default_factory=list)

    @property
    def queue_ns(self) -> float:
        """Time spent queued before its epoch started."""
        return self.started_ns - self.submitted_ns

    @property
    def latency_ns(self) -> float:
        """Submit-to-completion time on the drain's simulated clock."""
        return self.finished_ns - self.submitted_ns

    def __repr__(self):
        return (f"<Ticket #{self.index} {self.state}"
                f"{f' epoch={self.epoch}' if self.epoch >= 0 else ''}>")


@dataclasses.dataclass
class EpochReport:
    """One epoch of a drain: the tickets that shared it, the resources
    they claimed, and the epoch's critical-path time (max over resources
    of summed per-resource ns, plus serialized channel transfers)."""

    tickets: List[int] = dataclasses.field(default_factory=list)
    resources: List[Resource] = dataclasses.field(default_factory=list)
    ns: float = 0.0
    channel_ns: float = 0.0
    # Position on the drain's simulated clock: [start_ns, end_ns) where
    # end - start is the measured epoch ns, or the caller's epoch_cost
    # model when the backend has no DRAM timing (accelerator stores).
    start_ns: float = 0.0
    end_ns: float = 0.0
    # Refresh stall inside this epoch's [start_ns, end_ns) interval -
    # nonzero only under ``drain(refresh=True)``, where end - start =
    # work + refresh_ns (the epoch paused through refresh windows).
    refresh_ns: float = 0.0


@dataclasses.dataclass
class DrainReport:
    """What one drain did. ``stats.ns`` is the sum of epoch maxima;
    energy/AAPs/bytes are plain sums over the drained tickets (identical
    to serial evaluation by construction). ``serial_ns`` is what the same
    queries would have reported executed one eval at a time.

    ``stats.refresh_stolen_ns`` is the tickets' steady-state refresh tax
    (planner ledger, always on); ``refresh_stall_ns`` is the event-level
    stall the timeline actually absorbed, nonzero only under
    ``drain(refresh=True)`` (= sum of epoch ``refresh_ns``)."""

    epochs: List[EpochReport] = dataclasses.field(default_factory=list)
    stats: OpStats = dataclasses.field(default_factory=OpStats)
    serial_ns: float = 0.0
    # Total bank-busy time: the sum of every drained ticket's summed
    # per-resource ns. Unlike ``stats.ns`` (epoch maxima) or
    # ``serial_ns`` (per-ticket maxima), this is pure work with no
    # packing artifacts - the quantity the optimizer conserves.
    busy_ns: float = 0.0
    start_ns: float = 0.0           # the drain's ``now_ns``
    end_ns: float = 0.0             # clock after the last epoch
    refresh_stall_ns: float = 0.0
    # The optimizer's OptReport when this drain ran with optimize=True
    # (None otherwise): CSE/cache hit counts, placement skips and the
    # cost-model savings estimate for this drain.
    opt: Optional[object] = None

    @property
    def n_queries(self) -> int:
        return sum(len(e.tickets) for e in self.epochs)

    @property
    def wall_ns(self) -> float:
        """Simulated wall time the drain occupied the device for."""
        return self.end_ns - self.start_ns


class AsyncScheduler:
    """Submit/drain queue over one PimStore+QueryPlanner (single device)
    or PimCluster+ClusterPlanner (sharded) pair."""

    def __init__(self, store, planner, handle_type):
        self.store = store
        self.planner = planner
        self._handle_type = handle_type
        self.pending: List[Ticket] = []
        self.drains = 0
        self.last_drain: Optional[DrainReport] = None
        self._submitted = 0
        self._optimizer = None
        # Set by the runtime when fault injection is configured: ticket
        # execution routes through ReliabilityManager.execute_ticket
        # (bounded retry, quarantine, TMR scrub) instead of _execute_plain.
        self.reliability = None
        # DRAM timing of the backing device(s): drives the refresh-aware
        # drain timeline. None on accelerator stores (no DRAM model - a
        # ``refresh=True`` drain degrades to the plain timeline there).
        dev = getattr(store, "device", None)
        if dev is not None and hasattr(dev, "timing"):
            self._timing = dev.timing
        else:
            devs = getattr(store, "devices", None) or ()
            self._timing = devs[0].timing if len(devs) else None

    @property
    def optimizer(self):
        """The drain-time query optimizer (created lazily on first use;
        its result cache persists across drains)."""
        if self._optimizer is None:
            from .optimizer import QueryOptimizer
            self._optimizer = QueryOptimizer(self)
        return self._optimizer

    # -- submission ----------------------------------------------------------

    def submit(self, expression: E.Expr, env: Dict[str, object],
               out=None, out_name: Optional[str] = None,
               now_ns: float = 0.0) -> Ticket:
        """Enqueue a query; returns its Ticket. Operands may be resident
        handles or tickets of earlier-submitted queries (their result is
        consumed without an intermediate drain). All operands are held -
        protected from eviction and free - until the query executes.
        ``now_ns`` stamps the ticket's submit time on the caller's
        simulated clock (serving frontends measure queueing delay from
        it)."""
        if not env:
            raise ValueError("scheduler needs at least one operand")
        resolved: Dict[str, object] = {}
        held: List[object] = []     # rollback on validation failure
        try:
            for nm in sorted(env):
                v = env[nm]
                if isinstance(v, Ticket):
                    if v.scheduler is not self:
                        raise AmbitError(
                            f"operand {nm!r} is a ticket of another "
                            "scheduler")
                    if v.state == DONE:  # earlier drain: use the result
                        v = v.result
                    elif v.state != QUEUED:
                        raise AmbitError(
                            f"operand {nm!r} is a {v.state} ticket")
                if isinstance(v, Ticket):
                    resolved[nm] = v
                elif isinstance(v, self._handle_type):
                    self.store._check_handle(v)
                    self.store.hold(v)
                    held.append(v)
                    resolved[nm] = v
                else:
                    raise TypeError(
                        f"operand {nm!r} is not resident or a ticket - "
                        "call put() first")
            if out is not None:
                if not isinstance(out, self._handle_type):
                    raise TypeError(
                        "out= must be an existing resident handle")
                self.store._check_handle(out)
                self.store.hold(out)
                held.append(out)
        except Exception:
            for h in held:
                self.store.release(h)
            raise
        t = Ticket(scheduler=self, index=self._submitted,
                   expression=expression, env=resolved, out=out,
                   out_name=out_name, submitted_ns=now_ns)
        self._submitted += 1
        self.pending.append(t)
        return t

    def oldest_pending_ns(self) -> Optional[float]:
        """Earliest ``submitted_ns`` among queued tickets (None when the
        queue is empty) - the deadline signal a batching window checks."""
        return min((t.submitted_ns for t in self.pending), default=None)

    def cancel(self, ticket: Ticket) -> None:
        """Drop a queued ticket and release its operand holds. Queries
        already submitted that consume this ticket will fail at drain."""
        if ticket.state != QUEUED or ticket not in self.pending:
            raise AmbitError(f"cannot cancel {ticket!r}")
        self.pending.remove(ticket)
        self._release_ticket_holds(ticket)
        ticket.state = CANCELLED

    def _release_ticket_holds(self, t: Ticket) -> None:
        for nm in sorted(t.env):
            v = t.env[nm]
            if isinstance(v, Ticket):
                if v.state == DONE:     # post-execution result hold
                    self.store.release(v.result)
            else:
                self.store.release(v)
        if t.out is not None:
            self.store.release(t.out)

    # -- footprints ----------------------------------------------------------

    def _footprint(self, t: Ticket,
                   cache: Dict[int, frozenset]) -> frozenset:
        """(device, bank) resources ticket ``t`` will touch. A dependency
        ticket contributes its own footprint (its result is co-located
        with its operands by the planner's destination policy)."""
        if id(t) in cache:
            fp = cache[id(t)]
            if fp is None:      # re-entered while still computing it
                raise AmbitError(
                    f"ticket dependency cycle involving #{t.index} - "
                    "the ticket DAG is corrupted (submit can only "
                    "reference earlier tickets)")
            return fp
        cache[id(t)] = None     # in-progress marker for cycle detection
        res: set = set()
        for nm in sorted(t.env):
            v = t.env[nm]
            if isinstance(v, Ticket):
                res |= self._footprint(v, cache)
            else:
                res |= self.planner.footprint({nm: v})
        if t.out is not None:
            res |= self.planner.footprint({"out": t.out})
        fp = frozenset(res)
        cache[id(t)] = fp
        return fp

    # -- epoch formation ------------------------------------------------------

    def _form_epochs(self, tickets: List[Ticket]) -> List[EpochReport]:
        """Greedy first-fit in submit order (the deterministic tiebreak):
        each ticket lands in the earliest epoch that (a) is after every
        epoch its dependencies and handle conflicts require, (b) has no
        (device, bank) resource overlap with tickets already in it, and
        (c) - when the planner defines a ``stack_key`` (accelerator
        backends dispatch each epoch as ONE stacked kernel) - matches the
        epoch's key, so every epoch is shape-compatible to stack."""
        cache: Dict[int, frozenset] = {}
        epochs: List[EpochReport] = []
        epoch_resources: List[set] = []
        epoch_keys: List[object] = []
        keyer = getattr(self.planner, "stack_key", None)
        this_drain = {id(t): t for t in tickets}
        assigned: Dict[int, int] = {}       # id(ticket) -> epoch
        last_writer: Dict[int, int] = {}    # id(handle) -> epoch
        last_reader: Dict[int, int] = {}
        for t in tickets:
            fp = self._footprint(t, cache)
            key = keyer(t.expression, t.env) if keyer else None
            floor = 0
            why: List[str] = []     # the binding defer reasons

            def bump(new_floor: int, reason: str) -> None:
                nonlocal floor
                if new_floor > floor:
                    floor = new_floor
                    why.clear()
                    why.append(reason)
                elif new_floor == floor and floor > 0 and reason not in why:
                    why.append(reason)

            for nm in sorted(t.env):
                v = t.env[nm]
                if isinstance(v, Ticket):       # result-after-execute
                    if id(v) not in this_drain:
                        raise AmbitError(
                            f"operand {nm!r} of ticket #{t.index} is a "
                            f"{v.state} ticket not part of this drain")
                    if id(v) not in assigned:   # deps precede consumers
                        raise AmbitError(
                            f"operand {nm!r} of ticket #{t.index} "
                            f"(ticket #{v.index}) is not scheduled "
                            "before its consumer - dependency cycle?")
                    bump(assigned[id(v)] + 1, f"dep:#{v.index}")
                else:                           # read-after-write
                    bump(last_writer.get(id(v), -1) + 1,
                         f"read-after-write:{nm}")
            if t.out is not None:
                # never share an epoch with another writer of the same
                # destination, nor with anyone still reading its old value
                bump(last_writer.get(id(t.out), -1) + 1, "write-conflict")
                bump(last_reader.get(id(t.out), -1) + 1, "write-conflict")
            e = floor
            while e < len(epochs) and ((epoch_resources[e] & fp)
                                       or epoch_keys[e] != key):
                why.append("bank-conflict" if (epoch_resources[e] & fp)
                           else "stack-shape")
                e += 1
            t.deferred = why
            if e == len(epochs):
                epochs.append(EpochReport())
                epoch_resources.append(set())
                epoch_keys.append(key)
            epochs[e].tickets.append(t.index)
            epoch_resources[e] |= fp
            assigned[id(t)] = e
            t.epoch = e
            for nm in sorted(t.env):
                v = t.env[nm]
                if isinstance(v, Ticket):
                    # result handles are born inside this drain, so no
                    # pre-existing out= can alias them: the dep's
                    # epoch+1 floor above is the only ordering needed
                    continue
                last_reader[id(v)] = max(last_reader.get(id(v), -1), e)
            if t.out is not None:
                last_writer[id(t.out)] = e
        for e, rep in enumerate(epochs):
            rep.resources = sorted(epoch_resources[e])
        return epochs

    # -- execution ------------------------------------------------------------

    def drain(self, now_ns: float = 0.0, epoch_cost=None,
              refresh: bool = False,
              optimize: bool = False) -> List[Ticket]:
        """Execute every queued query and return the tickets in submit
        order. Execution order IS submit order - epochs only change how
        time is accounted - so energy/AAP ledgers are identical to serial
        evaluation and results are bit-identical.

        ``now_ns`` is the simulated clock the drain starts at; epochs are
        laid end to end from it and every ticket gets ``started_ns`` /
        ``finished_ns`` from its epoch's interval. The interval length is
        the measured epoch ns; ``epoch_cost(erep, tickets) -> ns``
        overrides it for backends whose DRAM-model ns is zero (the
        accelerator stores), WITHOUT touching the conservation-exact
        ``stats`` ledger - the timeline is an overlay, never a
        re-measurement.

        ``refresh=True`` makes the timeline refresh-aware: each epoch
        pauses through the [k*tREFI, k*tREFI + tRFC) refresh windows it
        crosses (timing.refresh_schedule), so wall clock stretches by the
        stall while the measured epoch ns - and with it every
        conservation invariant - is untouched. The absorbed stall lands
        in ``EpochReport.refresh_ns`` / ``DrainReport.refresh_stall_ns``.
        No-op on accelerator stores (no DRAM timing model).

        ``optimize=True`` runs the cost-based query optimizer
        (``pim.optimizer``) between the queue and epoch formation:
        cross-ticket CSE materializes shared subtrees once into
        synthetic scratch tickets, placement-aware gating keeps sharing
        off when moving the shared chunks would cost more than
        recomputing, and repeated read-only queries are served from the
        result cache without executing. Results stay bit-identical to
        ``optimize=False`` and to serial eval (the differential suites
        prove it); the rewritten program never charges more device ops
        than the submitted one. The returned list is always the
        *submitted* tickets in submit order - synthetic scratch tickets
        are internal and their results are freed before drain returns.
        (Distinct from ``AmbitRuntime(optimize=True)``, which toggles
        the single-program AAP peephole inside the planner.)"""
        submitted, self.pending = self.pending, []
        if not submitted:
            return []
        if optimize:
            tickets = self.optimizer.rewrite(submitted, now_ns=now_ns)
        else:
            tickets = submitted
        if not tickets:                 # everything served from cache
            report = DrainReport(start_ns=now_ns, end_ns=now_ns,
                                 opt=self.optimizer.last_report)
            self.last_drain = report
            self.drains += 1
            m = self.store.metrics
            m.counter("sched_drains").inc(1)
            m.counter("sched_queries").inc(len(submitted))
            self.optimizer.commit(submitted)
            if self.store.tracer.enabled:
                self._trace_cache_hits(submitted)
            return submitted
        consumers: Dict[int, int] = {}      # id(dep ticket) -> # readers
        for t in tickets:
            for v in t.env.values():
                if isinstance(v, Ticket):
                    consumers[id(v)] = consumers.get(id(v), 0) + 1
        current: Optional[Ticket] = None
        try:
            epochs = self._form_epochs(tickets)
            if hasattr(self.planner, "execute_epoch"):
                # Accelerator backends: each epoch is ONE fused stacked
                # dispatch. Epoch order respects every hazard (deps,
                # out= conflicts), so results match serial execution.
                by_idx = {t.index: t for t in tickets}
                for erep in epochs:
                    group = [by_idx[ti] for ti in erep.tickets]
                    current = group[0]
                    self._execute_epoch(group, consumers)
            else:
                for t in tickets:
                    current = t
                    try:
                        self._execute(t)
                    except FaultError as e:
                        # recovery lost: this ticket fails, the drain
                        # (and every independent ticket) keeps going
                        self._fail_ticket(t, e)
                        continue
                    # keep results alive for queued consumers
                    for _ in range(consumers.get(id(t), 0)):
                        self.store.hold(t.result)
        except Exception:
            # release every hold the dropped tickets still own (a failed
            # epoch formation drops them all) so no handle leaks a hold
            for u in tickets:
                if u.state == QUEUED:
                    u.state = FAILED if u is current else CANCELLED
                    self._release_ticket_holds(u)
            self._reap_scratch(tickets)     # no scratch handle outlives
            raise                           # the drain, even on failure
        # accounting: epoch ns = max over resources of summed per-resource
        # ns, plus the epoch's serialized channel transfers
        report = DrainReport(
            start_ns=now_ns,
            opt=self.optimizer.last_report if optimize else None)
        by_index = {t.index: t for t in tickets}
        total = OpStats()
        clock = now_ns
        for erep in epochs:
            per_res: Dict[Resource, float] = {}
            for ti in erep.tickets:
                t = by_index[ti]
                for r in sorted(t.resource_ns):
                    per_res[r] = per_res.get(r, 0.0) + t.resource_ns[r]
                erep.channel_ns += t.channel_ns
            erep.ns = max(per_res.values(), default=0.0) + erep.channel_ns
            dur = erep.ns if epoch_cost is None else float(
                epoch_cost(erep, [by_index[ti] for ti in erep.tickets]))
            # Retry backoff is waiting, not work: it stretches the
            # epoch's wall-clock interval (the latency-tail signal the
            # fault benchmarks measure) but never the measured epoch ns
            # or any conservation-exact ledger.
            dur += sum(by_index[ti].backoff_ns for ti in erep.tickets)
            erep.start_ns = clock
            if refresh and self._timing is not None and dur > 0.0:
                # Pausable epoch work threaded around refresh windows:
                # the epoch interval [start, end) absorbs the stall.
                _, end = refresh_schedule(clock, dur, self._timing)
                erep.end_ns = end
                erep.refresh_ns = (end - clock) - dur
                report.refresh_stall_ns += erep.refresh_ns
            else:
                erep.end_ns = clock + dur
            for ti in erep.tickets:
                by_index[ti].started_ns = erep.start_ns
                by_index[ti].finished_ns = erep.end_ns
            clock = erep.end_ns
            report.epochs.append(erep)
            total.ns += erep.ns
            total.channel_ns += erep.channel_ns
        report.end_ns = clock
        for t in tickets:
            total.energy_nj += t.stats.energy_nj
            total.aap_count += t.stats.aap_count
            total.bytes_touched += t.stats.bytes_touched
            total.channel_bytes += t.stats.channel_bytes
            total.refresh_stolen_ns += t.stats.refresh_stolen_ns
            report.serial_ns += t.stats.ns
            report.busy_ns += sum(t.resource_ns.values())
        report.stats = total
        self.last_drain = report
        self.drains += 1
        if optimize:
            self._reap_scratch(tickets)
            self.optimizer.commit(submitted)
        m = self.store.metrics
        m.counter("sched_drains").inc(1)
        m.counter("sched_epochs").inc(len(epochs))
        m.counter("sched_queries").inc(len(submitted))
        if refresh:
            m.counter("sched_refresh_stall_ns").inc(report.refresh_stall_ns)
        for t in tickets:
            for r in t.deferred:
                # label by reason class, not instance ("dep:#7" -> "dep")
                m.counter("sched_deferrals").inc(1, reason=r.split(":")[0])
        if self.store.tracer.enabled:
            self._trace_drain(report, by_index)
            if optimize:
                self._trace_cache_hits(submitted)
        return submitted

    def _reap_scratch(self, tickets: List[Ticket]) -> None:
        """Free the results of synthetic scratch tickets: every consumer
        has executed (or was cancelled) by now and released its hold, so
        no optimizer-introduced handle outlives the drain. Leak-checked
        by allocator occupancy in the test suite."""
        for t in tickets:
            if not t.synthetic or t.state != DONE or t.result is None:
                continue
            if not getattr(t.result, "freed", False):
                self.store.free(t.result)

    def _trace_cache_hits(self, submitted: List[Ticket]) -> None:
        """Async ticket spans for cache-served queries (they skip
        ``_trace_drain``'s by-index loop: they never entered an
        epoch)."""
        tr = self.store.tracer
        for t in submitted:
            if not t.cache_hit:
                continue
            tr.async_begin(("scheduler", "tickets"), f"q#{t.index}",
                           "ticket", t.index, t.submitted_ns,
                           args={"cache_hit": True})
            tr.async_end(("scheduler", "tickets"), f"q#{t.index}",
                         "ticket", t.index, t.finished_ns)

    def _trace_drain(self, report: DrainReport,
                     by_index: Dict[int, Ticket]) -> None:
        """Lay the drain on the trace: one span per epoch on the
        scheduler track (span durations tile [start_ns, end_ns) exactly -
        the sum-reconciliation contract tests/CI check), per-(device,
        bank) occupancy spans stacked in ticket order after the epoch's
        serialized channel time, a channel span when transfers happened,
        and one async span per ticket from submit to finish (defer
        reasons ride in its args)."""
        tr = self.store.tracer
        for k, erep in enumerate(report.epochs):
            eargs = {"tickets": list(erep.tickets),
                     "measured_ns": erep.ns,
                     "channel_ns": erep.channel_ns}
            if erep.refresh_ns:
                eargs["refresh_ns"] = erep.refresh_ns
            tr.span(("scheduler",), f"epoch{k}", "epoch", erep.start_ns,
                    erep.end_ns - erep.start_ns, args=eargs)
            if erep.refresh_ns:
                # Stall overlay: the refresh time this epoch absorbed,
                # summarized as one span on its own scheduler sub-track.
                tr.span(("scheduler", "refresh"), f"epoch{k}", "refresh",
                        erep.start_ns, erep.refresh_ns,
                        args={"epoch": k})
            if erep.channel_ns:
                tr.span(("channel",), f"epoch{k}", "channel",
                        erep.start_ns, erep.channel_ns)
            offsets: Dict[Resource, float] = {}
            for ti in erep.tickets:
                t = by_index[ti]
                for r in sorted(t.resource_ns):
                    d, b = r
                    off = offsets.get(r, 0.0)
                    tr.span((f"device{d}", f"bank{b}"), f"q#{t.index}",
                            "bank",
                            erep.start_ns + erep.channel_ns + off,
                            t.resource_ns[r],
                            args={"ticket": t.index, "epoch": k})
                    offsets[r] = off + t.resource_ns[r]
        for ti in sorted(by_index):
            t = by_index[ti]
            tr.async_begin(("scheduler", "tickets"), f"q#{t.index}",
                           "ticket", t.index, t.submitted_ns,
                           args={"epoch": t.epoch,
                                 "deferred": list(t.deferred),
                                 "started_ns": t.started_ns})
            tr.async_end(("scheduler", "tickets"), f"q#{t.index}",
                         "ticket", t.index, t.finished_ns)

    def _execute(self, t: Ticket) -> None:
        """Run one query: through the reliability layer when fault
        injection is wired (bounded retry / quarantine / TMR scrub),
        plainly otherwise. Tickets depending on a failed/cancelled
        ticket raise ``dep_failed`` here - their operand never
        materialized - and cancel instead of crashing the drain."""
        for nm in sorted(t.env):
            v = t.env[nm]
            if isinstance(v, Ticket) and v.state != DONE:
                raise FaultError(
                    f"operand {nm!r} of ticket #{t.index} is ticket "
                    f"#{v.index}, which {v.state}", kind="dep_failed")
        if self.reliability is not None:
            self.reliability.execute_ticket(self, t)
        else:
            self._execute_plain(t)

    def _fail_ticket(self, t: Ticket, e: FaultError) -> None:
        """Surface an unrecoverable fault as a FAILED (or, for a missing
        dependency, CANCELLED) ticket: error recorded, holds released,
        labeled metric + trace event emitted. The costs of its failed
        attempts were already committed to the ticket's ledgers."""
        t.state = CANCELLED if e.kind == "dep_failed" else FAILED
        t.error = str(e)
        self._release_ticket_holds(t)
        m = self.store.metrics
        m.counter("ticket_failures").inc(1, reason=e.kind)
        tr = self.store.tracer
        if tr.enabled:
            tr.instant(("scheduler", "failures"), "ticket_failed",
                       "fault", args={"ticket": t.index,
                                      "reason": e.kind})

    def _execute_plain(self, t: Ticket) -> None:
        """Run one query through the planner (fault-ins charged to its
        ticket), release its operand holds, and publish the result."""
        store = self.store
        env = {nm: (v.result if isinstance(v, Ticket) else v)
               for nm, v in t.env.items()}
        operands = list(env.values())
        up0, rd0 = store.bytes_to_device, store.bytes_from_device
        for v in operands:
            store.ensure_resident(v, protect=operands)
        res = self.planner.execute(t.expression, env, out_name=t.out_name)
        rep = self.planner.last_report
        st = OpStats()
        st += rep.stats
        st.bytes_touched += (store.bytes_to_device - up0) + \
            (store.bytes_from_device - rd0)
        t.stats = st
        t.resource_ns = {
            (k if isinstance(k, tuple) else (0, k)): bank_stats.ns
            for k, bank_stats in rep.per_bank.items()}
        t.channel_ns = getattr(rep, "transfer_ns", 0.0)
        t.result = self.store.rebind(t.out, res) if t.out is not None \
            else res
        self._release_ticket_holds(t)
        t.state = DONE

    def _execute_epoch(self, group: List[Ticket],
                       consumers: Dict[int, int]) -> None:
        """Dispatch one epoch through the planner's batched entry point
        (one fused stacked kernel launch). Fault-ins of each ticket's
        spilled operands are measured per ticket before the dispatch."""
        store = self.store
        jobs = []
        epoch_operands: List[object] = []   # every operand must survive
        for t in group:                     # until the stacked dispatch
            env = {nm: (v.result if isinstance(v, Ticket) else v)
                   for nm, v in t.env.items()}
            epoch_operands.extend(env.values())
            up0, rd0 = store.bytes_to_device, store.bytes_from_device
            for v in env.values():
                store.ensure_resident(v, protect=epoch_operands)
            t.stats = OpStats(
                bytes_touched=(store.bytes_to_device - up0)
                + (store.bytes_from_device - rd0))
            jobs.append((t.expression, env, t.out_name, t.out))
        results = self.planner.execute_epoch(jobs)
        for t, res in zip(group, results):
            t.result = self.store.rebind(t.out, res) if t.out is not None \
                else res
            self._release_ticket_holds(t)
            t.state = DONE
            for _ in range(consumers.get(id(t), 0)):
                self.store.hold(t.result)
