"""Cost-based multi-query optimizer over ticket DAGs.

``AsyncScheduler.drain`` packs queries as submitted but never *rewrites*
them. Database-shaped traffic (thousands of tenants issuing overlapping
predicates - see "Understanding Bulk-Bitwise Processing In-Memory
Through Database Analytics") repeats the same sub-ANDs across queries,
so naive per-query execution pays for every shared subtree once per
ticket. This pass runs between submit and epoch formation and applies
three rewrites, all provably bit-exact (the differential suites in
tests/test_optimizer.py and tests/test_scheduler.py execute every mix
optimized, unoptimized and through the numpy oracle):

  1. **Cross-ticket CSE.** Every ticket expression is canonicalized
     (commutative-operand sorting, De Morgan/double-NOT normalization,
     xor polarity extraction, maj self-duality) and each subtree is
     value-numbered by ``(canonical structure, operand handle identity,
     handle generation)``. A subtree worth >= ``min_subtree_ops`` device
     ops that appears under >= 2 tickets of the drain is materialized
     ONCE into a synthetic scratch ticket; the consuming tickets
     reference it as a DAG dependency (the scheduler's existing
     ticket-operand machinery orders, holds and releases it, and the
     scratch result is freed at the end of the drain). Consumers keep
     their ORIGINAL expression shape minus the shared subtree -
     canonicalization is used for *keying only* - so a rewritten
     program never costs more device ops than the submitted one.

  2. **Placement-aware rewriting.** On a cluster, sharing is only
     profitable when the scratch result's chunks live where the
     consumer computes; otherwise every chunk crosses the channel. Per
     consumer the pass compares the modeled move cost
     (``ChannelModel.device_to_device_ns`` over the chunks whose homes
     differ) against the modeled recompute cost (subtree ops x chunks x
     per-op ns) and leaves the consumer recomputing inline - "move the
     compute to the data" - when moving loses.

  3. **Result caching.** Read-only queries (no ``out=``, handle-only
     operands) are keyed by their full canonical value number and their
     results are cached across drains; a repeat query is served without
     executing anything. Entries are invalidated by dirty-tracking
     writes: ``out=`` rebinds, ``free`` and spill->fault-in all bump
     the store's per-handle *generation* (``LruSpillBase.generation``)
     and notify the cache, and intra-drain writes are tracked with a
     virtual-generation overlay so a write queued between two
     structurally equal reads forces the second read to execute.

Everything the pass does is observable: ``opt_cse_hits``,
``opt_cache_hits``/``opt_cache_misses``, ``opt_cse_materialized``,
``opt_rewrite_ns_saved{device}`` and ``opt_placement_skips`` land in
the store's MetricsRegistry (reconciled against ``OptReport`` and the
conservation ledgers by tests/CI), rewrite decisions are traced as
``opt`` events (tools/trace_report.py summarizes them), and
``Ticket.rewritten_from`` records the submitted expression of every
rewritten ticket.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from ..core import expr as E
from ..core.engine import OpStats
from ..core.expr import Expr, ONE, ZERO
from ..core.simulator import AmbitError

# -- expression canonicalization ---------------------------------------------
#
# The canonical form is the CSE/cache *key*, chosen so boolean-equal
# shapes collide: commutative operands sort by a structural key, NOT is
# pushed through AND/OR (De Morgan) so it only ever tops var/xor/maj
# nodes, xor operand polarity is extracted to one outer NOT, and an
# all-negated maj hoists its negation (maj is self-dual). The form is
# idempotent and PYTHONHASHSEED-independent (structural keys only, no
# hash-order iteration anywhere) - tests/test_optimizer.py
# property-tests both.

_SKEY: Dict[int, tuple] = {}
_NOPS: Dict[int, int] = {}


def struct_key(e: Expr) -> tuple:
    """Deterministic structural sort key (Expr nodes are interned and
    immortal, so a global id-keyed memo is safe)."""
    k = _SKEY.get(id(e))
    if k is None:
        k = (e.op, e.name) + tuple(struct_key(a) for a in e.args)
        _SKEY[id(e)] = k
    return k


def _c_bin(op: str, a: Expr, b: Expr) -> Expr:
    """Canonical commutative binary node: operands sorted, built through
    the overloaded operators so interning + algebraic folds apply. (A
    sort tie means structurally identical operands, which intern to the
    same object and fold away - ordering is always strict.)"""
    if struct_key(b) < struct_key(a):
        a, b = b, a
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    return a ^ b


def _c_not(x: Expr) -> Expr:
    """Canonical negation of an already-canonical node."""
    if x is ZERO:
        return ONE
    if x is ONE:
        return ZERO
    if x.op == "not":
        return x.args[0]
    if x.op == "and":    # De Morgan: push the NOT below AND/OR
        return _c_bin("or", _c_not(x.args[0]), _c_not(x.args[1]))
    if x.op == "or":
        return _c_bin("and", _c_not(x.args[0]), _c_not(x.args[1]))
    return Expr("not", (x,))    # var/xor/maj keep the NOT on top


def _c_maj(xs: List[Expr]) -> Expr:
    a, b, c = sorted(xs, key=struct_key)
    if a is b:
        return a                # maj(x, x, y) = x
    if b is c:
        return b
    if ZERO in (a, b, c):       # maj(0, x, y) = x & y
        o = [x for x in (a, b, c) if x is not ZERO]
        return _c_bin("and", o[0], o[1])
    if ONE in (a, b, c):        # maj(1, x, y) = x | y
        o = [x for x in (a, b, c) if x is not ONE]
        return _c_bin("or", o[0], o[1])
    return Expr("maj", (a, b, c))


def canonicalize(e: Expr, _memo: Optional[Dict[int, Expr]] = None) -> Expr:
    """Semantics-preserving canonical form of ``e`` (see module doc).
    Expressions boolean-equal under {commutativity, De Morgan,
    double-NOT, xor polarity, maj self-duality} map to the SAME
    interned node, so hash-cons identity is the equality test."""
    if _memo is None:
        _memo = {}
    r = _memo.get(id(e))
    if r is not None:
        return r
    if e.op in ("var", "lit"):
        c = e
    elif e.op == "not":
        c = _c_not(canonicalize(e.args[0], _memo))
    elif e.op in ("and", "or"):
        c = _c_bin(e.op, canonicalize(e.args[0], _memo),
                   canonicalize(e.args[1], _memo))
    elif e.op == "xor":
        a = canonicalize(e.args[0], _memo)
        b = canonicalize(e.args[1], _memo)
        par = 0
        if a.op == "not":
            a, par = a.args[0], par ^ 1
        if b.op == "not":
            b, par = b.args[0], par ^ 1
        if a is ONE:            # lits only survive in hand-built nodes
            a, par = ZERO, par ^ 1
        if b is ONE:
            b, par = ZERO, par ^ 1
        base = _c_bin("xor", a, b)
        c = _c_not(base) if par else base
    elif e.op == "maj":
        xs = [canonicalize(x, _memo) for x in e.args]
        if all(x.op == "not" for x in xs):
            c = _c_not(_c_maj([x.args[0] for x in xs]))
        else:
            c = _c_maj(xs)
    else:
        raise AmbitError(f"cannot canonicalize unknown op {e.op!r}")
    _memo[id(e)] = c
    return c


def n_ops(e: Expr) -> int:
    """Device ops (non-leaf nodes) in the DAG under ``e`` - the unit the
    CSE threshold and the recompute cost model are stated in."""
    n = _NOPS.get(id(e))
    if n is None:
        n = sum(1 for m in E.topo_order(e) if m.op not in ("var", "lit"))
        _NOPS[id(e)] = n
    return n


def _value_key(c: Expr, leaf, memo: Dict[int, tuple]) -> tuple:
    """Value number of canonical node ``c``: its structure with every
    var replaced by ``leaf(name)`` - operand handle identity plus
    generation - and commutative children re-sorted at the *value*
    level, so the same computation over the same handles keys equal
    regardless of operand naming."""
    k = memo.get(id(c))
    if k is None:
        if c.op == "var":
            k = ("leaf", leaf(c.name))
        elif c.op == "lit":
            k = ("lit", c.name)
        else:
            ks = [_value_key(a, leaf, memo) for a in c.args]
            if c.op in ("and", "or", "xor", "maj"):
                ks.sort()
            k = (c.op, *ks)
        memo[id(c)] = k
    return k


# -- result cache -------------------------------------------------------------


@dataclasses.dataclass
class _CacheEntry:
    key: tuple
    handles: Tuple[object, ...]     # strong refs: operand ids stay valid
    gens: Tuple[int, ...]
    result: object                  # held in the store while cached


class ResultCache:
    """Canonical-value-number -> result handle, LRU-bounded.

    The cache *holds* each cached result (the LRU spiller treats it
    like a queued operand: spilled only under real pressure, faulted
    back in on use) and keeps strong references to the operand handles
    so their ids cannot be reused while an entry depends on them.
    Invalidation is push-based: the store's ``_invalidate`` fan-out
    (out= rebind, free, spill->fault-in) drops every entry whose
    operands or result the mutated handle backs."""

    def __init__(self, store, capacity: int = 64):
        self.store = store
        self.capacity = max(1, capacity)
        self._entries: "OrderedDict[tuple, _CacheEntry]" = OrderedDict()
        self._by_handle: Dict[int, set] = {}    # id(handle) -> {keys}
        store._invalidation_hooks.append(self._on_invalidate)

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: tuple) -> Optional[_CacheEntry]:
        e = self._entries.get(key)
        if e is None:
            return None
        if getattr(e.result, "freed", False):   # defensive: drop stale
            self._drop(key)
            return None
        self._entries.move_to_end(key)
        return e

    def insert(self, key: tuple, handles: Tuple[object, ...],
               gens: Tuple[int, ...], result) -> None:
        if key in self._entries:
            return
        while len(self._entries) >= self.capacity:
            self._drop(next(iter(self._entries)))
        self.store.hold(result)
        entry = _CacheEntry(key=key, handles=tuple(handles),
                            gens=tuple(gens), result=result)
        self._entries[key] = entry
        for h in (*entry.handles, entry.result):
            self._by_handle.setdefault(id(h), set()).add(key)
        self.store.metrics.counter("opt_cache_inserts").inc(1)

    def _drop(self, key: tuple) -> None:
        entry = self._entries.pop(key, None)
        if entry is None:
            return
        for h in (*entry.handles, entry.result):
            keys = self._by_handle.get(id(h))
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del self._by_handle[id(h)]
        self.store.release(entry.result)

    def _on_invalidate(self, rbv) -> None:
        keys = self._by_handle.get(id(rbv))
        if keys:
            self.store.metrics.counter("opt_cache_invalidations").inc(
                len(keys))
            for key in list(keys):
                self._drop(key)

    def flush(self) -> None:
        for key in list(self._entries):
            self._drop(key)


# -- the optimizer pass -------------------------------------------------------


@dataclasses.dataclass
class OptReport:
    """What one optimized drain rewrote (mirrored into the metrics
    registry: the ``opt_*`` counters advance by exactly these
    integers)."""

    cse_hits: int = 0           # occurrence replacements beyond the
    cse_materialized: int = 0   # materializing one per shared subtree
    cache_hits: int = 0
    cache_misses: int = 0
    placement_skips: int = 0    # consumers left recomputing (move cost)
    ns_saved_est: float = 0.0   # cost-model estimate of rewrite savings


_CSE_PREFIX = "__cse"

# Modeled per-op per-chunk cost for the share-vs-recompute decision: a
# bbop is ~4 AAPs at the split-decoder latency (timing.py). Only the
# ratio against ChannelModel link costs matters here; the measured
# ledgers stay the ground truth the tests reconcile.
_OP_NS_EST = 4 * 49.0


@dataclasses.dataclass
class _Group:
    """One shared-subtree equivalence class: its value number, where it
    occurs, who shares it, and the scratch ticket that materializes it
    (created lazily at its first rewritten occurrence)."""

    gid: int
    key: tuple
    occs: List[tuple] = dataclasses.field(default_factory=list)
    ticket_ids: set = dataclasses.field(default_factory=set)
    # (ticket position, id(node)) pairs that reference the scratch
    participants: set = dataclasses.field(default_factory=set)
    gains: Dict[tuple, float] = dataclasses.field(default_factory=dict)
    ticket: object = None           # the synthetic scratch Ticket
    replaced: int = 0               # replace events in the final rewrite

    @property
    def var_name(self) -> str:
        return f"{_CSE_PREFIX}{self.gid}"

    def first_occ(self) -> Optional[tuple]:
        """First (info, node) occurrence still participating - the one
        whose original subtree the scratch ticket computes."""
        for info, node in self.occs:
            if (info.pos, id(node)) in self.participants:
                return (info, node)
        return None

    def n_tickets(self) -> int:
        return len({pos for pos, _ in self.participants})


class _TicketInfo:
    """Per-ticket rewrite state for one optimized drain."""

    __slots__ = ("ticket", "pos", "leaf", "keys", "rw_memo", "used_cse",
                 "scratch_before")

    def __init__(self, ticket, pos, leaf):
        self.ticket = ticket
        self.pos = pos
        self.leaf = leaf
        self.keys: Dict[int, tuple] = {}    # id(original node) -> vkey
        self.rw_memo: Dict[int, Expr] = {}
        self.used_cse: Dict[int, object] = {}   # gid -> scratch ticket
        self.scratch_before: List[object] = []  # scratch to insert


class QueryOptimizer:
    """The drain-time rewrite pass. One instance per AsyncScheduler
    (created lazily on the first ``drain(optimize=True)``); the result
    cache persists across drains."""

    def __init__(self, scheduler, min_subtree_ops: int = 1,
                 cache_capacity: int = 64):
        self.sched = scheduler
        self.store = scheduler.store
        self.planner = scheduler.planner
        self.min_subtree_ops = min_subtree_ops
        self.cache = ResultCache(self.store, capacity=cache_capacity)
        self.last_report: Optional[OptReport] = None
        self._insert_candidates: List[tuple] = []
        self._groups: Dict[tuple, _Group] = {}
        self._selected: Dict[tuple, _Group] = {}
        self._scratch_sink: Optional[List[object]] = None

    # -- placement cost model ----------------------------------------------

    def _chunk_devices(self, handle) -> Optional[List[int]]:
        """Device index per chunk, or None when unknown (spilled /
        partially spilled, or a store without per-chunk placement)."""
        if getattr(handle, "spilled", False):
            return None
        slots = getattr(handle, "slots", None)
        if not slots:
            return None
        devs = []
        for s in slots:
            if s is None:                   # partially spilled chunk
                return None
            # cluster slots are (device, (bank, sub, row)); single-
            # device slots are (bank, sub, row) -> device 0
            devs.append(s[0] if len(s) == 2 and isinstance(s[1], tuple)
                        else 0)
        return devs

    def _first_handle(self, t, node: Optional[Expr] = None):
        """First operand handle (sorted name order) of ticket ``t``,
        restricted to the vars under ``node`` when given."""
        from .scheduler import Ticket
        names = None
        if node is not None:
            names = {n.name for n in E.topo_order(node) if n.op == "var"}
        for nm in sorted(t.env):
            if names is not None and nm not in names:
                continue
            if not isinstance(t.env[nm], Ticket):
                return t.env[nm]
        return None

    def _share_gain_ns(self, g: _Group, info: "_TicketInfo",
                       node: Expr) -> float:
        """Modeled ns saved if this consumer references the shared
        scratch instead of recomputing ``node`` inline. Positive =
        share; negative = the scratch chunks live on other devices and
        moving them costs more than recomputing ("move the compute to
        the data")."""
        recompute_per_chunk = float(n_ops(node)) * _OP_NS_EST
        channel = getattr(self.store, "channel", None)
        h = self._first_handle(info.ticket, node)
        n_chunks = getattr(h, "n_slots", 1) if h is not None else 1
        if channel is None:
            # single device or accelerator store: sharing never moves
            # data, the saved ops are the whole story
            return recompute_per_chunk * float(n_chunks)
        src_info, src_node = g.occs[0]
        src = self._first_handle(src_info.ticket, src_node)
        src_devs = self._chunk_devices(src) if src is not None else None
        dst = self._first_handle(info.ticket)
        dst_devs = self._chunk_devices(dst) if dst is not None else None
        if src_devs is None or dst_devs is None or \
                len(src_devs) != len(dst_devs):
            # placement unknown (spilled operand faults in wherever the
            # allocator chooses): assume co-located
            return recompute_per_chunk * float(n_chunks)
        row_bytes = getattr(self.store, "row_bytes", 0)
        move = sum(channel.device_to_device_ns(s, d, row_bytes)
                   for s, d in zip(src_devs, dst_devs) if s != d)
        return recompute_per_chunk * float(len(dst_devs)) - move

    # -- the pass ----------------------------------------------------------

    def rewrite(self, tickets: List[object], now_ns: float = 0.0
                ) -> List[object]:
        """Rewrite one drain's ticket list. Returns the execution list:
        cache-served tickets removed (already DONE), synthetic scratch
        tickets inserted before their first consumer. The scheduler
        calls ``commit`` after executing it (cache inserts) and frees
        the scratch results."""
        from .scheduler import Ticket
        rep = OptReport()
        self.last_report = rep
        self._insert_candidates = []
        m = self.store.metrics
        tr = self.store.tracer
        vgen: Dict[int, int] = {}       # intra-queue write overlay
        infos: List[_TicketInfo] = []
        groups: "OrderedDict[tuple, _Group]" = OrderedDict()

        # -- scan: canonical value numbers, cache serving ----------------
        for t in tickets:
            # consumers of a ticket this drain already served from the
            # cache read the cached handle directly
            for nm in sorted(t.env):
                v = t.env[nm]
                if isinstance(v, Ticket) and v.cache_hit:
                    self.store.hold(v.result)
                    t.env[nm] = v.result

            def leaf(name, _t=t):
                v = _t.env[name]
                if isinstance(v, Ticket):
                    return ("t", v.index)
                return ("h", id(v),
                        self.store.generation(v) + vgen.get(id(v), 0))

            info = _TicketInfo(t, len(infos), leaf)
            cmemo: Dict[int, Expr] = {}
            vmemo: Dict[int, tuple] = {}
            root_c = canonicalize(t.expression, cmemo)
            root_key = _value_key(root_c, leaf, vmemo)
            cacheable = t.out is None and not any(
                isinstance(v, Ticket) for v in t.env.values())
            if cacheable:
                hit = self.cache.lookup(root_key)
                if hit is not None:
                    self._serve_hit(t, hit, now_ns)
                    rep.cache_hits += 1
                    m.counter("opt_cache_hits").inc(1)
                    if tr.enabled:
                        tr.instant(("scheduler", "optimizer"),
                                   f"cache_hit#{t.index}", "opt",
                                   args={"ticket": t.index})
                    continue
                rep.cache_misses += 1
                m.counter("opt_cache_misses").inc(1)
                handles = tuple(t.env[nm] for nm in sorted(t.env))
                gens = tuple(self.store.generation(h) +
                             vgen.get(id(h), 0) for h in handles)
                self._insert_candidates.append(
                    (t, root_key, handles, gens))
            # register shareable subtrees (proper subtrees only: a root
            # replacement would leave a bare-var program behind)
            for node in E.topo_order(t.expression):
                if node is t.expression or node.op in ("var", "lit"):
                    continue
                if n_ops(node) < self.min_subtree_ops:
                    continue
                key = _value_key(cmemo[id(node)], leaf, vmemo)
                info.keys[id(node)] = key
                g = groups.get(key)
                if g is None:
                    g = _Group(gid=len(groups), key=key)
                    groups[key] = g
                g.occs.append((info, node))
                g.ticket_ids.add(id(t))
            infos.append(info)
            if t.out is not None:
                vgen[id(t.out)] = vgen.get(id(t.out), 0) + 1

        # -- select: shared across >= 2 tickets, placement-gated ---------
        self._groups = groups
        selected: Dict[tuple, _Group] = {}
        for key, g in groups.items():
            if len(g.ticket_ids) < 2:
                continue
            for info, node in g.occs:
                gain = self._share_gain_ns(g, info, node)
                occ = (info.pos, id(node))
                g.gains[occ] = gain
                if gain > 0.0:
                    g.participants.add(occ)
                else:
                    rep.placement_skips += 1
                    m.counter("opt_placement_skips").inc(
                        1, reason="placement")
            if g.n_tickets() >= 2:
                selected[key] = g
            else:
                g.participants.clear()
        self._selected = selected

        # -- degenerate-fold fixpoint: a rewrite that folds a ticket's
        # whole expression to a bare var/lit (e.g. xor of two
        # value-equal subtrees) would leave the planner no program -
        # withdraw that ticket from every group and re-check viability
        while selected:
            demoted = False
            for info in infos:
                if not self._participates(info):
                    continue
                info.rw_memo = {}
                dry = self._rw(info, info.ticket.expression,
                               is_root=True, dry=True)
                if dry.op in ("var", "lit"):
                    for g in selected.values():
                        g.participants = {
                            occ for occ in g.participants
                            if occ[0] != info.pos}
                    demoted = True
            if not demoted:
                break
            selected = {k: g for k, g in selected.items()
                        if g.n_tickets() >= 2}
            for key, g in self._groups.items():
                if key not in selected:
                    g.participants.clear()
            self._selected = selected

        # -- rewrite + scratch materialization ---------------------------
        exec_list: List[object] = []
        for info in infos:
            t = info.ticket
            info.rw_memo = {}
            self._scratch_sink = info.scratch_before
            new_expr = self._rw(info, t.expression, is_root=True,
                                dry=False)
            if new_expr is not t.expression:
                t.rewritten_from = t.expression
                t.expression = new_expr
                self._prune_env(info, new_expr)
                if tr.enabled:
                    tr.instant(("scheduler", "optimizer"),
                               f"rewrite#{t.index}", "opt",
                               args={"ticket": t.index,
                                     "cse_vars": sorted(info.used_cse)})
            exec_list.extend(info.scratch_before)
            exec_list.append(t)
        self._scratch_sink = None
        # A group's scratch computes its subtree once; every replaced
        # reference beyond that first computation is a CSE hit.
        rep.cse_hits = sum(max(0, g.replaced - 1)
                           for g in selected.values()
                           if g.ticket is not None)
        for g in selected.values():
            first = g.first_occ()
            for occ in sorted(g.participants):
                if first is not None and occ == (first[0].pos,
                                                 id(first[1])):
                    continue        # the materializer pays the compute
                gain = max(g.gains.get(occ, 0.0), 0.0)
                rep.ns_saved_est += gain
                h = self._first_handle(infos[occ[0]].ticket)
                devs = self._chunk_devices(h) if h is not None else None
                m.counter("opt_rewrite_ns_saved").inc(
                    gain, device=f"d{devs[0] if devs else 0}")
        m.counter("opt_cse_hits").inc(rep.cse_hits)
        m.counter("opt_cse_materialized").inc(rep.cse_materialized)
        return exec_list

    def _participates(self, info: "_TicketInfo") -> bool:
        return any(occ[0] == info.pos for g in self._selected.values()
                   for occ in g.participants)

    def _rw(self, info: "_TicketInfo", node: Expr, is_root: bool,
            dry: bool) -> Expr:
        """Top-down rewrite: a participating occurrence of a selected
        group becomes a reference to the group's scratch ticket (never
        at the root); everything else is rebuilt bottom-up, letting the
        constructor folds simplify. ``dry`` builds the same expression
        without materializing scratch tickets (the fixpoint probe)."""
        if node.op in ("var", "lit"):
            return node
        if not is_root:
            hit = info.rw_memo.get(id(node))
            if hit is not None:
                return hit
            key = info.keys.get(id(node))
            g = self._selected.get(key) if key is not None else None
            if g is not None and (info.pos, id(node)) in g.participants:
                if not dry:
                    info.used_cse[g.gid] = self._materialize(g)
                    g.replaced += 1
                out = Expr.var(g.var_name)
                info.rw_memo[id(node)] = out
                return out
        new_args = tuple(self._rw(info, a, False, dry)
                         for a in node.args)
        if all(n is o for n, o in zip(new_args, node.args)):
            out = node
        elif node.op == "not":
            out = ~new_args[0]
        elif node.op == "and":
            out = new_args[0] & new_args[1]
        elif node.op == "or":
            out = new_args[0] | new_args[1]
        elif node.op == "xor":
            out = new_args[0] ^ new_args[1]
        elif node.op == "maj":
            out = E.maj(*new_args)
        else:
            raise AmbitError(f"cannot rewrite unknown op {node.op!r}")
        if not is_root:
            info.rw_memo[id(node)] = out
        return out

    def _materialize(self, g: _Group):
        """Build (once) the synthetic scratch ticket computing group
        ``g``'s subtree, recursively materializing nested shared
        subtrees first (they become its dependencies). The scratch is
        queued immediately before its first consumer, so every epoch-
        formation invariant (deps before consumers) holds by
        construction."""
        if g.ticket is not None:
            return g.ticket
        from .scheduler import Ticket
        info0, node0 = g.first_occ()
        sexpr = self._rw(info0, node0, is_root=True, dry=False)
        senv: Dict[str, object] = {}
        for n in E.topo_order(sexpr):
            if n.op != "var" or n.name in senv:
                continue
            if n.name in info0.ticket.env:
                v = info0.ticket.env[n.name]
                senv[n.name] = v
                if not isinstance(v, Ticket):
                    self.store.hold(v)
            else:               # a nested __cse var: scratch dependency
                gid = int(n.name[len(_CSE_PREFIX):])
                senv[n.name] = info0.used_cse[gid]
        sched = self.sched
        st = Ticket(scheduler=sched, index=sched._submitted,
                    expression=sexpr, env=senv, synthetic=True,
                    submitted_ns=info0.ticket.submitted_ns)
        sched._submitted += 1
        g.ticket = st
        self._scratch_sink.append(st)
        self.last_report.cse_materialized += 1
        if self.store.tracer.enabled:
            self.store.tracer.instant(
                ("scheduler", "optimizer"), f"materialize#{st.index}",
                "opt", args={"ticket": st.index, "ops": n_ops(node0),
                             "consumers": g.n_tickets()})
        return st

    def _prune_env(self, info: "_TicketInfo", new_expr: Expr) -> None:
        """Rebuild the consumer's env from the vars its rewritten
        expression actually reads: dropped handle operands release
        their submit-time hold, CSE vars bind their scratch tickets."""
        from .scheduler import Ticket
        t = info.ticket
        used = {n.name for n in E.topo_order(new_expr) if n.op == "var"}
        new_env: Dict[str, object] = {}
        for nm in sorted(used):
            if nm in t.env:
                new_env[nm] = t.env[nm]
            else:
                gid = int(nm[len(_CSE_PREFIX):])
                new_env[nm] = info.used_cse[gid]
        for nm in sorted(set(t.env) - used):
            v = t.env[nm]
            if not isinstance(v, Ticket):
                self.store.release(v)
        t.env = new_env

    def _serve_hit(self, t, entry: _CacheEntry, now_ns: float) -> None:
        """Complete a ticket from the cache without executing anything:
        zero stats, released operand holds, the cached handle as its
        result. The ticket never enters epoch formation."""
        from .scheduler import DONE
        for nm in sorted(t.env):
            self.store.release(t.env[nm])
        t.result = entry.result
        t.cache_hit = True
        t.state = DONE
        t.stats = OpStats()
        t.resource_ns = {}
        t.channel_ns = 0.0
        t.epoch = -1
        t.deferred = []
        t.started_ns = now_ns
        t.finished_ns = now_ns

    def commit(self, executed: List[object]) -> None:
        """Post-drain: insert the results of read-only queries whose
        operand generations are still current. A write later in the
        same drain (or a pressure-driven fault-in) bumped a generation
        past the recorded key, making it unreachable for every future
        lookup - skip those instead of caching dead entries."""
        from .scheduler import DONE
        for t, key, handles, gens in self._insert_candidates:
            if t.state != DONE or t.result is None or t.cache_hit:
                continue
            if getattr(t.result, "freed", False):
                continue
            if any(self.store.generation(h) != gen
                   for h, gen in zip(handles, gens)):
                continue
            self.cache.insert(key, handles, gens, t.result)
        self._insert_candidates = []
