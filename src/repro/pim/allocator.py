"""Row allocation for the PIM runtime (the Section 5.2 driver, grown up).

The seed `AmbitDevice.alloc_rows` was a bump cursor: rows could never be
freed or reused, so any workload with operand churn (the Section 8
database queries allocate intermediates per query) exhausted the device.
`RowAllocator` replaces it with a free-list allocator over
``(bank, subarray, row)`` slots that supports

  * ``free`` / reallocation - freed slots are reused lowest-address-first,
    deterministically;
  * per-subarray occupancy accounting (the planner's placement signal);
  * pluggable placement policies:
      - ``"striped"``   - round-robin banks fastest, then subarrays, then
        rows: corresponding rows of successive allocations land in the
        same subarray (the co-location contract) while the whole vector
        stripes across banks for bank-level parallelism (Fig. 21). This
        reproduces the seed bump-cursor order exactly when nothing has
        been freed, which keeps `AmbitDevice.alloc_rows` back-compatible.
      - ``"colocated"`` - fill one subarray before spilling to the next:
        operands allocated near each other share a subarray, so every
        staging copy is RowClone-FPM instead of PSM (affinity beats
        parallelism when chains of dependent ops dominate).
  * ``near=`` affinity - allocate in the subarrays already holding the
    given slots (the store's migration planner and the query planner use
    this to co-locate results with their operands).

The top ``scratch_rows`` rows of every subarray can be reserved so PSM
staging (which the device model writes into the top of the D-group) can
never clobber allocated data.
"""

from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.simulator import AmbitError

Slot = Tuple[int, int, int]  # (bank, subarray, row)

STRIPED = "striped"
COLOCATED = "colocated"
POLICIES = (STRIPED, COLOCATED)


class RowAllocator:
    """Free-list allocator over the D-group rows of an Ambit device."""

    def __init__(self, banks: int, subarrays: int, data_rows: int,
                 scratch_rows: int = 0, policy: str = STRIPED):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r} (use {POLICIES})")
        if banks < 1 or subarrays < 1:
            raise ValueError("need at least one bank and subarray")
        self.banks = banks
        self.subarrays = subarrays
        self.data_rows = data_rows
        self.scratch_rows = scratch_rows
        self.usable_rows = data_rows - scratch_rows
        if self.usable_rows < 1:
            raise ValueError("scratch reservation leaves no allocatable rows")
        self.policy = policy
        # Per-subarray state: rows [0, _virgin) have been handed out at
        # least once; freed rows below the virgin cursor sit in a min-heap.
        self._virgin: Dict[Tuple[int, int], int] = {}
        self._freed: Dict[Tuple[int, int], List[int]] = {}
        self._occupancy: Dict[Tuple[int, int], int] = {}
        for b in range(banks):
            for s in range(subarrays):
                self._virgin[(b, s)] = 0
                self._freed[(b, s)] = []
                self._occupancy[(b, s)] = 0
        self._live: set = set()
        # Quarantined slots are retired for the life of the allocator:
        # never handed out again, subtracted from capacity, and listed
        # in report() so CI can prove zero leaks (reliability layer).
        self._quarantined: set = set()
        self._q_by_sub: Dict[Tuple[int, int], int] = {}

    @classmethod
    def for_device(cls, device, scratch_rows: int = 0,
                   policy: str = STRIPED) -> "RowAllocator":
        return cls(len(device.banks), len(device.banks[0].subarrays),
                   device.geom.data_rows, scratch_rows, policy)

    # -- introspection -------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.banks * self.subarrays * self.usable_rows \
            - len(self._quarantined)

    @property
    def live(self) -> int:
        return len(self._live)

    @property
    def free_slots(self) -> int:
        return self.capacity - self.live

    @property
    def utilization(self) -> float:
        """Fraction of usable rows currently live (the cluster placement
        signal: packed/affinity policies pick the least-loaded device)."""
        return self.live / self.capacity

    def shortfall(self, n_rows: int) -> int:
        """How many rows short of an ``n_rows`` allocation the device is
        (0 = it fits). The spill loop evicts until this reaches zero
        instead of probing with throwaway failed allocations."""
        return max(0, n_rows - self.free_slots)

    def occupancy(self, bank: int, subarray: int) -> int:
        """Number of live slots in one subarray."""
        return self._occupancy[(bank, subarray)]

    def subarray_free(self, bank: int, subarray: int) -> int:
        return self.usable_rows - self._occupancy[(bank, subarray)] \
            - self._q_by_sub.get((bank, subarray), 0)

    def is_live(self, slot: Slot) -> bool:
        return tuple(slot) in self._live

    @property
    def quarantined(self) -> int:
        return len(self._quarantined)

    @property
    def quarantined_slots(self) -> frozenset:
        return frozenset(self._quarantined)

    def report(self) -> dict:
        """Accounting snapshot: every retired row must appear here (the
        chaos CI job asserts quarantine never leaks slots)."""
        return {
            "capacity": self.capacity,
            "live": self.live,
            "free": self.free_slots,
            "quarantined": len(self._quarantined),
            "quarantined_slots": sorted(self._quarantined),
        }

    # -- allocation ----------------------------------------------------------

    def _purge_quarantined(self, key: Tuple[int, int]) -> None:
        """Drop retired rows from the subarray's free structures: pop
        them off the freed heap and step the virgin cursor over them
        (lazily, so quarantine stays O(1))."""
        if not self._quarantined:
            return
        freed = self._freed[key]
        while freed and (key[0], key[1], freed[0]) in self._quarantined:
            heapq.heappop(freed)
        v = self._virgin[key]
        while v < self.usable_rows \
                and (key[0], key[1], v) in self._quarantined:
            v += 1
        self._virgin[key] = v

    def _lowest_free_row(self, key: Tuple[int, int]) -> Optional[int]:
        self._purge_quarantined(key)
        freed = self._freed[key]
        virgin = self._virgin[key]
        if freed:
            return min(freed[0], virgin) if virgin < self.usable_rows \
                else freed[0]
        return virgin if virgin < self.usable_rows else None

    def _take_row(self, key: Tuple[int, int]) -> int:
        """Pop the lowest free row of a subarray (caller checked non-full)."""
        self._purge_quarantined(key)
        freed = self._freed[key]
        virgin = self._virgin[key]
        if freed and (virgin >= self.usable_rows or freed[0] < virgin):
            row = heapq.heappop(freed)
        else:
            row = virgin
            self._virgin[key] = virgin + 1
        slot = (key[0], key[1], row)
        self._live.add(slot)
        self._occupancy[key] += 1
        return row

    def _pick_subarray(self, policy: str,
                       prefer: Sequence[Tuple[int, int]] = ()) -> Optional[
                           Tuple[int, int]]:
        """Choose the subarray the next slot comes from.

        Affinity subarrays (in order) win when they have space. Otherwise
        striped order minimizes (row, subarray, bank) - the seed bump-cursor
        order - and colocated order minimizes (bank, subarray) among
        non-full subarrays (fill one subarray, then move on)."""
        for key in prefer:
            if self._lowest_free_row(key) is not None:
                return key
        best = None
        best_rank = None
        for b in range(self.banks):
            for s in range(self.subarrays):
                row = self._lowest_free_row((b, s))
                if row is None:
                    continue
                rank = (row, s, b) if policy == STRIPED else (b, s, row)
                if best_rank is None or rank < best_rank:
                    best, best_rank = (b, s), rank
        return best

    def alloc(self, n_rows: int, policy: Optional[str] = None,
              near: Optional[Iterable[Slot]] = None) -> List[Slot]:
        """Allocate ``n_rows`` slots. Raises AmbitError when the device is
        full (no partial allocation survives a failure)."""
        policy = self.policy if policy is None else policy
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}")
        prefer: List[Tuple[int, int]] = []
        if near:
            seen = set()
            for b, s, _ in near:
                if (b, s) not in seen:
                    seen.add((b, s))
                    prefer.append((b, s))
        out: List[Slot] = []
        try:
            for _ in range(n_rows):
                key = self._pick_subarray(policy, prefer)
                if key is None:
                    raise AmbitError(
                        f"device full ({self.live}/{self.capacity} rows "
                        f"live)")
                out.append((key[0], key[1], self._take_row(key)))
        except AmbitError:
            self.free(out)
            raise
        return out

    def alloc_in(self, bank: int, subarray: int, n_rows: int) -> List[Slot]:
        """Allocate in exactly one subarray (placement-exact; used by the
        migration planner). Raises AmbitError when it doesn't fit."""
        key = (bank, subarray)
        if self.subarray_free(bank, subarray) < n_rows:
            raise AmbitError(
                f"subarray ({bank},{subarray}) full: "
                f"{self.subarray_free(bank, subarray)} free, "
                f"{n_rows} requested")
        return [(bank, subarray, self._take_row(key)) for _ in range(n_rows)]

    # -- freeing -------------------------------------------------------------

    def free(self, slots: Iterable[Slot]) -> None:
        for slot in slots:
            slot = tuple(slot)
            if slot not in self._live:
                raise AmbitError(f"free of non-live slot {slot}")
            self._live.remove(slot)
            b, s, r = slot
            heapq.heappush(self._freed[(b, s)], r)
            self._occupancy[(b, s)] -= 1

    # -- quarantine ----------------------------------------------------------

    def quarantine(self, slots: Iterable[Slot]) -> None:
        """Retire faulty rows permanently (the reliability layer's
        re-placement contract: a quarantined row is never allocated
        again). Live slots must be freed first; repeats are no-ops."""
        for slot in slots:
            slot = tuple(slot)
            b, s, r = slot
            if not (0 <= b < self.banks and 0 <= s < self.subarrays
                    and 0 <= r < self.usable_rows):
                raise AmbitError(
                    f"cannot quarantine non-allocatable slot {slot}")
            if slot in self._live:
                raise AmbitError(
                    f"cannot quarantine live slot {slot} (free it first)")
            if slot in self._quarantined:
                continue
            self._quarantined.add(slot)
            self._q_by_sub[(b, s)] = self._q_by_sub.get((b, s), 0) + 1
