"""Placement-aware query planner: whole Expr trees over resident operands.

The seed path lowered one binop at a time, each eval paying a host write
of every operand and a host read of the result. The planner instead takes
an entire expression DAG (``(w0 & w1) & w2 ...``), compiles it once
through PR 1's process-wide compile cache, and executes it directly over
resident rows:

  * chunks (device rows) are grouped by the subarray that holds their
    operands - each group runs the compiled AAP program **once**, batched
    over the group's rows (the Section 7 subarray-level parallelism);
  * operands that still span subarrays after the store's migration pass
    are staged through the reserved scratch row (RowClone-PSM cost,
    charged to the destination bank), mirroring the device bbop slow path;
  * results are written to freshly allocated rows co-located with their
    operands and returned as a *dirty* ResidentBitVector - no host
    read-back happens until someone calls ``get``;
  * a per-bank stat ledger is kept for each call: banks execute
    independent row groups in parallel, so the reported time is the
    **max over banks** while energy/AAP counts are summed (matching the
    Fig. 21 bank-parallelism accounting).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import expr as E
from ..core.engine import OpStats, _compile_cached
from ..core.simulator import AmbitError, AmbitSubarray
from ..core.timing import CommandStats
from .store import PimStore, ResidentBitVector


@dataclasses.dataclass
class PlanReport:
    """What one planner execution did, and what it cost.

    ``per_bank`` holds the full per-bank ledger delta (ns/energy/AAPs
    charged to each bank by THIS call) rather than only the merged
    totals: the async scheduler packs bank-disjoint queries into one
    epoch and needs per-resource deltas to account epoch time as
    max-over-resources."""

    groups: int = 0                 # batched program dispatches
    migrated_rows: int = 0          # PSM migrations performed up front
    staged_rows: int = 0            # scratch stagings at execution time
    per_bank: Dict[int, OpStats] = dataclasses.field(default_factory=dict)
    stats: OpStats = dataclasses.field(default_factory=OpStats)
    #: the call raised mid-execution (fault injection): the report holds
    #: the cost of the work that DID happen, and no result was adopted -
    #: the reliability layer absorbs it so retries bill honestly.
    partial: bool = False

    @property
    def per_bank_ns(self) -> Dict[int, float]:
        """Banks that burned time in this call (back-compat view)."""
        return {b: st.ns for b, st in self.per_bank.items() if st.ns > 0.0}


class QueryPlanner:
    def __init__(self, store: PimStore, optimize: bool = True,
                 colocate: bool = True):
        self.store = store
        self.optimize = optimize
        self.colocate = colocate
        self.last_report: Optional[PlanReport] = None

    # -- helpers -------------------------------------------------------------

    def _validate(self, env: Dict[str, ResidentBitVector]
                  ) -> Tuple[List[str], ResidentBitVector]:
        if not env:
            raise ValueError("planner needs at least one operand")
        names = sorted(env)
        first = env[names[0]]
        for nm in names:
            rbv = env[nm]
            self.store._check_live(rbv)
            if (rbv.n_bits, rbv.shape, rbv.n_slots) != (
                    first.n_bits, first.shape, first.n_slots):
                raise ValueError(
                    "bbop operands must be row-aligned and equal-sized "
                    "(Section 5.3)")
        return names, first

    def footprint(self, env: Dict[str, ResidentBitVector]
                  ) -> frozenset:
        """``(device, bank)`` resources the operands occupy (device is
        always 0 on a single-device store). Destinations are co-located
        with their operands, so this is the conservative resource set the
        async scheduler packs epochs by; spilled operands fault back in
        at an allocator-chosen location, so they claim every bank."""
        out = set()
        n_banks = len(self.store.device.banks)
        for nm in sorted(env):
            rbv = env[nm]
            if rbv.spilled:
                return frozenset((0, b) for b in range(n_banks))
            out.update((0, s[0]) for s in rbv.slots)
        return frozenset(out)

    def _bank_totals(self) -> Dict[int, CommandStats]:
        dev = self.store.device
        out = {}
        for bi, bank in enumerate(dev.banks):
            agg = CommandStats()
            agg.merge(bank.stats)
            for s in bank.subarrays:
                agg.merge(s.stats)
            out[bi] = agg
        return out

    # -- execution -----------------------------------------------------------

    def execute(self, expression: E.Expr,
                env: Dict[str, ResidentBitVector],
                out_name: Optional[str] = None) -> ResidentBitVector:
        """Evaluate ``expression`` over resident operands; the result stays
        resident (dirty). Appears in ``last_report`` with per-bank timing."""
        self.last_report = None
        names, first = self._validate(env)
        dev = self.store.device
        geom, timing = dev.geom, dev.timing
        report = PlanReport()
        before = self._bank_totals()

        dst_slots: List[tuple] = []
        try:
            operands = [env[nm] for nm in names]
            for rbv in operands:
                self.store._touch(rbv)  # in-use: refresh LRU recency
            if self.colocate and len(operands) > 1:
                report.migrated_rows = self.store.colocate(operands)

            # Destination rows co-located with their chunk's operands.
            # The fallback path may LRU-spill bystanders on a full
            # device, but the call's own operands are protected for the
            # duration.
            for i in range(first.n_slots):
                hb, hs, _ = operands[0].slots[i]
                try:
                    (slot,) = self.store.allocator.alloc_in(hb, hs, 1)
                except AmbitError:
                    (slot,) = self.store.alloc_slots(
                        1, near=[r.slots[i] for r in operands],
                        protect=operands)
                dst_slots.append(slot)

            compiled = _compile_cached(expression, tuple(names),
                                       self.optimize, geom.data_rows,
                                       timing)
            dst_row = len(names)

            # Group chunk indices by destination subarray; each group is
            # one batched program execution charged to that subarray's
            # ledger.
            groups: Dict[Tuple[int, int], List[int]] = {}
            for i, (b, s, _) in enumerate(dst_slots):
                groups.setdefault((b, s), []).append(i)

            inj = getattr(dev, "fault_injector", None)
            dev_idx = getattr(dev, "device_index", 0)
            for (gb, gs), idxs in sorted(groups.items()):
                sub = dev.banks[gb].subarrays[gs]
                n = len(idxs)
                batch = AmbitSubarray(geom, timing, words=dev.words,
                                      n_rows=n)
                for vi, nm in enumerate(names):
                    rows = np.empty((n, dev.words), np.uint64)
                    for gi, i in enumerate(idxs):
                        rows[gi] = self._fetch(env[nm].slots[i], gb, gs,
                                               report)
                    batch.write_row(vi, rows)
                batch.run(compiled.program)
                # the TRAs already ran: bill the batch before the
                # scatter, so an injected fault can't lose their cost
                sub.stats.merge(batch.stats)
                out = batch.read_row(dst_row).reshape(n, dev.words)
                for gi, i in enumerate(idxs):
                    row = out[gi]
                    if inj is not None:
                        row = inj.on_compute_write(
                            dev_idx, dst_slots[i], row)
                    sub.write_row(dst_slots[i][2], row)
                report.groups += 1
        except AmbitError:
            # Failed evals never leak live rows, and the work already
            # performed (stagings, TRAs, partial scatters) stays billed
            # via a partial report the reliability layer absorbs.
            if dst_slots:
                self.store.allocator.free(dst_slots)
            self._finalize(report, before, partial=True)
            raise

        self._finalize(report, before, partial=False)
        return self.store.adopt(ResidentBitVector(
            store=self.store, n_bits=first.n_bits, shape=first.shape,
            words32=first.words32, chunks=first.chunks, slots=dst_slots,
            dirty=True, name=out_name))

    def _finalize(self, report: PlanReport, before: Dict[int, CommandStats],
                  partial: bool) -> None:
        """Close out one execution attempt: compute the per-bank ledger
        delta, publish ``last_report`` and bill the metric/trace series.
        Runs for failed (partial) attempts too - injected faults must
        not leak unbilled DRAM work."""
        dev = self.store.device
        timing = dev.timing
        after = self._bank_totals()
        deltas = {bi: _delta(after[bi], before[bi]) for bi in after}
        # Refresh interference: every ns of bank-busy time drags
        # tRFC/(tREFI - tRFC) of refresh along with it (timing.py). This
        # is THE single site that computes stolen time from busy time, so
        # the per-bank ledger, the metrics series and the tracer spans
        # reconcile bit-exactly.
        report.per_bank = {
            bi: OpStats(ns=d.ns, energy_nj=d.energy_nj,
                        aap_count=d.aap_count,
                        refresh_stolen_ns=timing.refresh_stolen_ns(d.ns))
            for bi, d in deltas.items()
            if d.ns > 0.0 or d.energy_nj > 0.0 or d.aap_count}
        report.stats = OpStats(
            ns=max((d.ns for d in deltas.values()), default=0.0),
            energy_nj=sum(d.energy_nj for d in deltas.values()),
            aap_count=sum(d.aap_count for d in deltas.values()),
            bytes_touched=0,        # resident: no host traffic
            refresh_stolen_ns=sum(
                st.refresh_stolen_ns for st in report.per_bank.values()))
        report.partial = partial
        self.last_report = report

        # Observability: per-bank busy ns is the occupancy series the
        # utilization report divides by wall time. ``device=0`` because a
        # lone PimStore is device 0; under a PimCluster these land in the
        # per-device store's private registry while the ClusterPlanner
        # bills the shared one with real device indices.
        m = self.store.metrics
        if partial:
            m.counter("plan_faulted").inc(1)
        else:
            m.counter("plan_executions").inc(1)
        if report.groups:
            m.counter("plan_groups").inc(report.groups)
        if report.staged_rows:
            m.counter("plan_staged_rows").inc(report.staged_rows)
        for b in sorted(report.per_bank):
            st = report.per_bank[b]
            if st.ns:
                m.counter("bank_busy_ns").inc(st.ns, device=0, bank=b)
            if st.refresh_stolen_ns:
                m.counter("refresh_stolen_ns").inc(
                    st.refresh_stolen_ns, device=0, bank=b)
        tr = self.store.tracer
        if tr.enabled:
            args = {"groups": report.groups,
                    "migrated_rows": report.migrated_rows,
                    "staged_rows": report.staged_rows,
                    "aaps": report.stats.aap_count}
            if partial:
                args["partial"] = True
            tr.tick(("planner", "device0"), "plan", "plan", report.stats.ns,
                    args=args)
        # Per-bank refresh-stall spans go through the DEVICE tracer: under
        # a cluster the runtime threads the session tracer + a
        # ``device<d>`` trace_name onto each AmbitDevice (the per-device
        # store tracer stays NULL), so these spans are emitted exactly
        # once per call with the real device track either way.
        dtr = getattr(dev, "tracer", None)
        if dtr is not None and dtr.enabled:
            dev_track = getattr(dev, "trace_name", "device0")
            for b in sorted(report.per_bank):
                st = report.per_bank[b]
                if st.refresh_stolen_ns:
                    dtr.tick((dev_track, f"bank{b}"), "refresh_stall",
                             "refresh", st.refresh_stolen_ns,
                             args={"busy_ns": st.ns})

    def _fetch(self, src: tuple, gb: int, gs: int,
               report: PlanReport) -> np.ndarray:
        """Value of a source row for a group executing in subarray
        (gb, gs). Co-located rows are read in place; remote rows are
        PSM-staged into the reserved scratch row first (paper cost model),
        then read - one scratch row suffices because each staging is
        consumed before the next."""
        dev = self.store.device
        sb, ss, sr = src
        if (sb, ss) == (gb, gs):
            return dev.banks[gb].subarrays[gs].read_row(sr)
        if self.store.allocator.scratch_rows < 1:
            raise AmbitError(
                "non-co-located operand needs a reserved scratch row "
                "(RowAllocator scratch_rows >= 1)")
        scratch = dev.geom.data_rows - 1
        dev.migrate_row(src, (gb, gs, scratch))
        report.staged_rows += 1
        return dev.banks[gb].subarrays[gs].read_row(scratch)


def _delta(after: CommandStats, before: CommandStats) -> CommandStats:
    d = CommandStats()
    d.activates = after.activates - before.activates
    d.wordlines = after.wordlines - before.wordlines
    d.precharges = after.precharges - before.precharges
    d.aap_count = after.aap_count - before.aap_count
    d.ap_count = after.ap_count - before.ap_count
    d.ns = after.ns - before.ns
    d.energy_nj = after.energy_nj - before.energy_nj
    return d
