"""Resident bitvectors: data that lives in the simulated DRAM across calls.

The seed engine re-shipped every operand host -> subarray -> host on each
eval - exactly the memory-channel round-trip Ambit exists to avoid. The
store keeps bitvectors *in* the device model between operations:

  * ``put``  - pack a host BitVector into device rows (one allocator slot
    per row-sized chunk) and return a ResidentBitVector handle;
  * ``get``  - read it back (counted as host traffic; skipped entirely when
    the handle is clean, i.e. the host copy is already current);
  * ``free`` - release the rows for reuse.

Dirty tracking: a handle is *dirty* when the device content has never been
read back (planner results are born dirty); ``get`` on a clean handle
returns the cached host copy without touching the device, so the
bytes-touched ledger only grows for real host<->DRAM transfers.

LRU spill: when the device fills, ``put`` (and the planner's
destination-row allocation) evicts the least-recently-used unpinned
resident bitvectors instead of failing. A *clean* victim's host copy is
already current, so spilling it is free - zero ledger bytes; a *dirty*
victim is read back through the ledger first. Spilled handles stay valid:
``get`` serves the host copy for free and ``ensure_resident`` faults the
rows back in (charged as a fresh upload). ``pin=True`` at put time (or
``rbv.pinned = True``) exempts a handle from eviction, and operands of an
in-flight planner call are protected for the duration of the call.

``colocate`` is the PSM/RowClone migration planner: operands of one op
whose corresponding chunks landed in different subarrays are migrated
(RowClone-PSM within a bank, channel copy across banks - both charged to
the device ledger) so the op can run fully in-subarray.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.bitvector import BitVector, _mask_tail
from ..core.engine import _to_u64
from ..core.simulator import AmbitDevice, AmbitError
from ..obs import NULL_TRACER, MetricsRegistry
from .allocator import RowAllocator, Slot, STRIPED


# -- host <-> device-row layout (shared with pim.cluster) ---------------------


def _used32(n_bits: int, words32: int) -> int:
    """Meaningful packed uint32 words: BitVector pads the trailing dim
    to a VREG-lane multiple (bitvector.py), but only ceil(n_bits/32)
    words carry data - the lane padding is zero by construction and is
    not worth device rows."""
    return min(words32, -(-n_bits // 32))


def chunk_rows(bv: BitVector, words: int) -> np.ndarray:
    """Host BitVector -> (n_chunks, words) uint64 device-row chunks."""
    data32 = np.asarray(bv.data, np.uint32)
    flat = data32.reshape(-1, data32.shape[-1])
    used = _used32(bv.n_bits, data32.shape[-1])
    u64 = _to_u64(np.ascontiguousarray(flat[:, :used]))
    pad = (-u64.shape[1]) % words
    if pad:
        u64 = np.concatenate(
            [u64, np.zeros((u64.shape[0], pad), np.uint64)], axis=1)
    return u64.reshape(-1, words)


def unchunk_rows(rows: np.ndarray, n_bits: int, shape: Tuple[int, ...],
                 words32: int, words: int) -> BitVector:
    """(n_chunks, words) uint64 device rows -> the host BitVector layout."""
    n_rows = int(np.prod(shape)) if shape else 1
    u64 = rows.reshape(n_rows, -1)
    used = _used32(n_bits, words32)
    u32 = np.ascontiguousarray(u64).view(np.uint32)[:, :used]
    if used < words32:              # restore the host lane padding
        u32 = np.concatenate(
            [u32, np.zeros((n_rows, words32 - used), np.uint32)], axis=1)
    out = jnp.asarray(u32.reshape(shape + (words32,)))
    return BitVector(_mask_tail(out, n_bits), n_bits)


@dataclasses.dataclass(eq=False)
class ResidentBitVector:
    """Handle to a bitvector resident in device rows. Handles compare
    (and hash) by identity.

    ``slots`` is logical-row-major, chunk-minor: logical row r of the host
    (rows, n_bits) layout occupies slots[r*chunks : (r+1)*chunks], each
    holding one device-row-sized chunk of the packed words.

    ``spilled`` handles hold no device rows (they were LRU-evicted) but
    remain fully usable: the host copy is current, ``get`` is free, and
    ``PimStore.ensure_resident`` re-uploads on demand. ``pinned`` handles
    are never chosen as eviction victims."""

    store: "PimStore"
    n_bits: int
    shape: Tuple[int, ...]       # leading (batch) dims of the host layout
    words32: int                 # packed uint32 words per logical row
    chunks: int                  # device rows per logical row
    slots: List[Slot]
    dirty: bool = False
    pinned: bool = False
    spilled: bool = False
    name: Optional[str] = None
    _host: Optional[BitVector] = None
    # TMR protection (repro.pim.faults): a protected primary carries two
    # independently-placed replica handles; the reliability layer
    # executes queries replica-wise and majority-votes divergences.
    protected: bool = False
    replicas: List = dataclasses.field(default_factory=list)
    # Set when a device failure destroyed dirty, unspilled chunks: the
    # data is gone and any use raises FaultError(kind="data_loss").
    lost: bool = False

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    @property
    def device_bytes(self) -> int:
        return self.n_slots * self.store.device.row_bytes

    @property
    def freed(self) -> bool:
        return not self.slots and not self.spilled

    def get(self) -> BitVector:
        return self.store.get(self)

    def free(self) -> None:
        self.store.free(self)

    def __repr__(self):
        nm = f" {self.name!r}" if self.name else ""
        flags = (" pinned" if self.pinned else "") + \
            (" spilled" if self.spilled else "")
        return (f"<ResidentBitVector{nm} n_bits={self.n_bits} "
                f"slots={self.n_slots} dirty={self.dirty}{flags}>")


class LruSpillBase:
    """LRU bookkeeping + spill lifecycle shared by PimStore and PimCluster.

    One recency order, one eviction contract: ``spill`` frees a clean
    victim's rows for zero channel bytes (the host copy is current) and
    reads a dirty victim back through the ledger first; ``get`` serves
    spilled handles from the host copy for free. Subclasses provide the
    actual IO and row bookkeeping via ``_read_back`` / ``_release_rows``
    / ``_owner_of``."""

    _handle_desc = "resident bitvector"
    _obs_name = "store"

    def _lru_init(self) -> None:
        self.evicted_clean = 0
        self.evicted_dirty = 0
        # Observability (src/repro/obs): metrics are always on - every
        # channel transfer is charged through ``_charge_io`` so the
        # registry reconciles bit-exactly with the legacy byte counters;
        # the tracer defaults to the disabled NULL_TRACER (the runtime
        # swaps in live instances).
        self.metrics = MetricsRegistry()
        self.tracer = NULL_TRACER
        # Set by ``spill`` around the dirty read-back so _charge_io can
        # attribute those bytes to cause="spill" instead of "read_back".
        self._io_cause: Optional[str] = None
        self._lru: "OrderedDict[int, object]" = OrderedDict()
        # Hold refcounts: handles queued in an AsyncScheduler but not yet
        # executed must survive until their query runs - they are skipped
        # by eviction and cannot be freed or explicitly spilled.
        self._held: Dict[int, int] = {}
        # Pinning budget: ``pin``/``put(pin=True)`` charge the handle's
        # device bytes against ``pin_budget_bytes`` (None = unlimited), so
        # a shared device can cap how much of it tenants may exempt from
        # eviction. Only handles billed through ``pin`` are refunded at
        # unpin/free - a direct ``rbv.pinned = True`` poke stays the
        # documented unbudgeted escape hatch.
        self.pinned_bytes = 0
        self.pin_budget_bytes: Optional[int] = None
        self._pin_billed: set = set()
        # Dirty-tracking generations: every mutation of a handle's device
        # contents that is NOT an ordinary planner write into a fresh
        # result - ``out=`` rebind, free, spill->fault-in - bumps the
        # handle's generation and notifies the invalidation hooks. The
        # optimizer's result cache keys on (canonical expr, operand
        # generations), so a bumped operand makes stale entries
        # unreachable and the hook drops them eagerly.
        self._gen: Dict[int, int] = {}
        self._invalidation_hooks: List = []

    def generation(self, rbv) -> int:
        """Monotonic dirty-tracking counter for a handle (0 until its
        first invalidating mutation)."""
        return self._gen.get(id(rbv), 0)

    def _invalidate(self, rbv) -> None:
        """Bump a handle's generation and fan out to registered hooks
        (the optimizer's result cache)."""
        self._gen[id(rbv)] = self._gen.get(id(rbv), 0) + 1
        for hook in self._invalidation_hooks:
            hook(rbv)

    def _charge_io(self, direction: str, cause: str, nbytes: int) -> None:
        """THE accounting site for host<->device channel transfers.

        Every byte that crosses the channel is billed here exactly once:
        the legacy per-store counters, the MetricsRegistry series
        (``store_io_bytes``/``store_io_ops`` labeled by direction and
        cause: upload / fault_in / spill / read_back), and - when
        tracing - a store-track instant all update together, which is
        what keeps the registry bit-exactly reconciled with the legacy
        ledgers. ``direction`` is "to_device" or "from_device".
        PimCluster extends this to bill its ChannelLedger too."""
        if direction == "to_device":
            self.host_writes += 1
            self.bytes_to_device += nbytes
        else:
            self.host_reads += 1
            self.bytes_from_device += nbytes
        self.metrics.counter("store_io_bytes").inc(
            nbytes, direction=direction, cause=cause)
        self.metrics.counter("store_io_ops").inc(
            1, direction=direction, cause=cause)
        if self.tracer.enabled:
            self.tracer.instant(
                (self._obs_name, "io"), cause, "store",
                args={"direction": direction, "bytes": int(nbytes)})

    def pin(self, rbv) -> None:
        """Exempt a handle from eviction, charging its bytes against the
        pin budget. Raises AmbitError when the budget would overflow."""
        self._check_handle(rbv)
        if rbv.pinned:
            return
        nbytes = rbv.device_bytes
        if self.pin_budget_bytes is not None and \
                self.pinned_bytes + nbytes > self.pin_budget_bytes:
            raise AmbitError(
                f"pin budget exceeded: {self.pinned_bytes} B already "
                f"pinned + {nbytes} B would pass the "
                f"{self.pin_budget_bytes} B budget")
        rbv.pinned = True
        self.pinned_bytes += nbytes
        self._pin_billed.add(id(rbv))

    def unpin(self, rbv) -> None:
        """Make a pinned handle evictable again and refund its budget."""
        self._check_handle(rbv)
        if not rbv.pinned:
            return
        rbv.pinned = False
        if id(rbv) in self._pin_billed:
            self._pin_billed.discard(id(rbv))
            self.pinned_bytes -= rbv.device_bytes

    def hold(self, rbv) -> None:
        """Protect a handle from eviction/free until ``release``. Refcounted:
        the scheduler holds each operand once per queued query that reads
        it."""
        self._check_handle(rbv)
        self._held[id(rbv)] = self._held.get(id(rbv), 0) + 1

    def release(self, rbv) -> None:
        n = self._held.get(id(rbv), 0) - 1
        if n <= 0:
            self._held.pop(id(rbv), None)
        else:
            self._held[id(rbv)] = n

    def is_held(self, rbv) -> bool:
        return id(rbv) in self._held

    def _register(self, rbv) -> None:
        self._lru[id(rbv)] = rbv
        self._lru.move_to_end(id(rbv))

    def _touch(self, rbv) -> None:
        if id(rbv) in self._lru:
            self._lru.move_to_end(id(rbv))

    def _unregister(self, rbv) -> None:
        self._lru.pop(id(rbv), None)

    def spill(self, rbv, _force_held: bool = False) -> None:
        """Evict a handle's device rows back to host. Clean handles cost
        zero channel bytes; dirty ones are read back through the ledger
        first. Held (queued) handles refuse unless ``_force_held`` - the
        eviction loops set it only when nothing unheld can make room, and
        the spilled operand faults back in when its query executes."""
        self._check_live(rbv)
        if rbv.pinned:
            raise AmbitError(f"cannot spill pinned {rbv!r}")
        if self.is_held(rbv) and not _force_held:
            raise AmbitError(
                f"cannot spill {rbv!r}: a queued query still reads it")
        if rbv.dirty or rbv._host is None:
            self._io_cause = "spill"
            try:
                self._read_back(rbv)
            finally:
                self._io_cause = None
            self.evicted_dirty += 1
        else:
            self.evicted_clean += 1
        self._release_rows(rbv)
        rbv.spilled = True
        self._unregister(rbv)

    def get(self, rbv) -> BitVector:
        self._check_handle(rbv)
        if rbv.spilled:
            return rbv._host            # evicted clean: host copy current
        self._touch(rbv)
        if not rbv.dirty and rbv._host is not None:
            return rbv._host            # host copy is current: no traffic
        return self._read_back(rbv)

    def free(self, rbv) -> None:
        self._check_handle(rbv, allow_lost=True)
        rbv.lost = False                # freeing abandons the lost data
        # Notify BEFORE the held check: the result cache holds the
        # results (and references the operands) it caches, and dropping
        # those entries releases the cache's own hold - so a user can
        # free a handle whose only remaining holder is the cache.
        if self._invalidation_hooks:
            self._invalidate(rbv)
        if self.is_held(rbv):
            raise AmbitError(
                f"cannot free {rbv!r}: a queued query still reads it "
                "(drain the scheduler first)")
        if id(rbv) in self._pin_billed:     # refund the pin budget
            self._pin_billed.discard(id(rbv))
            self.pinned_bytes -= rbv.device_bytes
        rbv.pinned = False
        self._release_rows(rbv)
        self._unregister(rbv)
        rbv.spilled = False
        rbv._host = None
        self._gen.pop(id(rbv), None)    # id may be reused after gc
        # TMR planes live and die with their primary
        replicas, rbv.replicas = list(getattr(rbv, "replicas", ())), []
        for rep in replicas:
            if not rep.freed:
                self.free(rep)

    def rebind(self, out, res) -> object:
        """Move a fresh result's storage into an existing destination
        handle (``out=`` semantics: identity-preserving in-place write -
        no device copy, the destination's old storage is freed)."""
        if (out.n_bits, out.shape) != (res.n_bits, res.shape):
            raise AmbitError(
                f"out= handle shape mismatch: {out!r} vs result {res!r}")
        self._release_rows(out)         # no-op when out is spilled
        self._move_storage(out, res)
        self._unregister(res)
        out.spilled = False
        out.dirty = True
        out._host = None
        self._register(out)
        self._invalidate(out)           # out= is a dirty-tracked write
        return out

    def _move_storage(self, out, res) -> None:
        """Transfer ``res``'s device storage into ``out`` (slot lists by
        default; DeviceStore moves the device buffer instead)."""
        out.slots, res.slots = res.slots, []

    def _evict_lru(self, protect: Iterable, want=None, spill=None) -> bool:
        """Spill the least-recently-used evictable handle. Unheld victims
        are preferred; under capacity pressure a held (queued) operand of
        a not-yet-executed query spills as a last resort - it faults back
        in when its query runs, charged to that query. ``want`` narrows
        the candidate set (e.g. handles owning rows on one full device)
        and ``spill`` overrides how the victim is spilled (e.g. partial,
        per-device). Returns False when nothing evictable matched."""
        protected = {id(p) for p in protect}
        if spill is None:
            spill = lambda rbv, fh: self.spill(rbv, _force_held=fh)  # noqa: E731
        for force_held in (False, True):
            for rbv in list(self._lru.values()):
                if rbv.pinned or id(rbv) in protected or \
                        not self._resident_storage(rbv):
                    continue
                if want is not None and not want(rbv):
                    continue
                if self.is_held(rbv) and not force_held:
                    continue
                spill(rbv, force_held)
                return True
        return False

    def _resident_storage(self, rbv) -> bool:
        """Does the handle hold any device storage right now?"""
        return bool(rbv.slots)

    def _check_handle(self, rbv, allow_lost: bool = False) -> None:
        """Valid for get/free/ensure_resident: live OR spilled."""
        if rbv.freed:
            raise AmbitError(
                f"use of freed {self._handle_desc} {rbv!r}")
        if getattr(rbv, "lost", False) and not allow_lost:
            from .faults import FaultError
            raise FaultError(
                f"data loss: a failed device held the only copy of "
                f"{rbv!r}", kind="data_loss")
        if self._owner_of(rbv) is not self:
            raise AmbitError(
                f"{self._handle_desc} belongs to another store")

    def _check_live(self, rbv) -> None:
        """Valid for device-side ops: must actually hold rows."""
        self._check_handle(rbv)
        if rbv.spilled:
            raise AmbitError(
                f"device-side use of spilled {rbv!r} "
                "(ensure_resident re-uploads it)")

    # subclass hooks ---------------------------------------------------------

    def _read_back(self, rbv) -> BitVector:
        raise NotImplementedError

    def _release_rows(self, rbv) -> None:
        raise NotImplementedError

    def _owner_of(self, rbv):
        raise NotImplementedError


class PimStore(LruSpillBase):
    """put/get/free lifecycle for resident bitvectors on one device."""

    def __init__(self, device: AmbitDevice,
                 allocator: Optional[RowAllocator] = None,
                 policy: str = STRIPED, scratch_rows: int = 4):
        self.device = device
        if allocator is None:
            # Share the device's allocator: resident rows and raw
            # device.alloc_rows() calls must draw from ONE free list, or
            # the two would hand out the same physical rows.
            if device._allocator is None:
                device._allocator = RowAllocator.for_device(
                    device, scratch_rows=scratch_rows, policy=policy)
            allocator = device._allocator
        else:
            if device._allocator is not None and \
                    device._allocator is not allocator:
                raise AmbitError(
                    "device already has a different RowAllocator "
                    "(two allocators over one device hand out the same "
                    "physical rows)")
            device._allocator = allocator
        self.allocator = allocator
        self.policy = policy
        # Host-traffic ledger: only put/get move data over the channel.
        self.host_writes = 0
        self.host_reads = 0
        self.bytes_to_device = 0
        self.bytes_from_device = 0
        self.migrated_rows = 0
        # Eviction ledger + recency order (LruSpillBase): clean spills cost
        # nothing; dirty spills show up in host_reads/bytes_from_device.
        self._lru_init()
        # When this store is one device of a PimCluster, handles live in
        # the CLUSTER's LRU; the cluster installs a fallback here so a
        # full device can still evict during per-device sub-plans.
        self.spill_fallback = None

    # -- layout --------------------------------------------------------------

    def _chunk(self, bv: BitVector) -> np.ndarray:
        return chunk_rows(bv, self.device.words)

    def _unchunk(self, rows: np.ndarray, rbv: ResidentBitVector) -> BitVector:
        return unchunk_rows(rows, rbv.n_bits, rbv.shape, rbv.words32,
                            self.device.words)

    # -- LRU / eviction (machinery in LruSpillBase) --------------------------

    def _owner_of(self, rbv: ResidentBitVector):
        return rbv.store

    def _release_rows(self, rbv: ResidentBitVector) -> None:
        if rbv.slots:
            self.allocator.free(rbv.slots)
        rbv.slots = []

    def adopt(self, rbv: ResidentBitVector) -> ResidentBitVector:
        """Track an externally-built handle (planner results) in the LRU so
        it participates in spill like any put() handle."""
        self._register(rbv)
        return rbv

    def disown(self, rbv: ResidentBitVector) -> ResidentBitVector:
        """Stop tracking a handle without freeing its rows (the cluster
        harvests per-device sub-results into cluster-level handles)."""
        self._unregister(rbv)
        return rbv

    def _evict_one(self, protect: Iterable[ResidentBitVector]) -> bool:
        """Spill the LRU evictable handle (loop in LruSpillBase); when
        every registered handle is pinned or protected, give a
        cluster-installed fallback the chance to evict at its scope."""
        if self._evict_lru(protect):
            return True
        if self.spill_fallback is not None:
            return self.spill_fallback()
        return False

    def alloc_slots(self, n_rows: int, policy: Optional[str] = None,
                    near: Optional[Sequence[Slot]] = None,
                    protect: Iterable[ResidentBitVector] = ()
                    ) -> List[Slot]:
        """Allocate rows, LRU-spilling unpinned resident bitvectors (not in
        ``protect``) when the device is full. Raises AmbitError when the
        request cannot fit even after evicting everything evictable."""
        while self.allocator.shortfall(n_rows):
            if not self._evict_one(protect):
                raise AmbitError(
                    f"device full ({self.allocator.live}/"
                    f"{self.allocator.capacity} rows live) and every "
                    f"resident bitvector is pinned or in use")
        return self.allocator.alloc(n_rows, policy=policy, near=near)

    # -- lifecycle -----------------------------------------------------------

    def put(self, bv: BitVector, policy: Optional[str] = None,
            near: Optional[Sequence[Slot]] = None,
            name: Optional[str] = None,
            pin: bool = False, protect: bool = False) -> ResidentBitVector:
        chunks = self._chunk(bv)
        if len(chunks) == 0:
            raise AmbitError("cannot make a zero-row bitvector resident")
        if near is not None and len(near) == len(chunks):
            # chunk-aligned affinity: chunk k lands in the subarray that
            # holds chunk k of the neighbor, so corresponding rows of
            # co-operating bitvectors share a subarray (the Section 5.2
            # co-location contract) without any later migration.
            slots = []
            try:
                for k in range(len(chunks)):
                    slots.extend(self.alloc_slots(
                        1, policy=policy, near=[near[k]]))
            except AmbitError:
                self.allocator.free(slots)
                raise
        else:
            slots = self.alloc_slots(len(chunks), policy=policy, near=near)
        self.device.write(slots, chunks)
        data32 = np.asarray(bv.data, np.uint32)
        rbv = ResidentBitVector(
            store=self, n_bits=bv.n_bits, shape=data32.shape[:-1],
            words32=data32.shape[-1],
            chunks=len(chunks) // max(1, int(np.prod(data32.shape[:-1]))),
            slots=slots, dirty=False, name=name, _host=bv)
        self._charge_io("to_device", "upload", rbv.device_bytes)
        self._register(rbv)
        if pin:
            try:
                self.pin(rbv)
            except AmbitError:          # over budget: undo the upload
                self.free(rbv)
                raise
        if protect:
            # TMR encode-on-put: two more independently-placed planes,
            # each a full honest upload (3x storage, 3x channel bytes -
            # the paper's stated price for the only homomorphic code).
            try:
                for k in (1, 2):
                    rbv.replicas.append(self.put(
                        bv, policy=policy, pin=pin,
                        name=f"{name}/plane{k}" if name else None))
            except AmbitError:
                self.free(rbv)
                raise
            rbv.protected = True
        return rbv

    def _read_back(self, rbv: ResidentBitVector) -> BitVector:
        rows = self.device.read(rbv.slots)
        out = self._unchunk(rows.reshape(len(rbv.slots), self.device.words),
                            rbv)
        rbv._host = out
        rbv.dirty = False
        self._charge_io("from_device", self._io_cause or "read_back",
                        rbv.device_bytes)
        return out

    def ensure_resident(self, rbv: ResidentBitVector,
                        protect: Iterable[ResidentBitVector] = ()
                        ) -> ResidentBitVector:
        """Fault a spilled handle back into device rows (charged as a fresh
        host->device upload). Live handles just refresh recency."""
        self._check_handle(rbv)
        if not rbv.spilled:
            self._touch(rbv)
            return rbv
        chunks = self._chunk(rbv._host)
        slots = self.alloc_slots(len(chunks), protect=(rbv, *protect))
        self.device.write(slots, chunks)
        rbv.slots = slots
        rbv.spilled = False
        rbv.dirty = False
        self._charge_io("to_device", "fault_in", rbv.device_bytes)
        self._register(rbv)
        self._invalidate(rbv)   # placement changed: generation bumps
        return rbv

    # -- migration planner ---------------------------------------------------

    def plan_migrations(self, operands: Sequence[ResidentBitVector]
                        ) -> List[Tuple[ResidentBitVector, int, Slot]]:
        """For each chunk index where the operands span subarrays, pick the
        plurality subarray as the target and list (rbv, slot_index,
        target_subarray_slot=(bank, sub, -1)) moves. Pure planning - no
        device mutation (``colocate`` executes the plan)."""
        moves: List[Tuple[ResidentBitVector, int, Slot]] = []
        if not operands:
            return moves
        n = operands[0].n_slots
        for rbv in operands:
            self._check_live(rbv)
            if rbv.n_slots != n:
                raise AmbitError("operands must be chunk-aligned "
                                 "(same n_bits and shape)")
        for i in range(n):
            homes = [(r.slots[i][0], r.slots[i][1]) for r in operands]
            if len(set(homes)) == 1:
                continue
            counts: Dict[Tuple[int, int], int] = {}
            for h in homes:
                counts[h] = counts.get(h, 0) + 1
            best = max(counts.values())
            # plurality target; ties break to the first operand's home
            target = next(h for h in homes if counts[h] == best)
            seen = set()    # an operand listed twice moves once
            for rbv, h in zip(operands, homes):
                if h != target and id(rbv) not in seen:
                    seen.add(id(rbv))
                    moves.append((rbv, i, (target[0], target[1], -1)))
        return moves

    def colocate(self, operands: Sequence[ResidentBitVector]) -> int:
        """Execute the migration plan: move spanning chunks into the target
        subarray via RowClone-PSM / channel copy (device-ledger cost).
        Best-effort: a full target subarray leaves that chunk in place (the
        planner will stage it through scratch at execution time). Returns
        the number of rows migrated."""
        moved = 0
        try:
            for rbv, i, (tb, ts, _) in self.plan_migrations(operands):
                try:
                    (new_slot,) = self.allocator.alloc_in(tb, ts, 1)
                except AmbitError:
                    continue
                try:
                    self.device.migrate_row(rbv.slots[i], new_slot)
                except AmbitError:  # injected fault: don't leak the row
                    self.allocator.free([new_slot])
                    raise
                self.allocator.free([rbv.slots[i]])
                rbv.slots[i] = new_slot
                moved += 1
        finally:
            # bill even when a migration faults mid-plan: the moved rows
            # really moved
            self.migrated_rows += moved
            if moved:
                self.metrics.counter("migrated_rows").inc(moved)
        return moved
