"""Resident bitvectors: data that lives in the simulated DRAM across calls.

The seed engine re-shipped every operand host -> subarray -> host on each
eval - exactly the memory-channel round-trip Ambit exists to avoid. The
store keeps bitvectors *in* the device model between operations:

  * ``put``  - pack a host BitVector into device rows (one allocator slot
    per row-sized chunk) and return a ResidentBitVector handle;
  * ``get``  - read it back (counted as host traffic; skipped entirely when
    the handle is clean, i.e. the host copy is already current);
  * ``free`` - release the rows for reuse.

Dirty tracking: a handle is *dirty* when the device content has never been
read back (planner results are born dirty); ``get`` on a clean handle
returns the cached host copy without touching the device, so the
bytes-touched ledger only grows for real host<->DRAM transfers.

``colocate`` is the PSM/RowClone migration planner: operands of one op
whose corresponding chunks landed in different subarrays are migrated
(RowClone-PSM within a bank, channel copy across banks - both charged to
the device ledger) so the op can run fully in-subarray.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.bitvector import BitVector, _mask_tail
from ..core.engine import _to_u64
from ..core.simulator import AmbitDevice, AmbitError
from .allocator import RowAllocator, Slot, STRIPED


@dataclasses.dataclass
class ResidentBitVector:
    """Handle to a bitvector resident in device rows.

    ``slots`` is logical-row-major, chunk-minor: logical row r of the host
    (rows, n_bits) layout occupies slots[r*chunks : (r+1)*chunks], each
    holding one device-row-sized chunk of the packed words."""

    store: "PimStore"
    n_bits: int
    shape: Tuple[int, ...]       # leading (batch) dims of the host layout
    words32: int                 # packed uint32 words per logical row
    chunks: int                  # device rows per logical row
    slots: List[Slot]
    dirty: bool = False
    name: Optional[str] = None
    _host: Optional[BitVector] = None

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    @property
    def device_bytes(self) -> int:
        return self.n_slots * self.store.device.row_bytes

    @property
    def freed(self) -> bool:
        return not self.slots

    def get(self) -> BitVector:
        return self.store.get(self)

    def free(self) -> None:
        self.store.free(self)

    def __repr__(self):
        nm = f" {self.name!r}" if self.name else ""
        return (f"<ResidentBitVector{nm} n_bits={self.n_bits} "
                f"slots={self.n_slots} dirty={self.dirty}>")


class PimStore:
    """put/get/free lifecycle for resident bitvectors on one device."""

    def __init__(self, device: AmbitDevice,
                 allocator: Optional[RowAllocator] = None,
                 policy: str = STRIPED, scratch_rows: int = 4):
        self.device = device
        if allocator is None:
            # Share the device's allocator: resident rows and raw
            # device.alloc_rows() calls must draw from ONE free list, or
            # the two would hand out the same physical rows.
            if device._allocator is None:
                device._allocator = RowAllocator.for_device(
                    device, scratch_rows=scratch_rows, policy=policy)
            allocator = device._allocator
        else:
            if device._allocator is not None and \
                    device._allocator is not allocator:
                raise AmbitError(
                    "device already has a different RowAllocator "
                    "(two allocators over one device hand out the same "
                    "physical rows)")
            device._allocator = allocator
        self.allocator = allocator
        self.policy = policy
        # Host-traffic ledger: only put/get move data over the channel.
        self.host_writes = 0
        self.host_reads = 0
        self.bytes_to_device = 0
        self.bytes_from_device = 0
        self.migrated_rows = 0

    # -- layout --------------------------------------------------------------

    @staticmethod
    def _used32(n_bits: int, words32: int) -> int:
        """Meaningful packed uint32 words: BitVector pads the trailing dim
        to a VREG-lane multiple (bitvector.py), but only ceil(n_bits/32)
        words carry data - the lane padding is zero by construction and is
        not worth device rows."""
        return min(words32, -(-n_bits // 32))

    def _chunk(self, bv: BitVector) -> np.ndarray:
        """Host BitVector -> (n_slots, device.words) uint64 row chunks."""
        data32 = np.asarray(bv.data, np.uint32)
        flat = data32.reshape(-1, data32.shape[-1])
        used = self._used32(bv.n_bits, data32.shape[-1])
        u64 = _to_u64(np.ascontiguousarray(flat[:, :used]))
        w = self.device.words
        pad = (-u64.shape[1]) % w
        if pad:
            u64 = np.concatenate(
                [u64, np.zeros((u64.shape[0], pad), np.uint64)], axis=1)
        return u64.reshape(-1, w)

    def _unchunk(self, rows: np.ndarray, rbv: ResidentBitVector) -> BitVector:
        n_rows = int(np.prod(rbv.shape)) if rbv.shape else 1
        u64 = rows.reshape(n_rows, rbv.chunks * self.device.words)
        used = self._used32(rbv.n_bits, rbv.words32)
        u32 = np.ascontiguousarray(u64).view(np.uint32)[:, :used]
        if used < rbv.words32:          # restore the host lane padding
            u32 = np.concatenate(
                [u32, np.zeros((n_rows, rbv.words32 - used), np.uint32)],
                axis=1)
        out = jnp.asarray(u32.reshape(rbv.shape + (rbv.words32,)))
        return BitVector(_mask_tail(out, rbv.n_bits), rbv.n_bits)

    # -- lifecycle -----------------------------------------------------------

    def put(self, bv: BitVector, policy: Optional[str] = None,
            near: Optional[Sequence[Slot]] = None,
            name: Optional[str] = None) -> ResidentBitVector:
        chunks = self._chunk(bv)
        if len(chunks) == 0:
            raise AmbitError("cannot make a zero-row bitvector resident")
        if near is not None and len(near) == len(chunks):
            # chunk-aligned affinity: chunk k lands in the subarray that
            # holds chunk k of the neighbor, so corresponding rows of
            # co-operating bitvectors share a subarray (the Section 5.2
            # co-location contract) without any later migration.
            slots = []
            try:
                for k in range(len(chunks)):
                    slots.extend(self.allocator.alloc(
                        1, policy=policy, near=[near[k]]))
            except AmbitError:
                self.allocator.free(slots)
                raise
        else:
            slots = self.allocator.alloc(len(chunks), policy=policy,
                                         near=near)
        self.device.write(slots, chunks)
        data32 = np.asarray(bv.data, np.uint32)
        rbv = ResidentBitVector(
            store=self, n_bits=bv.n_bits, shape=data32.shape[:-1],
            words32=data32.shape[-1],
            chunks=len(chunks) // max(1, int(np.prod(data32.shape[:-1]))),
            slots=slots, dirty=False, name=name, _host=bv)
        self.host_writes += 1
        self.bytes_to_device += rbv.device_bytes
        return rbv

    def get(self, rbv: ResidentBitVector) -> BitVector:
        self._check_live(rbv)
        if not rbv.dirty and rbv._host is not None:
            return rbv._host            # host copy is current: no traffic
        rows = self.device.read(rbv.slots)
        out = self._unchunk(rows.reshape(len(rbv.slots), self.device.words),
                            rbv)
        rbv._host = out
        rbv.dirty = False
        self.host_reads += 1
        self.bytes_from_device += rbv.device_bytes
        return out

    def free(self, rbv: ResidentBitVector) -> None:
        self._check_live(rbv)
        self.allocator.free(rbv.slots)
        rbv.slots = []
        rbv._host = None

    def _check_live(self, rbv: ResidentBitVector) -> None:
        if rbv.freed:
            raise AmbitError(f"use of freed resident bitvector {rbv!r}")
        if rbv.store is not self:
            raise AmbitError("resident bitvector belongs to another store")

    # -- migration planner ---------------------------------------------------

    def plan_migrations(self, operands: Sequence[ResidentBitVector]
                        ) -> List[Tuple[ResidentBitVector, int, Slot]]:
        """For each chunk index where the operands span subarrays, pick the
        plurality subarray as the target and list (rbv, slot_index,
        target_subarray_slot=(bank, sub, -1)) moves. Pure planning - no
        device mutation (``colocate`` executes the plan)."""
        moves: List[Tuple[ResidentBitVector, int, Slot]] = []
        if not operands:
            return moves
        n = operands[0].n_slots
        for rbv in operands:
            self._check_live(rbv)
            if rbv.n_slots != n:
                raise AmbitError("operands must be chunk-aligned "
                                 "(same n_bits and shape)")
        for i in range(n):
            homes = [(r.slots[i][0], r.slots[i][1]) for r in operands]
            if len(set(homes)) == 1:
                continue
            counts: Dict[Tuple[int, int], int] = {}
            for h in homes:
                counts[h] = counts.get(h, 0) + 1
            best = max(counts.values())
            # plurality target; ties break to the first operand's home
            target = next(h for h in homes if counts[h] == best)
            for rbv, h in zip(operands, homes):
                if h != target:
                    moves.append((rbv, i, (target[0], target[1], -1)))
        return moves

    def colocate(self, operands: Sequence[ResidentBitVector]) -> int:
        """Execute the migration plan: move spanning chunks into the target
        subarray via RowClone-PSM / channel copy (device-ledger cost).
        Best-effort: a full target subarray leaves that chunk in place (the
        planner will stage it through scratch at execution time). Returns
        the number of rows migrated."""
        moved = 0
        for rbv, i, (tb, ts, _) in self.plan_migrations(operands):
            try:
                (new_slot,) = self.allocator.alloc_in(tb, ts, 1)
            except AmbitError:
                continue
            self.device.migrate_row(rbv.slots[i], new_slot)
            self.allocator.free([rbv.slots[i]])
            rbv.slots[i] = new_slot
            moved += 1
        self.migrated_rows += moved
        return moved
