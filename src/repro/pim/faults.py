"""Fault injection + end-to-end reliability for the PIM runtime.

Ambit's correctness rests on analog triple-row activation, and the paper
(Section 6, Table 3) shows TRA failing under process variation; Section
5.5 names triple-modular redundancy as the only protection that commutes
with bulk bitwise operation. This module wires both observations into
the runtime as one subsystem:

**inject** - a deterministic, seedable :class:`FaultInjector` the
simulator consults at TRA-result scatter time, RowClone/transfer time
and on every device touch:

  * *weak cells*: per-``(device, bank, subarray, row)`` bit masks
    sampled at the calibrated per-bit failure rate the ``core.analog``
    Monte-Carlo model produces for the configured process variation
    (Table 3), XORed into computed rows as they are written back;
  * *stuck rows*: a fixed fraction of data rows fail hard - any compute
    write or RowClone landing there raises, deterministically, forever
    (the persistent-fault class that makes quarantine meaningful);
  * *transient flips*: per-event single-bit upsets at a configured rate
    on compute writes and row transfers;
  * *device loss*: whole-device failure, either scheduled after the
    N-th event on a device or forced via :meth:`FaultInjector.fail_device`.

All sampling is keyed **structurally** - ``default_rng((seed, tag,
device, bank, ...))`` - never by ``hash()``, so the fault sequence is a
pure function of the seed and the executed workload: byte-identical
across runs and across ``PYTHONHASHSEED``.

**detect** - TMR-protected planes (``put(..., protect=True)`` stores
three independently-placed replicas) are executed replica-wise and
cross-checked with XOR parity queries lowered through the planner
(billed DRAM work, not magic); raw-row zero-tests are the only free
telemetry, standing in for the DQ-level compare a memory controller
gets for free.

**recover** - :class:`ReliabilityManager` retries failed plans with
bounded exponential backoff, quarantines faulty rows back to the
``RowAllocator``, scrubs diverged TMR planes by re-voting them through
native MAJ queries, and (on a cluster) evacuates lost devices and
repairs protected planes chunk-by-chunk from surviving siblings. The
serving frontend adds the last layer: deadline timeouts, error results
and host fallback (see ``serve.frontend``).

Every fault, scrub, retry and quarantine is a labeled metric
(``fault_injected{kind}``, ``scrub_corrections``,
``ticket_retries{reason}``, ``quarantined_rows``) and a trace event,
and every retried/scrubbed attempt's DRAM work is absorbed into the
caller's ``OpStats`` - recovery inflates the ledgers honestly, never
silently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core import expr as E
from ..core.engine import OpStats
from ..core.simulator import AmbitError

__all__ = [
    "FaultError", "DeviceLostError", "FaultConfig", "FaultInjector",
    "ReliabilityManager",
]

#: Top data rows excluded from stuck-row sampling: the compiler stages
#: PSM copies through the last data row and the allocator's scratch zone
#: lives directly below it, so a stuck row there would wedge every
#: query instead of modeling a recoverable placement fault.
STUCK_GUARD_ROWS = 8


class FaultError(AmbitError):
    """An injected (or detected) fault. ``kind`` labels the metric
    series; ``device``/``slot`` name the faulty site so recovery can
    re-place away from it."""

    def __init__(self, msg: str, kind: str = "fault",
                 device: Optional[int] = None,
                 slot: Optional[Tuple[int, int, int]] = None):
        super().__init__(msg)
        self.kind = kind
        self.device = device
        self.slot = slot


class DeviceLostError(FaultError):
    """A whole device went away."""

    def __init__(self, msg: str, device: Optional[int] = None):
        super().__init__(msg, kind="device_lost", device=device)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Deterministic fault model. All rates default to zero: a
    constructed-but-idle injector never perturbs anything."""

    seed: int = 0
    #: process variation fed to ``analog.tra_failure_rate``; the
    #: resulting per-bit TRA failure probability becomes the weak-cell
    #: density (Table 3: 0.0 at +-5%%, ~6e-2 at +-15%%).
    variation: float = 0.0
    #: explicit per-bit weak-cell rate; overrides ``variation`` when set
    #: (tests want small, targeted densities).
    weak_bit_rate: Optional[float] = None
    #: fraction of data rows that are hard-stuck (persistent faults).
    stuck_row_rate: float = 0.0
    #: per-compute-write probability of a single-bit transient upset.
    transient_rate: float = 0.0
    #: per-transfer probability of a single-bit flip at the destination.
    transfer_flip_rate: float = 0.0
    #: ``((device, after_n_events), ...)``: device fails permanently on
    #: its N-th injector-visible event.
    fail_device_after: Tuple[Tuple[int, int], ...] = ()
    #: Monte-Carlo trials for the analog calibration (kept modest: the
    #: rate is cached once per injector).
    analog_trials: int = 20_000


class FaultInjector:
    """Seeded, structurally-keyed fault source (see module docstring).

    The simulator calls :meth:`on_compute_write` when a TRA result row
    is scattered into its destination slot, :meth:`on_transfer` after a
    RowClone/inter-device row copy lands, and :meth:`check_alive` on
    every device touch. ``events`` is the execution-ordered fault
    ledger the determinism CI byte-diffs.
    """

    def __init__(self, config: Optional[FaultConfig] = None):
        self.config = config or FaultConfig()
        self.dead: Set[int] = set()
        self.events: List[str] = []
        self.counts: Dict[str, int] = {}
        self.metrics = None
        self.tracer = None
        self.data_rows: Optional[int] = None
        self._weak_rate: Optional[float] = None
        self._weak_masks: Dict[Tuple[int, int, int, int],
                               Optional[np.ndarray]] = {}
        self._stuck: Dict[Tuple[int, int, int, int], bool] = {}
        self._dev_events: Dict[int, int] = {}
        self._fail_after = dict(self.config.fail_device_after)

    def bind(self, metrics=None, tracer=None,
             data_rows: Optional[int] = None) -> None:
        """Attach observability sinks + geometry (runtime wiring)."""
        if metrics is not None:
            self.metrics = metrics
        if tracer is not None:
            self.tracer = tracer
        if data_rows is not None:
            self.data_rows = data_rows

    # -- deterministic sampling ----------------------------------------------

    def _rng(self, *key: int) -> np.random.Generator:
        return np.random.default_rng((self.config.seed,) + tuple(key))

    @property
    def weak_rate(self) -> float:
        """Per-bit weak-cell density: explicit override, else the
        calibrated analog failure rate for the configured variation."""
        if self._weak_rate is None:
            cfg = self.config
            if cfg.weak_bit_rate is not None:
                self._weak_rate = float(cfg.weak_bit_rate)
            elif cfg.variation > 0.0:
                from ..core.analog import tra_failure_rate
                self._weak_rate = float(tra_failure_rate(
                    cfg.variation, n_trials=cfg.analog_trials,
                    seed=cfg.seed))
            else:
                self._weak_rate = 0.0
        return self._weak_rate

    def weak_mask(self, device: int, slot: Tuple[int, int, int],
                  words: int) -> Optional[np.ndarray]:
        """The slot's weak-cell XOR mask (None when clean). Sampled once
        per slot from a structural key and cached: the same cells stay
        weak for the life of the run."""
        key = (device,) + tuple(slot)
        if key not in self._weak_masks:
            rate = self.weak_rate
            mask = None
            if rate > 0.0:
                bits = self._rng(1, *key).random(words * 64) < rate
                if bits.any():
                    mask = np.packbits(
                        bits, bitorder="little").view(np.uint64).copy()
            self._weak_masks[key] = mask
        return self._weak_masks[key]

    def row_stuck(self, device: int, slot: Tuple[int, int, int]) -> bool:
        """Persistent per-row stuck-at fault (guard band excluded)."""
        if self.config.stuck_row_rate <= 0.0:
            return False
        key = (device,) + tuple(slot)
        if key not in self._stuck:
            guard = (self.data_rows is not None
                     and slot[2] >= self.data_rows - STUCK_GUARD_ROWS)
            self._stuck[key] = bool(
                not guard
                and self._rng(2, *key).random()
                < self.config.stuck_row_rate)
        return self._stuck[key]

    def _flip_one_bit(self, row: np.ndarray, tag: int, device: int,
                      bank: int, n: int) -> np.ndarray:
        bit = int(self._rng(tag, device, bank, n).integers(0, row.size * 64))
        out = row.copy()
        out[bit >> 6] ^= np.uint64(1) << np.uint64(bit & 63)
        return out

    # -- fault ledger ---------------------------------------------------------

    def record(self, kind: str, device: int, detail: str) -> None:
        self.events.append(f"{kind} dev={device} {detail}")
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if self.metrics is not None:
            self.metrics.counter("fault_injected").inc(1, kind=kind)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(("faults", f"device{device}"), kind,
                                "fault", args={"detail": detail})

    def note(self, line: str) -> None:
        """Recovery-side ledger line (scrub/quarantine/evacuation):
        recorded alongside injected faults so the determinism diff
        covers the *response*, not just the stimulus."""
        self.events.append(line)

    # -- device lifetime ------------------------------------------------------

    def check_alive(self, device: int) -> None:
        if device in self.dead:
            raise DeviceLostError(f"device {device} is offline",
                                  device=device)

    def fail_device(self, device: int) -> None:
        """Take a device offline permanently (manual or scheduled)."""
        if device not in self.dead:
            self.dead.add(device)
            self.record("device_lost", device, "offline")

    def _tick(self, device: int) -> None:
        n = self._dev_events.get(device, 0) + 1
        self._dev_events[device] = n
        after = self._fail_after.get(device)
        if after is not None and n >= after and device not in self.dead:
            self.fail_device(device)
            raise DeviceLostError(
                f"device {device} failed at event {n}", device=device)

    # -- simulator hooks ------------------------------------------------------

    def on_compute_write(self, device: int, slot: Tuple[int, int, int],
                         row: np.ndarray) -> np.ndarray:
        """A computed (TRA-result) row is about to be written into
        ``slot``. Returns the possibly-corrupted row; raises for
        persistent faults / device loss."""
        self.check_alive(device)
        self._tick(device)
        slot = tuple(slot)
        if self.row_stuck(device, slot):
            self.record("stuck_row", device, f"slot={slot} op=compute")
            raise FaultError(f"stuck row at dev{device} {slot}",
                             kind="stuck_row", device=device, slot=slot)
        out = row
        mask = self.weak_mask(device, slot, row.size)
        if mask is not None:
            out = out ^ mask
            self.record("weak_cell", device,
                        f"slot={slot} bits={int(np.unpackbits(mask.view(np.uint8)).sum())}")
        if self.config.transient_rate > 0.0:
            n = self._dev_events[device]
            if self._rng(3, device, slot[0], n).random() \
                    < self.config.transient_rate:
                out = self._flip_one_bit(out, 4, device, slot[0], n)
                self.record("transient", device, f"slot={slot}")
        return out

    def on_transfer(self, device: int, slot: Tuple[int, int, int],
                    row: np.ndarray) -> np.ndarray:
        """A RowClone/migration just landed a row at ``slot`` on
        ``device``. Returns the possibly-corrupted destination row;
        raises when the destination row is hard-stuck (write-verify)."""
        self.check_alive(device)
        self._tick(device)
        slot = tuple(slot)
        if self.row_stuck(device, slot):
            self.record("stuck_row", device, f"slot={slot} op=transfer")
            raise FaultError(f"stuck row at dev{device} {slot}",
                             kind="stuck_row", device=device, slot=slot)
        out = row
        if self.config.transfer_flip_rate > 0.0:
            n = self._dev_events[device]
            if self._rng(5, device, slot[0], n).random() \
                    < self.config.transfer_flip_rate:
                out = self._flip_one_bit(out, 6, device, slot[0], n)
                self.record("transfer_flip", device, f"slot={slot}")
        return out

    def ledger(self) -> str:
        """Execution-ordered fault/recovery ledger (CI byte-diffs it)."""
        return "; ".join(self.events)


def _new_acc() -> dict:
    """Per-query cost accumulator threaded through retries: every
    attempt's DRAM work lands here whether or not the attempt (or even
    the query) succeeds - failed work is still work the ledgers own."""
    return {"stats": OpStats(), "res_ns": {}, "channel": 0.0,
            "backoff": 0.0, "retries": 0}


class ReliabilityManager:
    """Detection + recovery around a planner (see module docstring).

    The scheduler routes ticket execution through
    :meth:`execute_ticket`; ``AmbitRuntime.eval`` routes through
    :meth:`run_query`. Both share :meth:`run_plan`'s bounded
    retry/quarantine loop and the protected (TMR) execution path.
    """

    #: parity/scrub rounds before a protected query is declared failed.
    MAX_SCRUB_ROUNDS = 3

    def __init__(self, store, planner, injector: Optional[FaultInjector]
                 = None, max_retries: int = 3, backoff_ns: float = 2000.0,
                 cluster=None):
        self.store = store
        self.planner = planner
        self.injector = injector
        self.max_retries = max_retries
        self.backoff_ns = backoff_ns
        self.cluster = cluster

    @property
    def metrics(self):
        return getattr(self.store, "metrics", None)

    @property
    def tracer(self):
        return getattr(self.store, "tracer", None)

    # -- retry loop -----------------------------------------------------------

    def run_plan(self, expression, env, out_name=None, acc=None):
        """``planner.execute`` with bounded retry. Persistent-fault
        sites are quarantined between attempts so re-placement moves
        away from them; device loss triggers cluster evacuation. Raises
        the last ``FaultError`` when recovery is impossible (data loss,
        single-device loss, retries exhausted)."""
        acc = _new_acc() if acc is None else acc
        attempt = 0
        while True:
            try:
                res = self.planner.execute(expression, env,
                                           out_name=out_name)
            except FaultError as e:
                self._absorb(acc)
                if e.kind == "data_loss":
                    raise
                recovered = True
                if isinstance(e, DeviceLostError):
                    recovered = self._recover_device(e)
                else:
                    self._quarantine(e)
                attempt += 1
                acc["retries"] += 1
                if self.metrics is not None:
                    self.metrics.counter("ticket_retries").inc(
                        1, reason=e.kind)
                if not recovered or attempt > self.max_retries:
                    raise
                acc["backoff"] += self.backoff_ns * (2.0 ** (attempt - 1))
                self._refault(env)
                continue
            self._absorb(acc)
            return res

    def _absorb(self, acc: dict) -> None:
        """Fold the planner's last report - partial reports from failed
        attempts included - into the accumulator exactly once."""
        rep = getattr(self.planner, "last_report", None)
        if rep is None or getattr(rep, "_absorbed", False):
            return
        rep._absorbed = True
        acc["stats"].merge(rep.stats)
        for k, st in rep.per_bank.items():
            key = k if isinstance(k, tuple) else (0, k)
            acc["res_ns"][key] = acc["res_ns"].get(key, 0.0) + st.ns
        acc["channel"] += getattr(rep, "transfer_ns", 0.0)

    def _quarantine(self, e: FaultError) -> None:
        if e.device is None or e.slot is None:
            return
        self._quarantine_slot(e.device, e.slot)

    def _quarantine_slot(self, device: int, slot) -> None:
        """Retire a faulty row from its allocator so re-placement
        cannot land on it again. Scratch-zone rows (>= usable_rows) are
        device-managed, not allocator-owned, and are skipped."""
        alloc = self._allocator_for(device)
        if alloc is None:
            return
        slot = tuple(slot)
        if slot[2] >= alloc.usable_rows or alloc.is_live(slot) \
                or slot in alloc.quarantined_slots:
            return
        alloc.quarantine([slot])
        if self.metrics is not None:
            self.metrics.counter("quarantined_rows").inc(1)
        if self.injector is not None:
            self.injector.note(f"quarantine dev={device} slot={slot}")
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.instant(("faults", f"device{device}"), "quarantine",
                       "fault", args={"slot": list(slot)})

    def _allocator_for(self, device: int):
        if self.cluster is not None:
            allocs = getattr(self.cluster, "allocators", None)
            if allocs is not None and 0 <= device < len(allocs):
                return allocs[device]
            return None
        return getattr(self.store, "allocator", None)

    def _recover_device(self, e: DeviceLostError) -> bool:
        """Evacuate a lost device; recovery is possible iff survivors
        remain (a single-device runtime has none)."""
        cl = self.cluster
        if cl is None or e.device is None:
            return False
        if e.device not in cl.dead_devices:
            cl.evacuate_device(e.device)
            if self.metrics is not None:
                self.metrics.counter("devices_lost").inc(1)
            if self.injector is not None:
                self.injector.note(f"evacuate dev={e.device}")
        return len(cl.dead_devices) < cl.n_devices

    def _refault(self, env) -> None:
        """Bring evacuated/spilled operands back before a retry."""
        operands = list(env.values())
        for nm in sorted(env):
            self.store.ensure_resident(env[nm], protect=operands)

    # -- query entry points ---------------------------------------------------

    def run_query(self, expression, env, out_name=None, acc=None):
        """One query end to end: protected (TMR) execution when any
        operand is protected, plain retried execution otherwise."""
        acc = _new_acc() if acc is None else acc
        if any(getattr(v, "protected", False) for v in env.values()):
            return self._execute_protected(expression, env, out_name, acc)
        operands = list(env.values())
        for v in operands:
            self.store.ensure_resident(v, protect=operands)
        return self.run_plan(expression, env, out_name=out_name, acc=acc)

    def execute_ticket(self, sched, t) -> None:
        """Scheduler ticket execution with full recovery. Costs of
        failed attempts are committed to the ticket either way."""
        from .scheduler import DONE, Ticket
        store = sched.store
        env = {nm: (v.result if isinstance(v, Ticket) else v)
               for nm, v in t.env.items()}
        if t.out is not None and any(getattr(v, "protected", False)
                                     for v in env.values()):
            raise AmbitError(
                "out= rebind is not supported for TMR-protected queries")
        up0 = store.bytes_to_device
        rd0 = store.bytes_from_device
        acc = _new_acc()
        try:
            res = self.run_query(t.expression, env,
                                 out_name=t.out_name, acc=acc)
            t.result = store.rebind(t.out, res) if t.out is not None \
                else res
            sched._release_ticket_holds(t)
            t.state = DONE
        finally:
            t.stats.merge(acc["stats"])
            t.stats.bytes_touched += (store.bytes_to_device - up0) + \
                (store.bytes_from_device - rd0)
            for k, v in acc["res_ns"].items():
                t.resource_ns[k] = t.resource_ns.get(k, 0.0) + v
            t.channel_ns += acc["channel"]
            t.backoff_ns += acc["backoff"]
            t.retries += acc["retries"]

    # -- TMR-protected execution ----------------------------------------------

    def _execute_protected(self, expression, env, out_name, acc):
        """Execute replica-wise over three planes, parity-check the
        results through the planner (billed XOR queries), scrub
        divergences with native MAJ re-votes, and return the voted
        primary carrying two fresh replicas."""
        store = self.store
        names = sorted(env)
        planes = {}
        for nm in names:
            h = env[nm]
            reps = list(getattr(h, "replicas", None) or [])
            if getattr(h, "protected", False) and len(reps) == 2:
                planes[nm] = [h, reps[0], reps[1]]
            else:
                planes[nm] = [h, h, h]    # unprotected operand: reuse
        all_planes = [p for nm in names for p in dict.fromkeys(planes[nm])]
        results: List = []
        try:
            # A device can die *during* a plane pass, marking sibling
            # planes lost after the fact - so repair-then-execute is a
            # bounded loop, not a one-shot preamble.
            for attempt in range(3):
                for nm in names:
                    for h in dict.fromkeys(planes[nm]):
                        if getattr(h, "lost", False):
                            self._repair_plane(
                                h, [s for s in planes[nm] if s is not h])
                try:
                    for k in range(3):
                        env_k = {nm: planes[nm][k] for nm in names}
                        for nm in names:
                            store.ensure_resident(env_k[nm],
                                                  protect=all_planes)
                        results.append(
                            self.run_plan(expression, env_k, acc=acc))
                    self._parity_scrub(expression, results, acc)
                    for d_try in range(3):
                        try:
                            self._disperse(results, acc)
                            break
                        except FaultError as e:
                            if isinstance(e, DeviceLostError):
                                if not self._recover_device(e):
                                    raise
                            else:
                                self._quarantine(e)
                            if d_try == 2:
                                raise
                    break
                except FaultError as e:
                    # A device death mid-scrub can claim every
                    # (colocated) result plane at once: the inputs are
                    # still recoverable, so re-execute from them.
                    for r in results:
                        if r is not None and not getattr(r, "freed", True):
                            try:
                                store.free(r)
                            except AmbitError:
                                pass
                    del results[:]
                    if isinstance(e, DeviceLostError):
                        if not self._recover_device(e) or attempt == 2:
                            raise
                    elif e.kind != "data_loss" or attempt == 2:
                        raise
        except BaseException:
            for r in results:
                if r is not None and not getattr(r, "freed", True):
                    try:
                        store.free(r)
                    except AmbitError:
                        pass
            raise
        primary, r1, r2 = results
        primary.replicas = [r1, r2]
        primary.protected = True
        primary.name = out_name
        if self.metrics is not None:
            self.metrics.counter("protected_queries").inc(1)
        return primary

    def _parity_scrub(self, expression, results: List, acc) -> None:
        """Detect plane divergence with billed XOR parity queries; on
        mismatch re-vote all three planes through independent native
        MAJ queries (identical-corruption across independently-faulted
        planes is the one failure TMR cannot see). Bounded."""
        p0, p1, p2 = (E.Expr.var("p0"), E.Expr.var("p1"), E.Expr.var("p2"))
        for _ in range(self.MAX_SCRUB_ROUNDS + 1):
            x01 = self.run_plan(p0 ^ p1,
                                {"p0": results[0], "p1": results[1]},
                                acc=acc)
            x02 = self.run_plan(p0 ^ p2,
                                {"p0": results[0], "p2": results[2]},
                                acc=acc)
            r01 = self._raw_rows(x01)
            r02 = self._raw_rows(x02)
            bad = bool(r01.any()) or bool(r02.any())
            # Parity-result rows can themselves sit on weak cells; grab
            # their slots before free() so they can be quarantined
            # rather than recycled into the next round.
            par_slots = [self._slot_of(h, i)
                         for h, raw in ((x01, r01), (x02, r02))
                         for i in np.nonzero(raw.any(axis=1))[0]]
            self.store.free(x01)
            self.store.free(x02)
            if self.metrics is not None:
                self.metrics.counter("parity_checks").inc(1)
            if not bad:
                return
            rows = [self._raw_rows(r) for r in results]
            vote = (rows[0] & rows[1]) | (rows[1] & rows[2]) \
                | (rows[0] & rows[2])
            diverged = [(k, i) for k in range(3)
                        for i in range(vote.shape[0])
                        if bool((rows[k][i] != vote[i]).any())]
            if not diverged:
                # Planes agree: the mismatch came from the parity
                # query's own destination rows. Retire them and
                # re-check.
                for dev, slot in par_slots:
                    self._quarantine_slot(dev, slot)
                continue
            corrections = int(sum(
                np.unpackbits((r ^ vote).view(np.uint8)).sum()
                for r in rows))
            if self.metrics is not None:
                self.metrics.counter("scrub_corrections").inc(corrections)
                self.metrics.counter("fault_scrubs").inc(1)
            if self.injector is not None:
                self.injector.note(f"scrub corrections={corrections}")
            tr = self.tracer
            if tr is not None and tr.enabled:
                tr.instant(("faults", "scrub"), "scrub", "fault",
                           args={"corrections": corrections})
            env3 = {"p0": results[0], "p1": results[1], "p2": results[2]}
            fresh = [self.run_plan(E.maj(p0, p1, p2), env3, acc=acc)
                     for _ in range(3)]
            bad_slots = {self._slot_of(results[k], i) for k, i in diverged}
            for r in results:
                self.store.free(r)
            for dev, slot in sorted(bad_slots | set(par_slots)):
                self._quarantine_slot(dev, slot)
            results[:] = fresh
        # Query-based re-votes keep racing fresh transient flips; fall
        # back to the controller's authoritative scrub: write the voted
        # rows straight back into the planes (write-verified).
        self._writeback_vote(results)

    def _writeback_vote(self, results: List) -> None:
        """Majority-vote the planes on the host (free write-verify
        telemetry) and write the vote back into every diverging row.
        Raises ``scrub_failed`` when even write-back cannot stabilize
        the planes (e.g. a pathological transfer-flip rate)."""
        rows = [self._raw_rows(r) for r in results]
        vote = (rows[0] & rows[1]) | (rows[1] & rows[2]) \
            | (rows[0] & rows[2])
        inj = self.injector
        total = 0
        for _ in range(self.MAX_SCRUB_ROUNDS + 1):
            dirty = 0
            for r in results:
                cur = self._raw_rows(r)
                for i in np.nonzero((cur != vote).any(axis=1))[0]:
                    dev, slot = self._slot_of(r, int(i))
                    device = (self.cluster.devices[dev]
                              if self.cluster is not None
                              else self.store.device)
                    out = vote[int(i)].copy()
                    device.write([slot], out.reshape(1, -1))
                    if inj is not None:
                        got = inj.on_transfer(dev, slot, out)
                        if not np.array_equal(got, out):
                            device.write([slot], got.reshape(1, -1))
                    dirty += 1
            total += dirty
            if dirty == 0:
                if total:
                    if self.metrics is not None:
                        self.metrics.counter(
                            "scrub_writeback_rows").inc(total)
                    if inj is not None:
                        inj.note(f"scrub writeback rows={total}")
                return
        raise FaultError("TMR scrub failed to converge", kind="scrub_failed")

    def _disperse(self, results: List, acc) -> None:
        """Parity/scrub queries colocate the three result planes onto
        the same devices, which would let a single device loss claim
        every copy of a chunk. Re-rotate the replica planes across the
        alive devices (billed inter-device migrations)."""
        cl = self.cluster
        if cl is None:
            return
        alive = [d for d in range(cl.n_devices) if d not in cl.dead_devices]
        if len(alive) < 2:
            return
        led = cl.ledger
        ns0, nj0, by0 = (led.inter_device_ns, led.inter_device_nj,
                         led.inter_device_bytes)
        primary = results[0]
        moved = 0
        old_flight = cl._in_flight
        cl._in_flight = tuple(results)
        try:
            for k, rep in enumerate(results[1:], start=1):
                for i, ds in enumerate(primary.slots):
                    if ds is None or rep.slots[i] is None:
                        continue          # lost chunk: repaired on next use
                    base = alive.index(ds[0]) if ds[0] in alive else 0
                    target = alive[(base + k) % len(alive)]
                    if rep.slots[i][0] != target:
                        moved += cl._migrate_chunk(
                            [rep], i, [rep.slots[i][0]], target)
        finally:
            cl._in_flight = old_flight
            dns = led.inter_device_ns - ns0
            acc["stats"].ns += dns
            acc["stats"].channel_ns += dns
            acc["stats"].channel_bytes += led.inter_device_bytes - by0
            acc["stats"].energy_nj += led.inter_device_nj - nj0
            acc["channel"] += dns
            if moved and self.metrics is not None:
                self.metrics.counter("tmr_disperse_rows").inc(moved)

    def _slot_of(self, h, i: int) -> Tuple[int, Tuple[int, int, int]]:
        """(device, slot) of a fully-resident handle's chunk ``i``."""
        ds = h.slots[int(i)]
        if getattr(self.store, "devices", None) is not None:
            return (ds[0], tuple(ds[1]))
        return (0, tuple(ds))

    def _raw_rows(self, h) -> np.ndarray:
        """Raw device rows of a fully-resident handle - free telemetry
        (the zero-test a controller's write-verify gives you), never a
        billed channel transfer."""
        store = self.store
        devices = getattr(store, "devices", None)
        if devices is not None:          # cluster handle
            words = store.words
            out = np.empty((h.n_slots, words), dtype=np.uint64)
            by_dev: Dict[int, List[int]] = {}
            for i, ds in enumerate(h.slots):
                by_dev.setdefault(ds[0], []).append(i)
            for d in sorted(by_dev):
                idxs = by_dev[d]
                out[idxs] = devices[d].read([h.slots[i][1] for i in idxs])
            return out
        return np.asarray(store.device.read(h.slots))

    def _repair_plane(self, h, siblings: List) -> None:
        """Rebuild a lost protected plane chunk-by-chunk from surviving
        siblings via on-device RowClone (billed through the device
        ledger). Chunks no sibling still holds stay lost."""
        cl = self.cluster
        if cl is None or not getattr(h, "slots", None):
            return
        repaired = 0
        for i, ds in enumerate(h.slots):
            if ds is not None or i in h._stash:
                continue
            if not h.dirty and h._host is not None:
                continue                  # host shadow will fault it in
            src = next((s for s in siblings
                        if getattr(s, "slots", None)
                        and i < len(s.slots)
                        and s.slots[i] is not None), None)
            if src is None:
                continue
            sd, sslot = src.slots[i]
            (new,) = cl._alloc_on(sd, 1, protect=[h] + siblings)
            try:
                cl.devices[sd].migrate_row(sslot, new)
            except AmbitError:
                cl.allocators[sd].free([new])
                raise
            h.slots[i] = (sd, new)
            repaired += 1
        if repaired and self.metrics is not None:
            self.metrics.counter("fault_repaired_chunks").inc(repaired)
        if repaired and self.injector is not None:
            self.injector.note(f"repair plane chunks={repaired}")
        if all(ds is not None or i in h._stash
               or (not h.dirty and h._host is not None)
               for i, ds in enumerate(h.slots)):
            h.lost = False
