"""PIM runtime: resident bitvectors, row allocation and placement-aware
query planning over the Ambit device model.

  RowAllocator                 - free-list (bank, subarray, row) allocation
  PimStore / ResidentBitVector - bitvectors living in simulated DRAM
  QueryPlanner                 - whole-Expr batched AAP scheduling
  AmbitRuntime                 - the session API applications use
"""

from .allocator import COLOCATED, POLICIES, RowAllocator, STRIPED, Slot
from .planner import PlanReport, QueryPlanner
from .runtime import AmbitRuntime
from .store import PimStore, ResidentBitVector

__all__ = [
    "AmbitRuntime", "COLOCATED", "PimStore", "PlanReport", "POLICIES",
    "QueryPlanner", "ResidentBitVector", "RowAllocator", "STRIPED", "Slot",
]
