"""PIM runtime: resident bitvectors, row allocation and placement-aware
query planning over the Ambit device model.

  RowAllocator                 - free-list (bank, subarray, row) allocation
  PimStore / ResidentBitVector - bitvectors living in simulated DRAM
                                 (LRU spill/eviction when the device fills)
  QueryPlanner                 - whole-Expr batched AAP scheduling
  PimCluster / ClusterBitVector- N devices behind one store API: sharded
                                 placement, channel cost model, cross-device
                                 colocation, per-device sub-plans
  AsyncScheduler / Ticket      - submit/drain queue packing bank/device-
                                 disjoint queries into concurrent epochs
  DeviceStore / DeviceBitVector- the accelerator twin of PimStore: jax
                                 device arrays resident across calls,
                                 fused (stacked) dispatch per epoch
  AmbitRuntime                 - the session API applications use
                                 (devices=N shards across a cluster;
                                 backend="jnp"/"pallas" runs resident on
                                 the accelerator)
"""

from .allocator import COLOCATED, POLICIES, RowAllocator, STRIPED, Slot
from .cluster import (AFFINITY, ChannelLedger, ChannelModel, CLUSTER_POLICIES,
                      ClusterBitVector, ClusterPlanner, ClusterReport,
                      PACKED, PimCluster, ROUND_ROBIN)
from .device_store import DeviceBitVector, DevicePlanner, DeviceStore
from .planner import PlanReport, QueryPlanner
from .runtime import AmbitRuntime
from .scheduler import (AsyncScheduler, DrainReport, EpochReport, Ticket)
from .store import PimStore, ResidentBitVector

__all__ = [
    "AFFINITY", "AmbitRuntime", "AsyncScheduler", "COLOCATED",
    "ChannelLedger", "ChannelModel", "CLUSTER_POLICIES", "ClusterBitVector",
    "ClusterPlanner", "ClusterReport", "DeviceBitVector", "DevicePlanner",
    "DeviceStore", "DrainReport", "EpochReport",
    "PACKED", "PimCluster", "PimStore", "PlanReport", "POLICIES",
    "QueryPlanner", "ResidentBitVector", "ROUND_ROBIN", "RowAllocator",
    "STRIPED", "Slot", "Ticket",
]
