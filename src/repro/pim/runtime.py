"""AmbitRuntime: the session API applications call instead of raw
``engine.eval``.

A runtime owns one simulated device (or, with ``devices > 1``, a
``PimCluster`` of them), a RowAllocator per device, a PimStore-compatible
store and a planner, and exposes the put / eval / get / free lifecycle:

    rt = AmbitRuntime(banks=4, subarrays=4, words=64)
    a, b = rt.put(bv_a), rt.put(bv_b)
    acc = rt.and_(a, b)            # stays in DRAM - no host read-back
    acc = rt.xor(acc, a)           # chains stay resident
    result = rt.get(acc)           # the only host transfer
    rt.free(acc)

Multi-device sessions shard every bitvector across the cluster
(``placement=`` picks round_robin / packed / affinity) and lower each
expression as per-device sub-plans with explicit, measured inter-device
transfers when operands span shards:

    rt = AmbitRuntime(devices=4, placement="round_robin")

Per-call DRAM cost lands in ``last_stats`` (time = max over banks - and,
sharded, max over devices plus serialized channel time; energy and AAPs
summed); ``session_stats`` accumulates across the session, and
``bytes_touched`` counts only genuine host<->device transfers, so a
resident chain's ledger shows exactly the data-movement win the paper is
about. Spilled operands (LRU eviction on a full device) fault back in
transparently at eval time; the re-upload is charged to the call.
"""

from __future__ import annotations

import os
from typing import Dict, Optional

from ..core import expr as E
from ..core.bitvector import BitVector
from ..core.engine import OpStats, binop_expr
from ..core.geometry import DEFAULT_GEOMETRY, DRAMGeometry
from ..core.simulator import AmbitDevice, AmbitError
from ..core.timing import DEFAULT_TIMING, TimingParams
from ..obs import NULL_TRACER, MetricsRegistry, Tracer
from .allocator import STRIPED
from .cluster import (ChannelModel, ClusterBitVector, PimCluster,
                      ROUND_ROBIN)
from .device_store import DeviceBitVector, DevicePlanner, DeviceStore
from .faults import (FaultConfig, FaultInjector, ReliabilityManager,
                     _new_acc)
from .planner import QueryPlanner
from .scheduler import AsyncScheduler, DrainReport, Ticket
from .store import PimStore, ResidentBitVector


class AmbitRuntime:
    """Session API over one of three resident backends:

      * ``backend="ambit_sim"`` (default) - the DRAM device model:
        single device or a sharded ``PimCluster`` (``devices=N``).
      * ``backend="jnp"`` / ``"pallas"`` - the accelerator-resident
        ``DeviceStore``: operands live as jax device arrays, whole
        expressions run as one fused dispatch, and ``submit``/``drain``
        packs shape-compatible queries into ONE stacked kernel launch
        per epoch. ``capacity_bytes`` bounds device memory (LRU spill
        to host, exactly like the DRAM path's row budget).
    """

    def __init__(self, geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 banks: Optional[int] = None,
                 subarrays: Optional[int] = None,
                 words: Optional[int] = None,
                 policy: str = STRIPED, optimize: bool = True,
                 colocate: bool = True, scratch_rows: int = 4,
                 devices: int = 1, placement: str = ROUND_ROBIN,
                 channel: Optional[ChannelModel] = None,
                 seed: int = 0, backend: str = "ambit_sim",
                 capacity_bytes: Optional[int] = None,
                 pin_budget_bytes: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 fault_injector: Optional[FaultInjector] = None):
        if backend not in ("ambit_sim", "jnp", "pallas"):
            raise ValueError(backend)
        if fault_injector is not None and backend != "ambit_sim":
            raise ValueError(
                "fault injection models the DRAM device "
                "(backend='ambit_sim'); accelerator backends have no "
                "row-level fault surface")
        self.backend = backend
        if backend != "ambit_sim":
            if devices > 1:
                raise ValueError(
                    "devices>1 shards the DRAM model; the accelerator "
                    "store is one device (jax handles its own sharding)")
            self.cluster = None
            self.device = None
            self.allocator = None
            self.store = DeviceStore(backend=backend,
                                     capacity_bytes=capacity_bytes)
            self.planner = DevicePlanner(self.store)
            self._handle_type = DeviceBitVector
        elif devices > 1:
            self.cluster = PimCluster(
                devices, geometry, timing, banks=banks,
                subarrays=subarrays, words=words, placement=placement,
                channel=channel, policy=policy, scratch_rows=scratch_rows,
                optimize=optimize, colocate=colocate, seed=seed)
            self.store = self.cluster
            self.device = self.cluster.devices[0]
            self.allocator = None       # per-device: cluster.allocators
            self.planner = self.cluster.planner
            self._handle_type = ClusterBitVector
        else:
            self.cluster = None
            self.device = AmbitDevice(geometry, timing, banks=banks,
                                      subarrays=subarrays, words=words,
                                      seed=seed)
            self.store = PimStore(self.device, policy=policy,
                                  scratch_rows=scratch_rows)
            self.allocator = self.store.allocator
            self.planner = QueryPlanner(self.store, optimize=optimize,
                                        colocate=colocate)
            self._handle_type = ResidentBitVector
        self.store.pin_budget_bytes = pin_budget_bytes
        self.scheduler = AsyncScheduler(self.store, self.planner,
                                        self._handle_type)
        self.session_stats = OpStats()
        self.last_stats: Optional[OpStats] = None
        # Observability (repro.obs): the store owns the session's
        # MetricsRegistry (its IO sites charge it unconditionally - see
        # LruSpillBase._charge_io); a caller-supplied registry replaces
        # it, and a live tracer is threaded through every layer. The
        # disabled NULL_TRACER default makes untraced runs record
        # nothing at zero cost.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if metrics is not None:
            self.store.metrics = metrics
        self.metrics = self.store.metrics
        self.store.tracer = self.tracer
        if self.cluster is not None:
            for d, dev in enumerate(self.cluster.devices):
                dev.tracer = self.tracer
                dev.trace_name = f"device{d}"
        elif self.device is not None:
            self.device.tracer = self.tracer
        # Reliability (repro.pim.faults): an explicit injector, or the
        # chaos-CI env hook PIM_CHAOS_RATE / PIM_CHAOS_SEED. The env
        # hook injects stuck rows ONLY - detectable, positional,
        # deterministically recoverable faults - so a chaos run's
        # results stay bit-exact with the fault-free suite while every
        # retry/quarantine path gets exercised.
        self.fault_injector = fault_injector
        if backend == "ambit_sim" and self.fault_injector is None:
            rate = float(os.environ.get("PIM_CHAOS_RATE", "0") or 0)
            if rate > 0.0:
                self.fault_injector = FaultInjector(FaultConfig(
                    seed=int(os.environ.get("PIM_CHAOS_SEED", "0") or 0),
                    stuck_row_rate=rate))
        self.reliability: Optional[ReliabilityManager] = None
        if backend == "ambit_sim":
            inj = self.fault_injector
            if inj is not None:
                inj.bind(metrics=self.metrics, tracer=self.tracer,
                         data_rows=self.device.geom.data_rows)
                if self.cluster is not None:
                    for d, dev in enumerate(self.cluster.devices):
                        dev.fault_injector = inj
                        dev.device_index = d
                else:
                    self.device.fault_injector = inj
                    self.device.device_index = 0
            self.reliability = ReliabilityManager(
                self.store, self.planner, injector=inj,
                cluster=self.cluster)
            self.scheduler.reliability = self.reliability
        # Session-simulated clock: advanced by every call's modeled ns.
        self.clock_ns = 0.0

    # -- lifecycle -----------------------------------------------------------

    def put(self, bv: BitVector, name: Optional[str] = None,
            near=None, pin: bool = False, protect: bool = False):
        """Upload a bitvector. ``protect=True`` stores it TMR-encoded
        (three independently-placed planes, Section 5.5): queries over
        it execute replica-wise with parity checks and majority-vote
        scrubbing - 3x the storage and upload bytes, billed honestly."""
        up0 = self.store.bytes_to_device
        rd0 = self.store.bytes_from_device
        kwargs = {}
        if protect:
            if self.backend != "ambit_sim":
                raise ValueError(
                    "protect=True (TMR planes) requires backend="
                    "'ambit_sim' - the accelerator stores have no "
                    "row-level fault model to protect against")
            kwargs["protect"] = True
        rbv = self.store.put(bv, near=near, name=name, pin=pin, **kwargs)
        # Upload bytes for every plane, plus read-backs of dirty victims
        # a full device LRU-spilled to make room: all this call's traffic.
        self._account(OpStats(
            bytes_touched=(self.store.bytes_to_device - up0)
            + (self.store.bytes_from_device - rd0)))
        return rbv

    def get(self, rbv) -> BitVector:
        before = self.store.bytes_from_device
        out = self.store.get(rbv)
        # Only what actually crossed the channel (zero for clean/spilled
        # handles; a partially spilled dirty handle reads just its
        # still-resident chunks).
        self._account(OpStats(
            bytes_touched=self.store.bytes_from_device - before))
        return out

    def free(self, rbv) -> None:
        self.store.free(rbv)

    def pin(self, rbv) -> None:
        """Exempt a resident handle from LRU eviction, charged against
        the store's pin budget (``pin_budget_bytes``)."""
        self.store.pin(rbv)

    def unpin(self, rbv) -> None:
        self.store.unpin(rbv)

    # -- evaluation ----------------------------------------------------------

    def eval(self, expression: E.Expr, env: Dict[str, object],
             out_name: Optional[str] = None, out=None):
        """Evaluate a whole expression tree over resident operands. The
        result is a new resident bitvector; nothing crosses the channel
        except fault-ins of previously spilled operands. ``out=`` rebinds
        the result into an existing handle in place (on the accelerator
        backends the destination's buffer is donated to XLA, so chained
        queries update storage without allocation churn)."""
        for nm, v in env.items():
            if not isinstance(v, self._handle_type):
                raise TypeError(
                    f"operand {nm!r} is not resident - call put() first "
                    "(the host path is BulkBitwiseEngine.eval)")
        if out is not None and not isinstance(out, self._handle_type):
            raise TypeError("out= must be an existing resident handle")
        operands = list(env.values())
        up_before = self.store.bytes_to_device
        rd_before = self.store.bytes_from_device
        if self.reliability is not None:
            # Full recovery path: bounded retry + quarantine on injected
            # faults, replica-wise TMR execution for protected operands.
            # Failed attempts' DRAM work is accounted even when the
            # query ultimately raises - the ledgers own failed work too.
            if out is not None and any(getattr(v, "protected", False)
                                       for v in operands):
                raise AmbitError(
                    "out= rebind is not supported for TMR-protected "
                    "queries (the planes' storage moves as a set)")
            acc = _new_acc()
            try:
                res = self.reliability.run_query(expression, env,
                                                 out_name=out_name,
                                                 acc=acc)
            finally:
                st = OpStats()
                st.merge(acc["stats"])
                st.bytes_touched += \
                    (self.store.bytes_to_device - up_before) + \
                    (self.store.bytes_from_device - rd_before)
                self._account(st)
            return self.store.rebind(out, res) if out is not None else res
        for v in operands:
            self.store.ensure_resident(v, protect=operands)
        kwargs = {}
        if out is not None and isinstance(self.planner, DevicePlanner) \
                and any(v is out for v in operands):
            kwargs["donate_to"] = out
        res = self.planner.execute(expression, env, out_name=out_name,
                                   **kwargs)
        st = OpStats()
        st += self.planner.last_report.stats
        # Fault-ins (and any spill read-backs they forced) are host
        # traffic this call caused: charge them here.
        st.bytes_touched += (self.store.bytes_to_device - up_before) + \
            (self.store.bytes_from_device - rd_before)
        self._account(st)
        return self.store.rebind(out, res) if out is not None else res

    # -- async multi-query sessions -------------------------------------------

    def submit(self, expression: E.Expr, env: Dict[str, object],
               out=None, out_name: Optional[str] = None,
               now_ns: float = 0.0) -> Ticket:
        """Enqueue a query for the next ``drain``. Operands are resident
        handles or tickets of earlier submits (multi-root DAGs execute in
        one drain); queued operands are protected from eviction until
        their query runs. ``now_ns`` stamps the ticket on the caller's
        simulated clock. Returns the query's Ticket."""
        for nm, v in env.items():
            if not isinstance(v, (self._handle_type, Ticket)):
                raise TypeError(
                    f"operand {nm!r} is not resident - call put() first "
                    "(the host path is BulkBitwiseEngine.eval)")
        return self.scheduler.submit(expression, env, out=out,
                                     out_name=out_name, now_ns=now_ns)

    def drain(self, now_ns: float = 0.0, epoch_cost=None,
              refresh: bool = False, optimize: bool = False):
        """Execute every queued query, overlapping bank/device-disjoint
        queries in epochs. Returns the tickets in submit order; the
        drain's combined cost (sum of epoch maxima, summed energy/AAPs,
        fault-in bytes) lands in ``last_stats`` / ``session_stats``.
        ``now_ns``/``epoch_cost`` lay the epochs on a simulated clock
        (per-ticket ``started_ns``/``finished_ns``) for serving
        frontends; ``refresh=True`` pauses that timeline through DRAM
        refresh windows; ``optimize=True`` runs the cost-based query
        optimizer (cross-ticket CSE + result cache, bit-identical
        results) - see ``AsyncScheduler.drain``. NOTE: distinct from
        this runtime's constructor flag ``optimize=``, which controls
        the per-program AAP peephole inside the planner."""
        tickets = self.scheduler.drain(now_ns=now_ns,
                                       epoch_cost=epoch_cost,
                                       refresh=refresh,
                                       optimize=optimize)
        if tickets:
            st = OpStats()
            st += self.scheduler.last_drain.stats
            self._account(st)
        return tickets

    @property
    def last_drain(self) -> Optional[DrainReport]:
        return self.scheduler.last_drain

    def _binop(self, op: str, a, b):
        return self.eval(binop_expr(op), {"a": a, "b": b})

    def and_(self, a, b):
        return self._binop("and", a, b)

    def or_(self, a, b):
        return self._binop("or", a, b)

    def xor(self, a, b):
        return self._binop("xor", a, b)

    def nand(self, a, b):
        return self._binop("nand", a, b)

    def nor(self, a, b):
        return self._binop("nor", a, b)

    def xnor(self, a, b):
        return self._binop("xnor", a, b)

    def not_(self, a):
        return self.eval(~E.Expr.var("a"), {"a": a})

    def maj(self, a, b, c):
        return self.eval(E.maj(E.Expr.var("a"), E.Expr.var("b"),
                               E.Expr.var("c")), {"a": a, "b": b, "c": c})

    def popcount(self, rbv) -> int:
        """Count the set bits of a resident bitvector.

        On the accelerator backends the reduction runs device-side
        (pallas popcount kernel / ``lax.population_count``) and only the
        int32 total crosses the channel - ``bytes_touched`` charges 4
        bytes, not the whole array. The DRAM model has no reduction op
        (Section 9.1 future-op), so ``ambit_sim`` still reads the result
        back - the one transfer a resident query pays there."""
        if hasattr(self.store, "popcount"):
            before = self.store.bytes_from_device
            count = self.store.popcount(rbv)
            self._account(OpStats(
                bytes_touched=self.store.bytes_from_device - before))
            return count
        return int(self.get(rbv).popcount())

    # -- accounting ----------------------------------------------------------

    @property
    def host_reads(self) -> int:
        return self.store.host_reads

    @property
    def host_writes(self) -> int:
        return self.store.host_writes

    def _account(self, st: OpStats) -> None:
        self.last_stats = st
        self.session_stats += st
        self.clock_ns += st.ns
        m = self.metrics
        m.counter("runtime_calls").inc(1)
        m.counter("runtime_ns").inc(st.ns)
        m.counter("runtime_energy_nj").inc(st.energy_nj)
        m.counter("runtime_aaps").inc(st.aap_count)
        m.counter("runtime_bytes_touched").inc(st.bytes_touched)

    def metrics_snapshot(self) -> dict:
        """JSON-safe dump of the session's metrics registry."""
        return self.metrics.snapshot()
