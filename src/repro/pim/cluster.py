"""PimCluster: N Ambit devices behind one PimStore-compatible API.

A real deployment is a DIMM/rank hierarchy of many chips, not one
``AmbitDevice`` - and cross-device operand movement reintroduces exactly
the memory-channel traffic the paper eliminates (PAPER.md Section 8;
Buddy-RAM makes the same multi-bank/chip parallelism argument). The
cluster models that step:

  * ``ChannelModel`` - per-hop ns/byte + fixed latency for the three
    classes of movement: host<->device uploads/read-backs, inter-device
    transfers (devices sit on a linear chain; cost scales with hop
    count), and intra-device RowClone (charged by the device model
    itself via ``AmbitDevice.migrate_row``; the model exposes the figure
    for reference). Every transfer is *measured* - bytes come from rows
    actually moved, never from an analytic formula - and lands in the
    cluster's ``ChannelLedger`` and the per-call ``OpStats``.

  * placement policies - ``round_robin`` stripes chunks across devices
    (device-level parallelism: the planner reports max-over-devices
    time), ``packed`` fills one device before spilling to the next, and
    ``affinity`` co-shards operands that are used together: with
    ``near=`` it follows the neighbor's chunk->device layout exactly,
    without it the whole vector lands on the least-loaded device.

  * ``colocate`` - cross-device migration planner: for each chunk whose
    operands span devices it picks the cheapest migration direction from
    the channel model (minimum total link cost over candidate target
    devices) and moves the minority rows, so every op executes fully
    on-device.

  * ``ClusterPlanner`` - lowers ONE expression tree across shards:
    cross-device colocation first (explicit, measured transfer ops),
    then one per-device sub-plan through the existing ``QueryPlanner``
    (subarray batching, scratch staging, per-bank ledgers). Devices run
    independent chunk groups in parallel, so the reported time is the
    max over devices plus the serialized channel time; energy and AAP
    counts are summed.

LRU spill works at cluster scope exactly as it does on ``PimStore``: a
full device evicts the least-recently-used unpinned cluster handle that
owns rows on it (clean handles spill for free, dirty ones are read back
through the ledger first), and spilled handles fault back in via
``ensure_resident``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import expr as E
from ..core.engine import OpStats
from ..core.simulator import AmbitDevice, AmbitError
from ..core.geometry import DEFAULT_GEOMETRY, DRAMGeometry
from ..core.timing import DEFAULT_TIMING, CommandStats, TimingParams
from .allocator import STRIPED, Slot
from .faults import DeviceLostError
from .planner import QueryPlanner
from .store import (LruSpillBase, PimStore, ResidentBitVector, chunk_rows,
                    unchunk_rows)
from ..core.bitvector import BitVector

ROUND_ROBIN = "round_robin"
PACKED = "packed"
AFFINITY = "affinity"
CLUSTER_POLICIES = (ROUND_ROBIN, PACKED, AFFINITY)

DeviceSlot = Tuple[int, Slot]  # (device index, (bank, subarray, row))


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Per-hop cost model for data movement in the device hierarchy.

    Devices sit on a linear chain (device i <-> device i+1 is one hop), so
    an inter-device transfer costs ``fixed + hops * ns_per_byte * bytes``.
    Host transfers cross the memory channel once regardless of target.
    Intra-device RowClone is charged by ``AmbitDevice.migrate_row`` into
    the device ledger; ``intra_device_ns`` reproduces that figure so the
    three movement classes can be compared in one place."""

    host_ns_per_byte: float = 1.0 / 34.0     # ~34 GB/s host memory channel
    host_fixed_ns: float = 50.0
    link_ns_per_byte: float = 1.0 / 16.0     # ~16 GB/s inter-device hop
    link_fixed_ns: float = 100.0
    nj_per_byte: float = 0.0449              # ~46 nJ/KB channel energy

    def hops(self, src_dev: int, dst_dev: int) -> int:
        return abs(src_dev - dst_dev)

    def device_to_device_ns(self, src_dev: int, dst_dev: int,
                            nbytes: int) -> float:
        h = self.hops(src_dev, dst_dev)
        if h == 0:
            return 0.0
        return self.link_fixed_ns + h * self.link_ns_per_byte * nbytes

    def device_to_device_nj(self, src_dev: int, dst_dev: int,
                            nbytes: int) -> float:
        return self.hops(src_dev, dst_dev) * self.nj_per_byte * nbytes

    def host_transfer_ns(self, nbytes: int) -> float:
        return self.host_fixed_ns + self.host_ns_per_byte * nbytes

    def intra_device_ns(self, row_bytes: int,
                        timing: TimingParams = DEFAULT_TIMING) -> float:
        """RowClone-PSM row copy (mirrors AmbitBank.psm_copy accounting)."""
        from ..core.simulator import AmbitBank
        n_lines = row_bytes // 64
        return (2 * timing.tRAS + n_lines * AmbitBank.PSM_NS_PER_CACHELINE
                + timing.tRP)


DEFAULT_CHANNEL = ChannelModel()


@dataclasses.dataclass
class ChannelLedger:
    """Measured data-movement ledger for one cluster (bytes counted from
    rows actually transferred)."""

    host_writes: int = 0
    host_reads: int = 0
    host_to_device_bytes: int = 0
    device_to_host_bytes: int = 0
    host_ns: float = 0.0
    inter_device_rows: int = 0
    inter_device_bytes: int = 0
    inter_device_ns: float = 0.0
    inter_device_nj: float = 0.0

    def merge(self, other: "ChannelLedger") -> "ChannelLedger":
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


@dataclasses.dataclass(eq=False)
class ClusterBitVector:
    """Handle to a bitvector sharded across cluster devices.
    Handles compare (and hash) by identity.

    ``slots[i]`` is the ``(device, (bank, subarray, row))`` home of chunk
    ``i``; the chunk order is identical to ``ResidentBitVector.slots``
    (logical-row-major, chunk-minor), so ``near=other.slots`` aligns
    corresponding chunks across co-operating vectors.

    A slot of ``None`` marks a chunk that was *partially spilled* - a
    full device evicted only ITS chunks of this vector; the rest stayed
    hot. Spilled chunks of a dirty handle live in ``_stash`` (their
    device rows were read back through the ledger); clean ones are
    recoverable from the current host copy for free. ``ensure_resident``
    faults only the missing chunks back in."""

    cluster: "PimCluster"
    n_bits: int
    shape: Tuple[int, ...]
    words32: int
    chunks: int                  # device rows per logical row
    slots: List[Optional[DeviceSlot]]
    dirty: bool = False
    pinned: bool = False
    spilled: bool = False
    name: Optional[str] = None
    _host: Optional[BitVector] = None
    # chunk index -> (words,) uint64 row for dirty partially-spilled chunks
    _stash: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    # TMR protection (repro.pim.faults): a protected primary carries two
    # independently-placed replica planes; ``lost`` marks a handle whose
    # only copy of some chunk died with its device - every use short of
    # free/plane-repair raises a data-loss FaultError.
    protected: bool = False
    replicas: List["ClusterBitVector"] = dataclasses.field(
        default_factory=list)
    lost: bool = False

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    @property
    def live_chunks(self) -> List[int]:
        return [i for i, ds in enumerate(self.slots) if ds is not None]

    @property
    def partially_spilled(self) -> bool:
        return any(ds is None for ds in self.slots)

    @property
    def device_bytes(self) -> int:
        return self.n_slots * self.cluster.row_bytes

    @property
    def resident_bytes(self) -> int:
        return len(self.live_chunks) * self.cluster.row_bytes

    @property
    def devices(self) -> List[int]:
        return sorted({ds[0] for ds in self.slots if ds is not None})

    @property
    def freed(self) -> bool:
        return not self.slots and not self.spilled

    def get(self) -> BitVector:
        return self.cluster.get(self)

    def free(self) -> None:
        self.cluster.free(self)

    def __repr__(self):
        nm = f" {self.name!r}" if self.name else ""
        flags = (" pinned" if self.pinned else "") + \
            (" spilled" if self.spilled else "")
        return (f"<ClusterBitVector{nm} n_bits={self.n_bits} "
                f"slots={self.n_slots} devices={self.devices} "
                f"dirty={self.dirty}{flags}>")


class PimCluster(LruSpillBase):
    """N AmbitDevices behind one PimStore-compatible put/get/free API."""

    _handle_desc = "cluster bitvector"
    _obs_name = "cluster"

    def _charge_io(self, direction: str, cause: str, nbytes: int) -> None:
        """Cluster host IO additionally lands in the ChannelLedger with
        its modeled channel time - same single-site contract as the
        base: legacy counters, ledger, and metrics move together."""
        super()._charge_io(direction, cause, nbytes)
        hns = self.channel.host_transfer_ns(nbytes)
        if direction == "to_device":
            self.ledger.host_writes += 1
            self.ledger.host_to_device_bytes += nbytes
        else:
            self.ledger.host_reads += 1
            self.ledger.device_to_host_bytes += nbytes
        self.ledger.host_ns += hns
        self.metrics.counter("host_channel_ns").inc(hns)

    def __init__(self, devices: int = 2,
                 geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 banks: Optional[int] = None,
                 subarrays: Optional[int] = None,
                 words: Optional[int] = None,
                 placement: str = ROUND_ROBIN,
                 channel: Optional[ChannelModel] = None,
                 policy: str = STRIPED, scratch_rows: int = 4,
                 optimize: bool = True, colocate: bool = True,
                 seed: int = 0):
        if devices < 1:
            raise ValueError("need at least one device")
        if placement not in CLUSTER_POLICIES:
            raise ValueError(
                f"unknown placement {placement!r} (use {CLUSTER_POLICIES})")
        self.devices = [
            AmbitDevice(geometry, timing, banks=banks, subarrays=subarrays,
                        words=words, seed=seed + 7919 * d)
            for d in range(devices)]
        # Per-device stores share each device's allocator and give the
        # per-device QueryPlanners their staging/colocation machinery; the
        # cluster itself owns placement, the LRU and the channel ledger.
        self.stores = [PimStore(dev, policy=policy,
                                scratch_rows=scratch_rows)
                       for dev in self.devices]
        self.allocators = [st.allocator for st in self.stores]
        self.planners = [QueryPlanner(st, optimize=optimize,
                                      colocate=colocate)
                         for st in self.stores]
        self.planner = ClusterPlanner(self)
        self.placement = placement
        self.channel = channel or DEFAULT_CHANNEL
        self.ledger = ChannelLedger()
        self.words = self.devices[0].words
        self.row_bytes = self.devices[0].row_bytes
        # PimStore-compatible host-traffic counters.
        self.host_writes = 0
        self.host_reads = 0
        self.bytes_to_device = 0
        self.bytes_from_device = 0
        self._lru_init()
        # Devices taken offline by the reliability layer: excluded from
        # placement, guarded in _alloc_on, populated by evacuate_device.
        self.dead_devices: set = set()
        # Operands of an in-flight ClusterPlanner call: protected from
        # eviction for its duration (set by ClusterPlanner.execute).
        self._in_flight: Tuple[ClusterBitVector, ...] = ()
        # A full device during a per-device sub-plan must be able to
        # evict CLUSTER handles (they are registered here, not in the
        # per-device store LRUs): install the cluster-scope fallback.
        for d, st in enumerate(self.stores):
            st.spill_fallback = \
                (lambda d=d: self._evict_one(d, self._in_flight))

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def migrated_rows(self) -> int:
        """Intra-device subarray migrations (per-device store colocation)."""
        return sum(st.migrated_rows for st in self.stores)

    def total_stats(self) -> CommandStats:
        agg = CommandStats()
        for dev in self.devices:
            agg.merge(dev.total_stats())
        return agg

    # -- placement -----------------------------------------------------------

    def _place(self, n_chunks: int, placement: Optional[str],
               near: Optional[Sequence[DeviceSlot]],
               rotate: int = 0) -> List[int]:
        """chunk index -> device index, deterministically.

        Only devices still alive participate; ``rotate`` offsets the
        alive-device ordering so TMR replica planes shard onto staggered
        devices (chunk i of plane k lands k devices over - a single
        device loss then never takes out the same chunk of two planes).
        With no dead devices and ``rotate=0`` this reproduces the
        original placement exactly."""
        placement = self.placement if placement is None else placement
        if placement not in CLUSTER_POLICIES:
            raise ValueError(f"unknown placement {placement!r}")
        alive = [d for d in range(self.n_devices)
                 if d not in self.dead_devices]
        if not alive:
            raise DeviceLostError("every cluster device is offline")
        r = rotate % len(alive)
        alive = alive[r:] + alive[:r]
        if near is not None and len(near) == n_chunks and \
                all(ds is not None and ds[0] not in self.dead_devices
                    for ds in near):
            # chunk-aligned affinity: chunk k shares its neighbor's device
            return [d for d, _ in near]
        if placement == ROUND_ROBIN:
            return [alive[i % len(alive)] for i in range(n_chunks)]
        if placement == PACKED:
            free = {d: self.allocators[d].free_slots for d in alive}
            out = []
            for _ in range(n_chunks):
                d = next((i for i in alive if free[i] > 0), alive[0])
                free[d] -= 1
                out.append(d)
            return out
        # AFFINITY without a neighbor: whole vector on the least-loaded
        # device, so vectors put near= each other later share it.
        d = min(alive,
                key=lambda i: (self.allocators[i].utilization,
                               alive.index(i)))
        return [d] * n_chunks

    # -- LRU / eviction (machinery in LruSpillBase) ---------------------------
    # A full device evicts PARTIALLY: only the victim's chunks resident on
    # that device spill (the rest of the vector stays hot on its other
    # devices). Explicit ``spill`` still evicts the whole vector.

    def _owner_of(self, cbv: ClusterBitVector):
        return cbv.cluster

    def _check_fully_live(self, cbv) -> None:
        """Planner-side ops need every chunk on a device; ``spill`` and
        ``get`` remain legal on partially spilled handles."""
        self._check_live(cbv)
        if cbv.partially_spilled:
            raise AmbitError(
                f"device-side use of partially spilled {cbv!r} "
                "(ensure_resident faults the missing chunks back in)")

    def _release_rows(self, cbv: ClusterBitVector) -> None:
        by_dev: Dict[int, List[Slot]] = {}
        for ds in cbv.slots:
            if ds is not None:
                by_dev.setdefault(ds[0], []).append(ds[1])
        for d in sorted(by_dev):
            self.allocators[d].free(by_dev[d])
        cbv.slots = []
        cbv._stash.clear()

    def _evict_one(self, d: int,
                   protect: Iterable[ClusterBitVector]) -> bool:
        """Partial spill of the LRU unpinned handle owning rows on full
        device ``d``: only its device-``d`` chunks evict. Unheld victims
        first; a held (queued) operand spills only under capacity
        pressure and faults back in when its query executes."""
        return self._evict_lru(
            protect,
            want=lambda cbv: any(ds is not None and ds[0] == d
                                 for ds in cbv.slots),
            spill=lambda cbv, fh: self.spill_device(cbv, d,
                                                    _force_held=fh))

    def spill_device(self, cbv: ClusterBitVector, d: int,
                     _force_held: bool = False) -> None:
        """Evict only the chunks of ``cbv`` resident on device ``d``.
        Clean chunks cost zero ledger bytes (the host copy is current);
        dirty ones are read back - just those rows - through the ledger
        into the chunk stash. When every live chunk is on ``d`` this
        degenerates to a whole-vector ``spill``."""
        self._check_handle(cbv)
        if cbv.spilled:
            return                      # nothing resident anywhere
        if cbv.pinned:
            raise AmbitError(f"cannot spill pinned {cbv!r}")
        if self.is_held(cbv) and not _force_held:
            raise AmbitError(
                f"cannot spill {cbv!r}: a queued query still reads it")
        live = cbv.live_chunks
        idxs = [i for i in live if cbv.slots[i][0] == d]
        if not idxs:
            return                      # no rows on this device
        if len(idxs) == len(live):      # whole remainder lives on d
            self.spill(cbv, _force_held=_force_held)
            return
        if cbv.dirty or cbv._host is None:
            rows = self.devices[d].read([cbv.slots[i][1] for i in idxs])
            rows = rows.reshape(len(idxs), self.words)
            for k, i in enumerate(idxs):
                cbv._stash[i] = rows[k].copy()
            nbytes = len(idxs) * self.row_bytes
            self._charge_io("from_device", "spill", nbytes)
            self.evicted_dirty += 1
        else:
            self.evicted_clean += 1     # host copy current: free
        self.allocators[d].free([cbv.slots[i][1] for i in idxs])
        for i in idxs:
            cbv.slots[i] = None
        # still owns rows elsewhere: stays registered in the LRU

    def evacuate_device(self, d: int) -> None:
        """Take device ``d`` out of service after a whole-device failure.

        Every registered handle loses its device-``d`` chunks (their
        rows are gone - nothing is read back). Chunks with a current
        host/stash copy stay recoverable: ``ensure_resident`` faults
        them back in on the survivors for the usual ledger price. A
        dirty chunk whose only copy died marks the handle ``lost`` -
        only a TMR sibling repair (``_repair_plane``) or ``free`` may
        touch it again. Idempotent."""
        if d in self.dead_devices:
            return
        self.dead_devices.add(d)
        evacuated = 0
        for cbv in list(self._lru.values()):
            idxs = [i for i, ds in enumerate(cbv.slots)
                    if ds is not None and ds[0] == d]
            if not idxs:
                continue
            self.allocators[d].free([cbv.slots[i][1] for i in idxs])
            for i in idxs:
                cbv.slots[i] = None
            if (cbv.dirty or cbv._host is None) and \
                    any(i not in cbv._stash for i in idxs):
                cbv.lost = True
            evacuated += len(idxs)
            self._invalidate(cbv)   # placement changed: generation bumps
        if evacuated:
            self.metrics.counter("fault_evacuated_chunks").inc(evacuated)
        if self.tracer.enabled:
            self.tracer.instant(("faults", f"device{d}"), "evacuate",
                                "fault", args={"chunks": evacuated})

    def _alloc_on(self, d: int, n_rows: int,
                  near: Optional[Sequence[Slot]] = None,
                  protect: Iterable[ClusterBitVector] = ()) -> List[Slot]:
        if d in self.dead_devices:
            raise DeviceLostError(f"device {d} is offline", device=d)
        alloc = self.allocators[d]
        while alloc.shortfall(n_rows):
            if not self._evict_one(d, protect):
                raise AmbitError(
                    f"cluster device {d} full ({alloc.live}/"
                    f"{alloc.capacity} rows live) and every resident "
                    f"bitvector on it is pinned or in use")
        return alloc.alloc(n_rows, near=near)

    # -- lifecycle -----------------------------------------------------------

    def put(self, bv: BitVector, placement: Optional[str] = None,
            near: Optional[Sequence[DeviceSlot]] = None,
            name: Optional[str] = None,
            pin: bool = False, protect: bool = False,
            _rotate: int = 0) -> ClusterBitVector:
        chunks = chunk_rows(bv, self.words)
        if len(chunks) == 0:
            raise AmbitError("cannot make a zero-row bitvector resident")
        devmap = self._place(len(chunks), placement, near, rotate=_rotate)
        aligned = near is not None and len(near) == len(chunks)
        slots: List[Optional[DeviceSlot]] = [None] * len(chunks)
        try:
            for d in sorted(set(devmap)):
                idxs = [i for i, dd in enumerate(devmap) if dd == d]
                if aligned:
                    # chunk-aligned: each chunk lands in the subarray that
                    # holds the neighbor's corresponding chunk.
                    for i in idxs:
                        (s,) = self._alloc_on(d, 1, near=[near[i][1]])
                        slots[i] = (d, s)
                else:
                    got = self._alloc_on(d, len(idxs))
                    for i, s in zip(idxs, got):
                        slots[i] = (d, s)
                self.devices[d].write([slots[i][1] for i in idxs],
                                      chunks[idxs])
        except AmbitError:
            for ds in slots:
                if ds is not None:
                    self.allocators[ds[0]].free([ds[1]])
            raise
        data32 = np.asarray(bv.data, np.uint32)
        cbv = ClusterBitVector(
            cluster=self, n_bits=bv.n_bits, shape=data32.shape[:-1],
            words32=data32.shape[-1],
            chunks=len(chunks) // max(1, int(np.prod(data32.shape[:-1]))),
            slots=slots, dirty=False, name=name, _host=bv)
        self._charge_io("to_device", "upload", cbv.device_bytes)
        self._register(cbv)
        if pin:
            try:
                self.pin(cbv)
            except AmbitError:          # over budget: undo the upload
                self.free(cbv)
                raise
        if protect:
            # TMR encode-on-put: two more honestly-uploaded planes, each
            # sharded with a rotated chunk->device map so one device loss
            # never claims the same chunk of two planes (that chunk stays
            # repairable from a surviving sibling via _repair_plane).
            try:
                for k in (1, 2):
                    cbv.replicas.append(self.put(
                        bv, placement=placement, pin=pin,
                        name=f"{name}/plane{k}" if name else None,
                        _rotate=k))
            except AmbitError:
                self.free(cbv)
                raise
            cbv.protected = True
        return cbv

    def _read_back(self, cbv: ClusterBitVector) -> BitVector:
        rows = np.empty((cbv.n_slots, self.words), np.uint64)
        by_dev: Dict[int, List[int]] = {}
        for i, ds in enumerate(cbv.slots):
            if ds is None:              # partially spilled chunk: stashed
                rows[i] = cbv._stash[i]
                continue
            by_dev.setdefault(ds[0], []).append(i)
        for d in sorted(by_dev):
            idxs = by_dev[d]
            rows[idxs] = self.devices[d].read(
                [cbv.slots[i][1] for i in idxs])
        out = unchunk_rows(rows, cbv.n_bits, cbv.shape, cbv.words32,
                           self.words)
        cbv._host = out
        cbv.dirty = False
        cbv._stash.clear()              # host copy now covers every chunk
        # only rows that actually crossed the channel are charged
        self._charge_io("from_device", self._io_cause or "read_back",
                        cbv.resident_bytes)
        return out

    def ensure_resident(self, cbv: ClusterBitVector,
                        protect: Iterable[ClusterBitVector] = ()
                        ) -> ClusterBitVector:
        """Fault a spilled handle back in (fresh upload, default
        placement). Partially spilled handles re-upload ONLY the missing
        chunks - the rest never left. Live handles refresh recency."""
        self._check_handle(cbv)
        if not cbv.spilled:
            if cbv.partially_spilled:
                return self._fault_in_partial(cbv, protect)
            self._touch(cbv)
            return cbv
        chunks = chunk_rows(cbv._host, self.words)
        devmap = self._place(len(chunks), None, None)
        slots: List[Optional[DeviceSlot]] = [None] * len(chunks)
        try:
            for d in sorted(set(devmap)):
                idxs = [i for i, dd in enumerate(devmap) if dd == d]
                got = self._alloc_on(d, len(idxs),
                                     protect=(cbv, *protect))
                for i, s in zip(idxs, got):
                    slots[i] = (d, s)
                self.devices[d].write([slots[i][1] for i in idxs],
                                      chunks[idxs])
        except AmbitError:
            for ds in slots:
                if ds is not None:
                    self.allocators[ds[0]].free([ds[1]])
            raise
        cbv.slots = slots
        cbv.spilled = False
        cbv.dirty = False
        self._charge_io("to_device", "fault_in", cbv.device_bytes)
        self._register(cbv)
        self._invalidate(cbv)   # placement changed: generation bumps
        return cbv

    def _fault_in_partial(self, cbv: ClusterBitVector,
                          protect: Iterable[ClusterBitVector]
                          ) -> ClusterBitVector:
        """Re-upload only the missing (None-slot) chunks: dirty chunks
        come from the stash (their only current copy), clean ones from
        the host copy. Placement follows the vector's default chunk->
        device mapping; only the uploaded bytes are charged."""
        missing = [i for i, ds in enumerate(cbv.slots) if ds is None]
        host_chunks = None
        rows = np.empty((len(missing), self.words), np.uint64)
        for k, i in enumerate(missing):
            if i in cbv._stash:
                rows[k] = cbv._stash[i]
            else:
                if host_chunks is None:
                    host_chunks = chunk_rows(cbv._host, self.words)
                rows[k] = host_chunks[i]
        devmap = self._place(cbv.n_slots, None, None)
        try:
            for d in sorted({devmap[i] for i in missing}):
                ks = [k for k, i in enumerate(missing) if devmap[i] == d]
                got = self._alloc_on(d, len(ks), protect=(cbv, *protect))
                self.devices[d].write(got, rows[ks])
                for k, s in zip(ks, got):
                    cbv.slots[missing[k]] = (d, s)
        except AmbitError:
            for i in missing:           # roll back to a consistent state
                if cbv.slots[i] is not None:
                    self.allocators[cbv.slots[i][0]].free([cbv.slots[i][1]])
                    cbv.slots[i] = None
            raise
        for i in missing:
            cbv._stash.pop(i, None)     # device copy is current again
        self._charge_io("to_device", "fault_in",
                        len(missing) * self.row_bytes)
        self._touch(cbv)
        self._invalidate(cbv)   # placement changed: generation bumps
        return cbv

    # -- cross-device migration ----------------------------------------------

    def colocate(self, operands: Sequence[ClusterBitVector]) -> int:
        """Unify each chunk's operands onto one device, picking the
        cheapest migration direction from the channel model (minimum
        total link cost over the candidate target devices; ties break to
        the lowest device index). Transfers are executed immediately and
        measured into the ChannelLedger. Returns rows moved."""
        if not operands:
            return 0
        n = operands[0].n_slots
        for cbv in operands:
            self._check_fully_live(cbv)
            if cbv.n_slots != n:
                raise AmbitError("operands must be chunk-aligned "
                                 "(same n_bits and shape)")
        moved = 0
        rb = self.row_bytes
        for i in range(n):
            homes = [cbv.slots[i][0] for cbv in operands]
            if len(set(homes)) == 1:
                continue
            def cost(t):
                return sum(self.channel.device_to_device_ns(h, t, rb)
                           for h in homes if h != t)
            targets = sorted(set(homes), key=lambda t: (cost(t), t))
            last_err = None
            for target in targets:
                try:
                    moved += self._migrate_chunk(operands, i, homes, target)
                    break
                except AmbitError as e:     # target full: next-cheapest
                    last_err = e
            else:
                raise AmbitError(
                    f"cannot colocate chunk {i}: every candidate device "
                    f"is full ({last_err})")
        return moved

    def _migrate_chunk(self, operands: Sequence[ClusterBitVector], i: int,
                       homes: List[int], target: int) -> int:
        """Move chunk ``i`` of every operand not on ``target`` there."""
        anchor = next((cbv.slots[i][1] for cbv, h in zip(operands, homes)
                       if h == target), None)
        moved = 0
        for cbv, h in zip(operands, homes):
            if h == target or cbv.slots[i][0] == target:
                continue        # second clause: duplicate handle in env
            src_d, src_slot = cbv.slots[i]
            (new_slot,) = self._alloc_on(
                target, 1, near=[anchor] if anchor else None,
                protect=operands)
            try:
                data = self.devices[src_d].read([src_slot])
                self.devices[target].write([new_slot], data)
                inj = getattr(self.devices[target], "fault_injector", None)
                if inj is not None:
                    row = data.reshape(self.words)
                    out = inj.on_transfer(target, new_slot, row)
                    if out is not row:
                        self.devices[target].write([new_slot],
                                                   out.reshape(1, -1))
            except AmbitError:
                # landing row is stuck / a device died mid-hop: give the
                # fresh slot back so retry re-placement starts clean
                self.allocators[target].free([new_slot])
                raise
            self.allocators[src_d].free([src_slot])
            cbv.slots[i] = (target, new_slot)
            anchor = anchor or new_slot
            hop_ns = self.channel.device_to_device_ns(src_d, target,
                                                      self.row_bytes)
            self.ledger.inter_device_rows += 1
            self.ledger.inter_device_bytes += self.row_bytes
            self.ledger.inter_device_ns += hop_ns
            self.ledger.inter_device_nj += \
                self.channel.device_to_device_nj(src_d, target,
                                                 self.row_bytes)
            self.metrics.counter("inter_device_rows").inc(1)
            self.metrics.counter("inter_device_bytes").inc(self.row_bytes)
            self.metrics.counter("inter_device_ns").inc(hop_ns)
            if self.tracer.enabled:
                self.tracer.instant(
                    ("cluster", "channel"), "migrate_chunk", "channel",
                    args={"src": src_d, "dst": target,
                          "bytes": int(self.row_bytes)})
            moved += 1
        return moved


@dataclasses.dataclass
class ClusterReport:
    """What one sharded planner execution did, and what it cost.

    ``per_bank`` is the full ledger delta keyed by ``(device, bank)`` -
    the resource grain the async scheduler packs epochs by (banks of
    different devices are independent execution resources; channel
    transfers serialize and are reported separately in
    ``transfer_ns``)."""

    per_device_ns: Dict[int, float] = dataclasses.field(default_factory=dict)
    per_bank: Dict[Tuple[int, int], OpStats] = dataclasses.field(
        default_factory=dict)
    transferred_rows: int = 0       # cross-device colocation moves
    transfer_ns: float = 0.0
    transfer_bytes: int = 0
    stats: OpStats = dataclasses.field(default_factory=OpStats)
    #: the execution faulted partway: this report bills only the work
    #: actually done before the raise (the reliability layer absorbs it
    #: into the retrying query's accumulator).
    partial: bool = False


class ClusterPlanner:
    """Lower one expression tree across every shard of the cluster.

    Per chunk, operands are first unified onto one device (cheapest
    direction from the channel model - explicit, measured transfer ops);
    each device then runs ONE sub-plan over its chunk group through the
    existing QueryPlanner (subarray batching, scratch staging). Reported
    time is max-over-devices compute plus the serialized channel time;
    energy and AAP counts are summed (the Fig. 21 accounting, lifted one
    level up the hierarchy)."""

    def __init__(self, cluster: PimCluster):
        self.cluster = cluster
        self.last_report: Optional[ClusterReport] = None

    def footprint(self, env: Dict[str, ClusterBitVector]) -> frozenset:
        """``(device, bank)`` resources the operands occupy - the epoch
        admission signal for the async scheduler. A spilled operand
        faults back in at placement-chosen devices, so it conservatively
        claims every bank of every device."""
        cl = self.cluster
        out = set()
        for nm in sorted(env):
            cbv = env[nm]
            if cbv.spilled or cbv.partially_spilled:
                return frozenset(
                    (d, b) for d in range(cl.n_devices)
                    for b in range(len(cl.devices[d].banks)))
            out.update((ds[0], ds[1][0]) for ds in cbv.slots)
        return frozenset(out)

    def execute(self, expression: E.Expr,
                env: Dict[str, ClusterBitVector],
                out_name: Optional[str] = None) -> ClusterBitVector:
        cl = self.cluster
        self.last_report = None
        if not env:
            raise ValueError("planner needs at least one operand")
        names = sorted(env)
        operands = [env[nm] for nm in names]
        first = operands[0]
        for cbv in operands:
            cl._check_fully_live(cbv)
            if (cbv.n_bits, cbv.shape, cbv.n_slots) != (
                    first.n_bits, first.shape, first.n_slots):
                raise ValueError(
                    "bbop operands must be row-aligned and equal-sized "
                    "(Section 5.3)")
            cl._touch(cbv)
        report = ClusterReport()

        dst: List[Optional[DeviceSlot]] = [None] * first.n_slots
        dev_stats: Dict[int, OpStats] = {}
        cl._in_flight = tuple(operands)     # no eviction of operands
        led = cl.ledger
        rows0, ns0, bytes0, nj0 = (led.inter_device_rows,
                                   led.inter_device_ns,
                                   led.inter_device_bytes,
                                   led.inter_device_nj)
        try:
            try:
                if len(operands) > 1:
                    cl.colocate(operands)
                report.transferred_rows = led.inter_device_rows - rows0
                report.transfer_ns = led.inter_device_ns - ns0
                report.transfer_bytes = led.inter_device_bytes - bytes0
                transfer_nj = led.inter_device_nj - nj0

                by_dev: Dict[int, List[int]] = {}
                for i in range(first.n_slots):
                    by_dev.setdefault(operands[0].slots[i][0], []).append(i)

                for d in sorted(by_dev):
                    idxs = by_dev[d]
                    # Names bound to the same handle must share ONE view:
                    # distinct views over the same slots would each free
                    # the old slot when colocation migrates the chunk.
                    views: Dict[int, ResidentBitVector] = {}
                    sub_env = {}
                    for nm in names:
                        key = id(env[nm])
                        if key not in views:
                            views[key] = self._subview(env[nm], d, idxs)
                        sub_env[nm] = views[key]
                    try:
                        res = cl.planners[d].execute(expression, sub_env)
                    finally:
                        # Per-device colocation may have moved operand
                        # rows within the device - even on a faulted
                        # attempt, where the moves that completed are
                        # real. Write the sub-view slots back either
                        # way or a retry frees stale rows.
                        for nm in names:
                            sv = sub_env[nm]
                            for k, i in enumerate(idxs):
                                if k < len(sv.slots) and \
                                        sv.slots[k] is not None:
                                    env[nm].slots[i] = (d, sv.slots[k])
                    cl.stores[d].disown(res)
                    for k, i in enumerate(idxs):
                        dst[i] = (d, res.slots[k])
                    res.slots = []  # ownership moves to the cluster handle
                    sub_rep = cl.planners[d].last_report
                    sub_rep._cluster_absorbed = True
                    dev_stats[d] = sub_rep.stats
                    for b, st in sub_rep.per_bank.items():
                        report.per_bank[(d, b)] = st
            except AmbitError:
                for ds in dst:
                    if ds is not None:
                        cl.allocators[ds[0]].free([ds[1]])
                # Bill the work the fault interrupted: transfers already
                # on the wire plus the faulting device's own partial
                # sub-report (its planner frees the device rows; the
                # cost survives). The retry loop absorbs this report.
                report.transferred_rows = led.inter_device_rows - rows0
                report.transfer_ns = led.inter_device_ns - ns0
                report.transfer_bytes = led.inter_device_bytes - bytes0
                transfer_nj = led.inter_device_nj - nj0
                for d in range(cl.n_devices):
                    rep = cl.planners[d].last_report
                    if rep is not None and rep.partial and \
                            not getattr(rep, "_cluster_absorbed", False):
                        rep._cluster_absorbed = True
                        dev_stats[d] = rep.stats
                        for b, st in rep.per_bank.items():
                            report.per_bank[(d, b)] = st
                self._finalize(report, dev_stats, transfer_nj,
                               partial=True)
                raise
        finally:
            cl._in_flight = ()

        self._finalize(report, dev_stats, transfer_nj, partial=False)

        out = ClusterBitVector(
            cluster=cl, n_bits=first.n_bits, shape=first.shape,
            words32=first.words32, chunks=first.chunks, slots=dst,
            dirty=True, name=out_name)
        cl._register(out)
        return out

    def _finalize(self, report: ClusterReport,
                  dev_stats: Dict[int, OpStats], transfer_nj: float,
                  partial: bool) -> None:
        """Roll per-device sub-reports into the cluster report, publish
        it as ``last_report`` and emit the metrics/trace events. Shared
        by the success path and the partial (faulted) path so recovery
        costs hit the same ledgers as normal work."""
        cl = self.cluster
        report.per_device_ns = {d: st.ns for d, st in dev_stats.items()
                                if st.ns > 0.0}
        report.stats = OpStats(
            ns=max((st.ns for st in dev_stats.values()), default=0.0)
            + report.transfer_ns,
            energy_nj=sum(st.energy_nj for st in dev_stats.values())
            + transfer_nj,
            aap_count=sum(st.aap_count for st in dev_stats.values()),
            bytes_touched=0,        # resident: no host traffic
            channel_ns=report.transfer_ns,
            channel_bytes=report.transfer_bytes,
            refresh_stolen_ns=sum(st.refresh_stolen_ns
                                  for st in dev_stats.values()))
        report.partial = partial
        self.last_report = report

        # Per-(device,bank) busy time is the occupancy signal the
        # utilization report divides by the drain wall clock. Counted
        # here (not in the per-device QueryPlanners, whose registries
        # are private to their stores) so each bank-ns is billed once.
        m = cl.metrics
        if partial:
            m.counter("plan_faulted").inc(1)
        else:
            m.counter("plan_executions").inc(1)
        for (d, b) in sorted(report.per_bank):
            st = report.per_bank[(d, b)]
            if st.ns:
                m.counter("bank_busy_ns").inc(st.ns, device=d, bank=b)
            if st.refresh_stolen_ns:
                m.counter("refresh_stolen_ns").inc(
                    st.refresh_stolen_ns, device=d, bank=b)
        if cl.tracer.enabled:
            args = {"devices": len(report.per_device_ns),
                    "transfer_rows": report.transferred_rows,
                    "aaps": report.stats.aap_count}
            if partial:
                args["partial"] = True
            cl.tracer.tick(
                ("planner", "cluster"), "plan", "plan", report.stats.ns,
                args=args)

    def _subview(self, cbv: ClusterBitVector, d: int,
                 idxs: List[int]) -> ResidentBitVector:
        """A per-device ResidentBitVector view of the chunks living on
        device ``d``: each chunk becomes one full-row logical row, so the
        device planner can batch/stage/colocate them natively. Slot
        updates are written back by the caller after the sub-plan."""
        cl = self.cluster
        return ResidentBitVector(
            store=cl.stores[d], n_bits=cl.words * 64, shape=(len(idxs),),
            words32=cl.words * 2, chunks=1,
            slots=[cbv.slots[i][1] for i in idxs], dirty=True,
            name=cbv.name)
