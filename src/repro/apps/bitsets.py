"""Bitvector sets vs ordered-set baseline (paper Section 8.3, Fig. 24).

Set union/intersection/difference over m input sets with domain 1..N:
  * BitSet  - N-bit bitvectors through the BulkBitwiseEngine (the paper's
              "Bitset with SIMD" accelerated by Ambit).
  * SortedSet - numpy sorted-array set ops (the RB-tree stand-in: same
              O(n) merge behaviour without pointer chasing, an optimistic
              baseline).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from ..core import BitVector, BulkBitwiseEngine


class BitSetOps:
    def __init__(self, domain: int, engine: BulkBitwiseEngine):
        self.domain = domain
        self.engine = engine

    def make(self, elems: np.ndarray) -> BitVector:
        bits = np.zeros(self.domain, bool)
        bits[elems] = True
        return BitVector.from_bits(bits)

    def union(self, sets: List[BitVector]) -> BitVector:
        acc = sets[0]
        for s in sets[1:]:
            acc = self.engine.or_(acc, s)
        return acc

    def intersection(self, sets: List[BitVector]) -> BitVector:
        acc = sets[0]
        for s in sets[1:]:
            acc = self.engine.and_(acc, s)
        return acc

    def difference(self, base: BitVector, sets: List[BitVector]) -> BitVector:
        acc = base
        for s in sets:
            acc = self.engine.masked_clear(acc, s)
        return acc


class SortedSetOps:
    @staticmethod
    def union(sets: List[np.ndarray]) -> np.ndarray:
        acc = sets[0]
        for s in sets[1:]:
            acc = np.union1d(acc, s)
        return acc

    @staticmethod
    def intersection(sets: List[np.ndarray]) -> np.ndarray:
        acc = sets[0]
        for s in sets[1:]:
            acc = np.intersect1d(acc, s)
        return acc

    @staticmethod
    def difference(base: np.ndarray, sets: List[np.ndarray]) -> np.ndarray:
        acc = base
        for s in sets:
            acc = np.setdiff1d(acc, s)
        return acc
