"""BitFunnel-style document filtering (paper Section 8.4.1).

Documents are Bloom-filter bit columns: a document-major bit matrix where
row r is "documents whose Bloom filter has bit r set". A query ANDs the
rows of its terms' hash positions; surviving bits are candidate documents
(supersets: Bloom false positives are verified downstream). Bulk bitwise
AND over thousands of documents per word is exactly Ambit's sweet spot.

With an ``AmbitRuntime``, the filter rows are uploaded once (``freeze``)
and every query lowers as a single AND tree over the resident rows - the
term count no longer multiplies host traffic. Any runtime backend works
unmodified: ``ambit_sim`` keeps rows in simulated DRAM, ``jnp``/``pallas``
keep them on the accelerator (one fused dispatch per query). A multi-device runtime
shards the rows across the cluster (the ``near=`` chain keeps them
chunk-aligned, so query ANDs stay on-device); cold rows LRU-spill on a
full device and fault back in at query time, and ``freeze(pin=True)``
exempts the filter from eviction entirely.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import numpy as np

from ..core import BitVector, BulkBitwiseEngine, Expr


def _hashes(term: str, k: int, m: int) -> List[int]:
    out = []
    h = 2166136261
    for i in range(k):
        for ch in f"{term}/{i}":
            h = (h ^ ord(ch)) * 16777619 % (1 << 32)
        out.append(h % m)
    return out


class BitFunnelIndex:
    def __init__(self, n_docs: int, filter_bits: int = 512, k: int = 3,
                 engine: BulkBitwiseEngine = None, runtime=None):
        self.n_docs = n_docs
        self.m = filter_bits
        self.k = k
        self.runtime = runtime
        self.engine = engine or (None if runtime is not None
                                 else BulkBitwiseEngine("jnp"))
        # rows[r] = bitvector over documents having Bloom bit r
        self._rows = np.zeros((filter_bits, n_docs), bool)
        self._resident: Dict[int, object] = {}  # row -> ResidentBitVector

    def add_document(self, doc_id: int, terms: Iterable[str]) -> None:
        for t in terms:
            for h in _hashes(t, self.k, self.m):
                self._rows[h, doc_id] = True
        if self._resident:          # index mutated: resident copy is stale
            self.thaw()

    # -- resident lifecycle --------------------------------------------------

    def freeze(self, pin: bool = False) -> None:
        """Upload every non-empty filter row to the device (idempotent).
        Queries then run fully resident until the next add_document.
        ``pin=True`` exempts the rows from LRU eviction (use when the
        device is shared and the filter must stay hot)."""
        if self.runtime is None:
            raise ValueError("freeze() needs an AmbitRuntime")
        if self._resident:
            return
        near = None
        for r in np.nonzero(self._rows.any(axis=1))[0]:
            rbv = self.runtime.put(BitVector.from_bits(self._rows[r]),
                                   name=f"bloom{r}", near=near, pin=pin)
            self._resident[int(r)] = rbv
            near = rbv.slots if rbv.slots else near

    def thaw(self) -> None:
        """Free the resident copy (after index mutation)."""
        for rbv in self._resident.values():
            self.runtime.free(rbv)
        self._resident.clear()

    # -- queries -------------------------------------------------------------

    def query(self, terms: Sequence[str]) -> np.ndarray:
        """Candidate doc ids containing ALL terms (Bloom superset)."""
        rows = sorted({h for t in terms for h in _hashes(t, self.k, self.m)})
        if self.runtime is not None:
            return self._query_resident(rows)
        acc = BitVector.from_bits(self._rows[rows[0]])
        for r in rows[1:]:
            acc = self.engine.and_(acc, BitVector.from_bits(self._rows[r]))
        bits = np.asarray(acc.bits())[:self.n_docs]
        return np.nonzero(bits)[0]

    def _query_resident(self, rows: List[int]) -> np.ndarray:
        self.freeze()
        # A queried Bloom row no document sets was never uploaded: the AND
        # is all-zeros, no device work needed.
        if any(r not in self._resident for r in rows):
            return np.empty(0, np.int64)
        expr = Expr.var(f"r{rows[0]}")
        for r in rows[1:]:
            expr = expr & Expr.var(f"r{r}")
        env = {f"r{r}": self._resident[r] for r in rows}
        out = self.runtime.eval(expr, env)
        bits = np.asarray(self.runtime.get(out).bits())[:self.n_docs]
        self.runtime.free(out)
        return np.nonzero(bits)[0]
