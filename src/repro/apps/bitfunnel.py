"""BitFunnel-style document filtering (paper Section 8.4.1).

Documents are Bloom-filter bit columns: a document-major bit matrix where
row r is "documents whose Bloom filter has bit r set". A query ANDs the
rows of its terms' hash positions; surviving bits are candidate documents
(supersets: Bloom false positives are verified downstream). Bulk bitwise
AND over thousands of documents per word is exactly Ambit's sweet spot.
"""

from __future__ import annotations

from typing import Iterable, List, Sequence

import numpy as np

from ..core import BitVector, BulkBitwiseEngine


def _hashes(term: str, k: int, m: int) -> List[int]:
    out = []
    h = 2166136261
    for i in range(k):
        for ch in f"{term}/{i}":
            h = (h ^ ord(ch)) * 16777619 % (1 << 32)
        out.append(h % m)
    return out


class BitFunnelIndex:
    def __init__(self, n_docs: int, filter_bits: int = 512, k: int = 3,
                 engine: BulkBitwiseEngine = None):
        self.n_docs = n_docs
        self.m = filter_bits
        self.k = k
        self.engine = engine or BulkBitwiseEngine("jnp")
        # rows[r] = bitvector over documents having Bloom bit r
        self._rows = np.zeros((filter_bits, n_docs), bool)

    def add_document(self, doc_id: int, terms: Iterable[str]) -> None:
        for t in terms:
            for h in _hashes(t, self.k, self.m):
                self._rows[h, doc_id] = True

    def query(self, terms: Sequence[str]) -> np.ndarray:
        """Candidate doc ids containing ALL terms (Bloom superset)."""
        rows = sorted({h for t in terms for h in _hashes(t, self.k, self.m)})
        acc = BitVector.from_bits(self._rows[rows[0]])
        for r in rows[1:]:
            acc = self.engine.and_(acc, BitVector.from_bits(self._rows[r]))
        bits = np.asarray(acc.bits())[:self.n_docs]
        return np.nonzero(bits)[0]
