"""Bitmap index (paper Section 8.1).

Tracks user characteristics/activity as bitvectors (bit u = user u).
The paper's workload: "how many unique users were active every week for
the past w weeks?" = popcount(AND of w weekly bitmaps); "how many male
users were active each week?" = w popcounts of (weekly AND gender).

All bulk ops route through the BulkBitwiseEngine, so the same query runs
on the jnp/pallas backends (performance) or the ambit_sim backend
(paper-fidelity, returning DRAM ns/nJ for the Fig. 22 benchmark).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import BitVector, BulkBitwiseEngine, Expr
from ..core.engine import OpStats


class BitmapIndex:
    def __init__(self, n_users: int, engine: BulkBitwiseEngine):
        self.n_users = n_users
        self.engine = engine
        self.bitmaps: Dict[str, BitVector] = {}

    def add(self, name: str, members: np.ndarray) -> None:
        bits = np.zeros(self.n_users, bool)
        bits[members] = True
        self.bitmaps[name] = BitVector.from_bits(bits)

    def query_and_all(self, names: List[str]) -> Tuple[int, OpStats]:
        """popcount(AND over names) + accumulated engine stats."""
        total = OpStats()
        acc = self.bitmaps[names[0]]
        for nm in names[1:]:
            acc = self.engine.and_(acc, self.bitmaps[nm])
            st = self.engine.last_stats
            if st:
                total.ns += st.ns
                total.energy_nj += st.energy_nj
                total.aap_count += st.aap_count
        return int(self.engine.popcount(acc)), total

    def weekly_active_query(self, weeks: List[str], gender: str
                            ) -> Tuple[int, List[int], OpStats]:
        """The paper's two-part query (Section 8.1)."""
        total = OpStats()
        unique_all, st = self.query_and_all(weeks)
        total.ns += st.ns
        total.energy_nj += st.energy_nj
        per_week = []
        g = self.bitmaps[gender]
        for wk in weeks:
            inter = self.engine.and_(self.bitmaps[wk], g)
            st2 = self.engine.last_stats
            if st2:
                total.ns += st2.ns
                total.energy_nj += st2.energy_nj
            per_week.append(int(self.engine.popcount(inter)))
        return unique_all, per_week, total


def baseline_cpu_ns(n_users: int, n_ops: int,
                    bw_bytes_per_s: float = 34e9) -> float:
    """Model of the DDR3-channel-bound CPU baseline (Section 7): each bulk
    AND streams 2 reads + 1 write of n_users/8 bytes at channel bandwidth."""
    bytes_moved = 3 * (n_users / 8) * n_ops
    return bytes_moved / bw_bytes_per_s * 1e9
