"""Bitmap index (paper Section 8.1).

Tracks user characteristics/activity as bitvectors (bit u = user u).
The paper's workload: "how many unique users were active every week for
the past w weeks?" = popcount(AND of w weekly bitmaps); "how many male
users were active each week?" = w popcounts of (weekly AND gender).

Two execution paths:

  * host (non-resident) baseline - all bulk ops route through the
    BulkBitwiseEngine, one binop at a time, each op paying the
    host<->device round-trip (jnp/pallas for performance, ambit_sim for
    the paper-fidelity DRAM ns/nJ ledger of Fig. 22);
  * resident - pass an ``AmbitRuntime``: bitmaps are uploaded once at
    ``add`` time, whole queries lower as one expression tree through the
    placement-aware planner, and only the final popcount reads data back.
    The runtime's backend is transparent to this class: the DRAM model
    (``ambit_sim``, default) measures paper-units ns/nJ, while
    ``AmbitRuntime(backend="jnp"/"pallas")`` keeps the bitmaps resident
    on the accelerator (DeviceStore) with identical put/eval/get code -
    weekly queries then drain as fused stacked kernel launches.
    A multi-device runtime (``AmbitRuntime(devices=N)``) shards each
    bitmap across the cluster; the ``near=`` chain keeps corresponding
    chunks of co-queried bitmaps on the same device, so queries pay no
    inter-device transfers. On a full device the LRU spills cold bitmaps
    to host (free when clean) and queries fault them back in on demand;
    ``pin_bitmaps=True`` exempts the index's bitmaps from eviction when
    the device is shared with other tenants.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import BitVector, BulkBitwiseEngine, Expr
from ..core.engine import OpStats


class BitmapIndex:
    def __init__(self, n_users: int,
                 engine: Optional[BulkBitwiseEngine] = None,
                 runtime=None, pin_bitmaps: bool = False):
        if (engine is None) == (runtime is None):
            raise ValueError("pass exactly one of engine= (host path) or "
                             "runtime= (resident path)")
        self.n_users = n_users
        self.engine = engine
        self.runtime = runtime
        self.pin_bitmaps = pin_bitmaps
        self.bitmaps: Dict[str, BitVector] = {}
        self.resident: Dict[str, object] = {}  # name -> ResidentBitVector

    def add(self, name: str, members: np.ndarray) -> None:
        bits = np.zeros(self.n_users, bool)
        bits[members] = True
        bv = BitVector.from_bits(bits)
        if self.runtime is not None:
            if name in self.resident:   # drop BEFORE picking a neighbor:
                self.runtime.free(self.resident.pop(name))
            # co-locate with already-loaded bitmaps: queries AND across
            # them (spilled neighbors hold no rows - skip them)
            near = next((r.slots for r in self.resident.values()
                         if r.slots), None)
            self.resident[name] = self.runtime.put(
                bv, name=name, near=near, pin=self.pin_bitmaps)
        else:
            self.bitmaps[name] = bv

    @staticmethod
    def _and_tree(names: List[str]) -> Expr:
        acc = Expr.var(names[0])
        for nm in names[1:]:
            acc = acc & Expr.var(nm)
        return acc

    def query_plan(self, names: List[str]) -> Tuple[Expr, Dict[str, object]]:
        """The popcount(AND over names) query as a submittable plan:
        (expression, resident-operand env) for ``AmbitRuntime.submit`` /
        ``serve.QueryFrontend.submit``. Serving frontends batch many
        tenants' plans into one scheduler drain instead of paying a
        serialized ``query_and_all`` per query."""
        if self.runtime is None:
            raise ValueError("plans need the resident path - pass runtime=")
        return self._and_tree(names), {nm: self.resident[nm] for nm in names}

    def query_and_all(self, names: List[str]) -> Tuple[int, OpStats]:
        """popcount(AND over names) + accumulated engine stats."""
        total = OpStats()
        if self.runtime is not None:
            rt = self.runtime
            out = rt.eval(self._and_tree(names),
                          {nm: self.resident[nm] for nm in names})
            total += rt.last_stats
            count = rt.popcount(out)     # the only host read-back
            total += rt.last_stats
            rt.free(out)
            return count, total
        acc = self.bitmaps[names[0]]
        for nm in names[1:]:
            acc = self.engine.and_(acc, self.bitmaps[nm])
            if self.engine.last_stats:
                total += self.engine.last_stats
        count = int(self.engine.popcount(acc))
        total += self.engine.last_stats      # fresh per-entry-point ledger
        return count, total

    def weekly_active_query(self, weeks: List[str], gender: str
                            ) -> Tuple[int, List[int], OpStats]:
        """The paper's two-part query (Section 8.1).

        Resident path: the AND-over-all-weeks root and the per-week
        (week AND gender) roots are submitted as ONE multi-root batch and
        executed by a single scheduler drain - the runtime overlaps the
        roots whose operands occupy disjoint banks/devices instead of
        paying one serialized eval per week. Only the popcounts read data
        back."""
        total = OpStats()
        if self.runtime is not None:
            rt = self.runtime
            g = self.resident[gender]
            uniq_t = rt.submit(self._and_tree(weeks),
                               {nm: self.resident[nm] for nm in weeks})
            week_ts = [rt.submit(Expr.var("w") & Expr.var("g"),
                                 {"w": self.resident[wk], "g": g})
                       for wk in weeks]
            rt.drain()
            total += rt.last_stats
            unique_all = rt.popcount(uniq_t.result)
            total += rt.last_stats
            rt.free(uniq_t.result)
            per_week = []
            for t in week_ts:
                per_week.append(rt.popcount(t.result))
                total += rt.last_stats
                rt.free(t.result)
            return unique_all, per_week, total
        unique_all, st = self.query_and_all(weeks)
        total += st
        per_week = []
        g = self.bitmaps[gender]
        for wk in weeks:
            inter = self.engine.and_(self.bitmaps[wk], g)
            if self.engine.last_stats:
                total += self.engine.last_stats
            per_week.append(int(self.engine.popcount(inter)))
            total += self.engine.last_stats  # the popcount's own ledger
        return unique_all, per_week, total


def baseline_cpu_ns(n_users: int, n_ops: int,
                    bw_bytes_per_s: float = 34e9) -> float:
    """Model of the DDR3-channel-bound CPU baseline (Section 7): each bulk
    AND streams 2 reads + 1 write of n_users/8 bytes at channel bandwidth."""
    bytes_moved = 3 * (n_users / 8) * n_ops
    return bytes_moved / bw_bytes_per_s * 1e9
