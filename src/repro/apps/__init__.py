"""Paper application workloads built on the BulkBitwiseEngine."""
