"""BitWeaving-V column scans (paper Section 8.2).

Stores an integer column bit-sliced (plane i = bit i of every value,
packed 32 values/word) and evaluates `select count(*) where c1<=v<=c2`
with bulk bitwise ops + a popcount - the exact query of Fig. 23.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..core import BitVector, BulkBitwiseEngine
from ..core.bitvector import unpack_bits
from ..kernels import ops, ref


@dataclasses.dataclass
class BitWeavingColumn:
    planes: jnp.ndarray  # (b, words) uint32, MSB-first
    n_rows: int
    bits: int

    @staticmethod
    def from_values(values: np.ndarray, bits: int) -> "BitWeavingColumn":
        n = len(values)
        pad = (-n) % 32
        v = np.pad(values.astype(np.uint32), (0, pad))
        planes = ref.bitslice(jnp.asarray(v), bits)
        return BitWeavingColumn(planes, n, bits)

    def scan_between(self, c1: int, c2: int,
                     use_kernel: bool = True) -> jnp.ndarray:
        """Packed predicate bitvector for c1 <= v <= c2."""
        fn = ops.bitweaving_scan if use_kernel else ref.bitweaving_scan
        return fn(self.planes, int(c1), int(c2))

    def count_between(self, c1: int, c2: int,
                      use_kernel: bool = True) -> int:
        sel = self.scan_between(c1, c2, use_kernel)
        # mask tail rows beyond n_rows
        mask = np.zeros(sel.shape[0] * 32, bool)
        mask[:self.n_rows] = True
        from ..core.bitvector import pack_bits
        sel = sel & pack_bits(jnp.asarray(mask))[:sel.shape[0]]
        return int(jnp.sum(jnp.asarray(
            ops.popcount(sel[None, :]) if use_kernel
            else ref.popcount(sel[None, :]))))

    def oracle_count(self, values: np.ndarray, c1: int, c2: int) -> int:
        return int(((values >= c1) & (values <= c2)).sum())


def word_at_a_time_scan(values: np.ndarray, c1: int, c2: int) -> int:
    """The paper's CPU baseline: per-value comparisons on word-aligned
    integers (numpy vectorized = an optimistic SIMD baseline)."""
    return int(((values >= c1) & (values <= c2)).sum())


def scan_expr(bits: int, c1: int, c2: int, prefix: str = "p"):
    """The BitWeaving-V predicate c1 <= v <= c2 as ONE expression DAG over
    plane variables {prefix}0..{prefix}{b-1} (MSB first) - the exact
    recurrence of kernels/ref.bitweaving_scan, but lowered as a whole
    tree so the PIM planner can schedule it as a single batched AAP
    program. Constant folding (expr.py) prunes the ZERO/ONE seeds; CSE
    shares the plane loads between the two comparisons. ``prefix``
    namespaces the plane variables so predicates over several columns
    compose into one conjunction (the TPC-H suite below)."""
    from ..core.expr import Expr, ONE, ZERO

    def cmp(const: int):
        gt, lt, eq = ZERO, ZERO, ONE
        for i in range(bits):
            cbit = (const >> (bits - 1 - i)) & 1
            p = Expr.var(f"{prefix}{i}")
            if cbit:
                lt = lt | (eq & ~p)
            else:
                gt = gt | (eq & p)
            eq = eq & ~(p ^ (ONE if cbit else ZERO))
        return gt, lt, eq

    gt1, lt1, eq1 = cmp(c1)
    gt2, lt2, eq2 = cmp(c2)
    return (gt1 | eq1) & (lt2 | eq2)


def ensure_resident_planes(col: BitWeavingColumn, runtime,
                           pin_planes: bool = False):
    """Upload the column's bit planes to ``runtime`` and cache them on the
    column (keyed by runtime identity), so repeated scans pay zero upload
    traffic; planes previously resident on a *different* runtime are freed
    first. The ``near=`` chain co-locates corresponding chunks so the
    predicate runs without inter-device transfers on sharded runtimes.
    Returns ``(plane_handles, upload_stats)`` - the stats are zero when
    the planes were already resident."""
    from ..core.engine import OpStats

    up = OpStats()
    resident = getattr(col, "_resident_planes", None)
    if resident is not None and resident[0] is runtime:
        return resident[1], up
    if resident is not None:         # planes on a previous runtime: free
        for rbv in resident[1]:
            resident[0].free(rbv)
    near = None
    planes = []
    for i in range(col.bits):
        rbv = runtime.put(BitVector(col.planes[i], col.n_rows),
                          name=f"p{i}", near=near, pin=pin_planes)
        up += runtime.last_stats
        planes.append(rbv)
        near = rbv.slots if rbv.slots else near
    col._resident_planes = (runtime, planes)
    return planes, up


def scan_plan(col: BitWeavingColumn, c1: int, c2: int, runtime,
              pin_planes: bool = False):
    """The c1 <= v <= c2 scan as a submittable plan: (expression, env of
    resident plane handles) for ``AmbitRuntime.submit`` /
    ``serve.QueryFrontend.submit``. A serving frontend batches many
    tenants' scans into one drain; planes upload on first use and are
    shared by every later plan against the same runtime."""
    planes, _ = ensure_resident_planes(col, runtime, pin_planes=pin_planes)
    return (scan_expr(col.bits, int(c1), int(c2)),
            {f"p{i}": rbv for i, rbv in enumerate(planes)})


def ambit_scan_resident(col: BitWeavingColumn, c1: int, c2: int,
                        runtime, keep_resident: bool = False,
                        pin_planes: bool = False):
    """Run the scan fully resident: planes are uploaded once, the whole
    predicate executes in-DRAM as one planner call, and only the selection
    bitvector is read back for the popcount. Returns (count, OpStats,
    selection) - ``selection`` is the still-resident predicate bitvector
    when ``keep_resident`` (caller frees it), else None.

    Planes stay resident across calls (cached on the column), so repeated
    scans with different constants pay zero upload traffic. On a full
    device cold planes LRU-spill to host (free - they are clean) and the
    next scan faults them back in, charged to that scan's ledger;
    ``pin_planes=True`` exempts them from eviction. Sharded runtimes
    (``AmbitRuntime(devices=N)``) split every plane across devices; the
    ``near=`` chain keeps corresponding chunks co-resident, so the whole
    predicate still runs without inter-device transfers. Accelerator
    runtimes (``backend="jnp"/"pallas"``) hold the planes as device
    arrays and run the whole predicate as one fused kernel - same code,
    same ledger contract (only spill/fault-in bytes are charged)."""
    from ..core.engine import OpStats

    total = OpStats()
    planes, up = ensure_resident_planes(col, runtime,
                                        pin_planes=pin_planes)
    total += up
    env = {f"p{i}": rbv for i, rbv in enumerate(planes)}
    out = runtime.eval(scan_expr(col.bits, int(c1), int(c2)), env)
    total += runtime.last_stats
    sel = runtime.get(out)           # the only per-query read-back
    total += runtime.last_stats
    # get() masked bits beyond n_bits=n_rows, so tail rows can't count
    count = int(sel.popcount())
    if not keep_resident:
        runtime.free(out)
        return count, total, None
    return count, total, out


# -- TPC-H-flavoured multi-predicate suite ------------------------------------
#
# "Understanding Bulk-Bitwise Processing In-Memory Through Database
# Analytics" measures Ambit-class hardware on database scans: thousands
# of tenants issuing overlapping range predicates over a handful of
# columns. This suite reproduces that shape - a lineitem-flavoured table
# of ~8 bit-sliced columns, per-column pools of range predicates sharing
# their lower bound (so the comparator recurrence for the shared prefix
# is the SAME Expr subtree across queries), and a Zipfian tenant mix -
# as the workload the drain-time query optimizer is measured on
# (``kern_pim_optimizer`` in benchmarks/kernels_micro.py).

# (name, bits) - widths keep whole-mix programs small enough for compact
# test geometries while giving every column a distinct selectivity.
TPCH_COLUMNS = (
    ("quantity", 6), ("discount", 4), ("tax", 4), ("shipmode", 3),
    ("priority", 3), ("suppkey", 7), ("extprice", 8), ("status", 2),
)


@dataclasses.dataclass
class TpchTable:
    """A synthetic lineitem-flavoured table: each column bit-sliced for
    BitWeaving-V scans, with the raw values kept for oracle checks."""

    n_rows: int
    values: "dict[str, np.ndarray]"
    columns: "dict[str, BitWeavingColumn]"

    @staticmethod
    def synthesize(n_rows: int = 4096, seed: int = 0,
                   columns=TPCH_COLUMNS) -> "TpchTable":
        rng = np.random.default_rng(seed)
        values, cols = {}, {}
        for name, bits in columns:
            v = rng.integers(0, 1 << bits, n_rows, dtype=np.uint32)
            values[name] = v
            cols[name] = BitWeavingColumn.from_values(v, bits)
        return TpchTable(n_rows, values, cols)

    def oracle(self, specs) -> np.ndarray:
        """Row-selection bits for a conjunction of
        ``(column, c1, c2)`` range predicates (numpy ground truth)."""
        sel = np.ones(self.n_rows, bool)
        for col, c1, c2 in specs:
            v = self.values[col]
            sel &= (v >= c1) & (v <= c2)
        return sel


def shared_prefix_ranges(bits: int, n: int, rng) -> list:
    """``n`` range predicates over a ``bits``-wide column sharing their
    lower bound: ``c1`` is fixed, the upper bounds spread above it. The
    shared bound makes the whole lower-comparator subtree of
    ``scan_expr`` identical across the pool - exactly the structure
    cross-ticket CSE materializes once."""
    lo = int(rng.integers(0, 1 << max(bits - 1, 1)))
    his = sorted({int(h) for h in rng.integers(lo, 1 << bits, n)})
    if not his:
        his = [(1 << bits) - 1]
    return [(lo, hi) for hi in his]


def predicate_plan(table: TpchTable, specs, runtime,
                   pin_planes: bool = False):
    """A multi-column conjunction as one submittable
    ``(expression, env)`` plan: each ``(column, c1, c2)`` term is the
    BitWeaving comparator over that column's resident planes (uploaded
    once per runtime, shared by every later plan), ANDed together.
    Column names namespace the plane variables, so plans over different
    column sets compose in one drain."""
    expr, env = None, {}
    for col, c1, c2 in specs:
        column = table.columns[col]
        planes, _ = ensure_resident_planes(column, runtime,
                                           pin_planes=pin_planes)
        term = scan_expr(column.bits, int(c1), int(c2), prefix=f"{col}_b")
        env.update({f"{col}_b{i}": rbv for i, rbv in enumerate(planes)})
        expr = term if expr is None else expr & term
    return expr, env


def zipf_tenant_queries(table: TpchTable, n_tenants: int, n_queries: int,
                        seed: int = 0, s: float = 1.2,
                        ranges_per_column: int = 3,
                        cols_per_query: int = 2) -> list:
    """A Zipfian tenant mix over shared predicate templates: every
    tenant owns one fixed conjunction template (columns + ranges drawn
    from the per-column shared-prefix pools), and queries sample tenants
    with Zipf(s) popularity. Hot tenants repeat their template verbatim
    (the result cache serves them); distinct tenants overlap on the
    pooled column predicates (cross-ticket CSE shares them). Returns
    ``[(tenant_id, specs), ...]`` with ``specs`` as taken by
    ``predicate_plan`` / ``TpchTable.oracle``."""
    rng = np.random.default_rng(seed)
    names = list(table.columns)
    pools = {c: shared_prefix_ranges(table.columns[c].bits,
                                     ranges_per_column, rng)
             for c in names}
    templates = []
    for t in range(n_tenants):
        trng = np.random.default_rng(seed * 7919 + 31 * t + 1)
        picks = trng.choice(len(names), size=min(cols_per_query,
                                                 len(names)),
                            replace=False)
        specs = []
        for ci in sorted(int(c) for c in picks):
            col = names[ci]
            pool = pools[col]
            specs.append((col, *pool[int(trng.integers(len(pool)))]))
        templates.append(tuple(specs))
    ranks = np.arange(1, n_tenants + 1, dtype=np.float64) ** -s
    probs = ranks / ranks.sum()
    return [(int(t), templates[int(t)])
            for t in rng.choice(n_tenants, size=n_queries, p=probs)]


def ambit_scan_stats(col: BitWeavingColumn, c1: int, c2: int,
                     engine: BulkBitwiseEngine) -> Tuple[int, float]:
    """Run the BitWeaving predicate THROUGH the Ambit device model to get
    paper-units timing: each plane op is a row-wide bulk bitwise op.

    The predicate needs ~6 bulk ops per bit-plane (gt/lt/eq updates for
    both constants) + 1 final AND; we model rows of 65,536 bits."""
    from ..core import expr as E
    # count via engine on packed planes (values correctness path)
    sel = col.scan_between(c1, c2, use_kernel=False)
    count = int(jnp.sum(jnp.asarray(ref.popcount(sel[None, :]))))
    # DRAM-time model: ops per plane from the BitWeaving recurrence
    n_ops = 6 * col.bits + 1
    rows = max(1, (col.n_rows + 65535) // 65536)
    # each bulk op = one Figure-20 'and'-class program (4 AAPs) per row
    ns = n_ops * rows * 4 * 49.0
    return count, ns
