"""BitWeaving-V column scans (paper Section 8.2).

Stores an integer column bit-sliced (plane i = bit i of every value,
packed 32 values/word) and evaluates `select count(*) where c1<=v<=c2`
with bulk bitwise ops + a popcount - the exact query of Fig. 23.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..core import BulkBitwiseEngine
from ..core.bitvector import unpack_bits
from ..kernels import ops, ref


@dataclasses.dataclass
class BitWeavingColumn:
    planes: jnp.ndarray  # (b, words) uint32, MSB-first
    n_rows: int
    bits: int

    @staticmethod
    def from_values(values: np.ndarray, bits: int) -> "BitWeavingColumn":
        n = len(values)
        pad = (-n) % 32
        v = np.pad(values.astype(np.uint32), (0, pad))
        planes = ref.bitslice(jnp.asarray(v), bits)
        return BitWeavingColumn(planes, n, bits)

    def scan_between(self, c1: int, c2: int,
                     use_kernel: bool = True) -> jnp.ndarray:
        """Packed predicate bitvector for c1 <= v <= c2."""
        fn = ops.bitweaving_scan if use_kernel else ref.bitweaving_scan
        return fn(self.planes, int(c1), int(c2))

    def count_between(self, c1: int, c2: int,
                      use_kernel: bool = True) -> int:
        sel = self.scan_between(c1, c2, use_kernel)
        # mask tail rows beyond n_rows
        mask = np.zeros(sel.shape[0] * 32, bool)
        mask[:self.n_rows] = True
        from ..core.bitvector import pack_bits
        sel = sel & pack_bits(jnp.asarray(mask))[:sel.shape[0]]
        return int(jnp.sum(jnp.asarray(
            ops.popcount(sel[None, :]) if use_kernel
            else ref.popcount(sel[None, :]))))

    def oracle_count(self, values: np.ndarray, c1: int, c2: int) -> int:
        return int(((values >= c1) & (values <= c2)).sum())


def word_at_a_time_scan(values: np.ndarray, c1: int, c2: int) -> int:
    """The paper's CPU baseline: per-value comparisons on word-aligned
    integers (numpy vectorized = an optimistic SIMD baseline)."""
    return int(((values >= c1) & (values <= c2)).sum())


def ambit_scan_stats(col: BitWeavingColumn, c1: int, c2: int,
                     engine: BulkBitwiseEngine) -> Tuple[int, float]:
    """Run the BitWeaving predicate THROUGH the Ambit device model to get
    paper-units timing: each plane op is a row-wide bulk bitwise op.

    The predicate needs ~6 bulk ops per bit-plane (gt/lt/eq updates for
    both constants) + 1 final AND; we model rows of 65,536 bits."""
    from ..core import expr as E
    # count via engine on packed planes (values correctness path)
    sel = col.scan_between(c1, c2, use_kernel=False)
    count = int(jnp.sum(jnp.asarray(ref.popcount(sel[None, :]))))
    # DRAM-time model: ops per plane from the BitWeaving recurrence
    n_ops = 6 * col.bits + 1
    rows = max(1, (col.n_rows + 65535) // 65536)
    # each bulk op = one Figure-20 'and'-class program (4 AAPs) per row
    ns = n_ops * rows * 4 * 49.0
    return count, ns
