"""Masked initialization (paper Section 8.4.2): bulk set/clear of bit
positions via preloaded mask rows - x|mask and x&~mask row-wide."""

from __future__ import annotations

import numpy as np

from ..core import BitVector, BulkBitwiseEngine


def masked_set(engine: BulkBitwiseEngine, x: BitVector,
               mask: BitVector) -> BitVector:
    return engine.masked_set(x, mask)


def masked_clear(engine: BulkBitwiseEngine, x: BitVector,
                 mask: BitVector) -> BitVector:
    return engine.masked_clear(x, mask)


def clear_color_channel(engine: BulkBitwiseEngine, image_bits: BitVector,
                        channel_mask: BitVector) -> BitVector:
    """The paper's graphics example: clear one color channel across a
    whole image buffer with a single bulk AND-NOT."""
    return engine.masked_clear(image_bits, channel_mask)
