"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert exact equality - these are
integer/bit ops, so no tolerance is needed; the binary matmul oracle is
exact integer arithmetic too).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..core import expr as E


def bitwise_eval(expression: E.Expr,
                 env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Fused bitwise expression over packed uint32 arrays."""
    return E.eval_expr(expression, env)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Total set bits per row: (rows, words) uint32 -> (rows,) int32."""
    return jax.lax.population_count(x).astype(jnp.int32).sum(-1)


def bitweaving_scan(planes: jnp.ndarray, c1: int, c2: int) -> jnp.ndarray:
    """BitWeaving-V predicate scan: c1 <= v <= c2 (Section 8.2).

    planes: (b, words) uint32 bit-sliced column - plane i holds bit
    (b-1-i) (MSB first) of each of the words*32 values.
    Returns a packed uint32 result bitvector (words,) with bit j set iff
    c1 <= v_j <= c2.
    """
    b = planes.shape[0]
    ones = jnp.uint32(0xFFFFFFFF)

    def cmp(const: int):
        """Returns (gt, lt, eq) packed masks of v <op> const."""
        gt = jnp.zeros_like(planes[0])
        lt = jnp.zeros_like(planes[0])
        eq = jnp.full_like(planes[0], ones)
        for i in range(b):
            cbit = (const >> (b - 1 - i)) & 1
            p = planes[i]
            if cbit:
                lt = lt | (eq & ~p)
            else:
                gt = gt | (eq & p)
            eq = eq & ~(p ^ (ones if cbit else jnp.uint32(0)))
        return gt, lt, eq

    gt1, lt1, eq1 = cmp(c1)
    gt2, lt2, eq2 = cmp(c2)
    ge_c1 = gt1 | eq1
    le_c2 = lt2 | eq2
    return ge_c1 & le_c2


def bitslice(values: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack integer column (n,) -> bit-sliced planes (bits, n/32) uint32,
    MSB-first plane order. n must be a multiple of 32."""
    n = values.shape[0]
    assert n % 32 == 0
    v = values.astype(jnp.uint32)
    planes = []
    for i in range(bits):
        bit = (v >> (bits - 1 - i)) & 1
        planes.append(_pack32(bit))
    return jnp.stack(planes)


def _pack32(bits01: jnp.ndarray) -> jnp.ndarray:
    bits01 = bits01.reshape(-1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits01 << shifts).sum(-1, dtype=jnp.uint32)


def binary_matmul(a_packed: jnp.ndarray, b_packed: jnp.ndarray,
                  k_bits: int) -> jnp.ndarray:
    """XNOR-popcount matmul over {-1,+1} vectors packed as bits (1 = +1).

    a_packed: (M, K/32) uint32, b_packed: (N, K/32) uint32.
    Returns (M, N) int32 with C[m,n] = sum_k a[m,k]*b[n,k]
                                     = k_bits - 2*popcount(a XOR b).
    Padding bits beyond k_bits must be zero in both operands (they cancel:
    0 XOR 0 = 0 contributes popcount 0, and the formula subtracts the pad
    via the k_bits constant).
    """
    x = a_packed[:, None, :] ^ b_packed[None, :, :]
    pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    pad = a_packed.shape[-1] * 32 - k_bits
    # pad bits are 0^0=0 -> contribute 0 to popcount; dot over k_bits only.
    return jnp.int32(k_bits) - 2 * pc


def binary_matmul_mxu(a_packed: jnp.ndarray, b_packed: jnp.ndarray,
                      k_bits: int) -> jnp.ndarray:
    """MXU-path oracle: unpack to +-1 bf16 and use a real dot product.
    (On TPU this trades 32x unpack bandwidth for MXU throughput; see
    kernels/binary_matmul.py for the codesign discussion.)"""
    from ..core.bitvector import unpack_bits
    a = unpack_bits(a_packed)[..., :k_bits].astype(jnp.float32) * 2 - 1
    b = unpack_bits(b_packed)[..., :k_bits].astype(jnp.float32) * 2 - 1
    return jnp.dot(a, b.T).astype(jnp.int32)
