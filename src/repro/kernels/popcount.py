"""Packed popcount reduction kernel (the paper's `bitcount`, Section 9.1).

Input (rows, words) uint32; output (rows, 1) int32 of set bits per row.
Grid walks (row tiles, word tiles); the word-tile dimension is innermost
and revisits the same output block, accumulating partial popcounts - the
standard Pallas reduction pattern (sequential grid on TPU makes the
accumulation race-free).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_WORDS = 512


def _popcount_kernel(x_ref, o_ref):
    j = pl.program_id(1)
    pc = lax.population_count(x_ref[...]).astype(jnp.int32)
    partial = pc.sum(axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words",
                                             "interpret"))
def popcount_rows(x: jnp.ndarray, block_rows: int = DEFAULT_BLOCK_ROWS,
                  block_words: int = DEFAULT_BLOCK_WORDS,
                  interpret: bool = True) -> jnp.ndarray:
    """(rows, words) uint32 -> (rows,) int32 popcounts."""
    rows, words = x.shape
    br = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (pl.cdiv(rows, br), pl.cdiv(words, bw))
    out = pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        interpret=interpret,
    )(x)
    return out[:, 0]
