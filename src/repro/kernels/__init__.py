"""Pallas TPU kernels for the Ambit bulk-bitwise hot spots.

Each kernel module pairs with a pure-jnp oracle in ref.py; ops.py holds the
jitted public wrappers (padding + backend selection).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
