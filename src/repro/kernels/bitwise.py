"""Fused bulk-bitwise expression kernel (Pallas, TPU target).

This is the TPU-native realization of an Ambit AAP chain: the whole bitwise
expression DAG is evaluated in ONE pass over VMEM-resident uint32 tiles, so
intermediates never travel back to HBM - the analogue of Ambit keeping
operands inside the subarray and eliding copies with RowClone/dead-store
elimination (Sections 3.1.4, 4.2).

Tiling: operands are (rows, words) packed uint32. Blocks of
(BLOCK_ROWS, BLOCK_WORDS) live in VMEM; the grid walks row tiles x word
tiles. BLOCK_WORDS is a multiple of 128 (VREG lane width) and BLOCK_ROWS a
multiple of 8 (sublanes), so tiles map exactly onto (8,128) int32 VREGs and
the VPU executes one logical op per VREG pair per cycle - the arithmetic
intensity is ~#ops/12 bytes, i.e. firmly HBM-bound, which is precisely the
regime Ambit targets (Section 7).
"""

from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import expr as E

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_WORDS = 512


def _expr_kernel(expression: E.Expr, names: Tuple[str, ...]):
    def kernel(*refs):
        *in_refs, o_ref = refs
        env = {nm: r[...] for nm, r in zip(names, in_refs)}
        o_ref[...] = E.eval_expr(expression, env)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("expression", "names", "block_rows",
                                    "block_words", "interpret"))
def fused_bitwise(expression: E.Expr, names: Tuple[str, ...],
                  *arrays: jnp.ndarray,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  block_words: int = DEFAULT_BLOCK_WORDS,
                  interpret: bool = True) -> jnp.ndarray:
    """Evaluate `expression` over equal-shaped (rows, words) uint32 arrays."""
    rows, words = arrays[0].shape
    br = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (pl.cdiv(rows, br), pl.cdiv(words, bw))
    spec = pl.BlockSpec((br, bw), lambda i, j: (i, j))
    return pl.pallas_call(
        _expr_kernel(expression, names),
        grid=grid,
        in_specs=[spec] * len(arrays),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, words), jnp.uint32),
        interpret=interpret,
    )(*arrays)


@functools.partial(jax.jit,
                   static_argnames=("expression", "names", "block_rows",
                                    "block_words", "interpret"))
def fused_bitwise_stacked(expression: E.Expr, names: Tuple[str, ...],
                          *arrays: jnp.ndarray,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          block_words: int = DEFAULT_BLOCK_WORDS,
                          interpret: bool = True) -> jnp.ndarray:
    """Multi-query fusion: evaluate `expression` over ``(queries, rows,
    words)`` uint32 stacks in ONE kernel launch. The leading grid axis
    walks the query dimension, so an epoch of shape-compatible queries
    costs one dispatch instead of one per query - the multi-session
    analogue of the AAP-chain fusion above (banks run concurrent bbops;
    here query tiles share one launch's grid)."""
    queries, rows, words = arrays[0].shape
    br = min(block_rows, rows)
    bw = min(block_words, words)
    grid = (queries, pl.cdiv(rows, br), pl.cdiv(words, bw))
    spec = pl.BlockSpec((1, br, bw), lambda q, i, j: (q, i, j))
    return pl.pallas_call(
        _expr_kernel(expression, names),
        grid=grid,
        in_specs=[spec] * len(arrays),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((queries, rows, words), jnp.uint32),
        interpret=interpret,
    )(*arrays)
