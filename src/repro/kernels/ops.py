"""Jitted public wrappers around the Pallas kernels.

Handles padding to lane-aligned tile multiples, backend selection
(interpret=True everywhere except real TPU), and shape normalization.
These are the entry points the BulkBitwiseEngine's "pallas" backend and
the model stack use.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import expr as E
from . import binary_matmul as _bmm
from . import bitweaving as _bw
from . import bitwise as _bitwise
from . import popcount as _pc


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- fused-dispatch probe ------------------------------------------------------
# Counts calls to the fused bitwise entry points at the (un-jitted) wrapper
# layer - one increment per kernel launch issued by Python. Tests and
# benchmarks assert "one fused dispatch per epoch" against this counter.

_FUSED_DISPATCHES = 0


def _count_dispatch() -> None:
    global _FUSED_DISPATCHES
    _FUSED_DISPATCHES += 1


def fused_dispatch_count() -> int:
    return _FUSED_DISPATCHES


def fused_dispatch_reset() -> None:
    global _FUSED_DISPATCHES
    _FUSED_DISPATCHES = 0


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def _eval_padded(expression: E.Expr, names,
                 env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Shape-normalized fused evaluation (shared by the public wrapper and
    the accelerator-resident compiled callables; jit-safe, no counters)."""
    arrays = [jnp.asarray(env[n], jnp.uint32) for n in names]
    shape = arrays[0].shape
    lead = shape[:-1]
    words = shape[-1]
    rows = int(np.prod(lead)) if lead else 1
    arrays = [a.reshape(rows, words) for a in arrays]
    padded = [_pad_to(a, (8, 128)) for a in arrays]
    out = _bitwise.fused_bitwise(expression, tuple(names), *padded,
                                 interpret=_interpret())
    return out[:rows, :words].reshape(shape)


def _eval_padded_stacked(expression: E.Expr, names,
                         env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """(queries, rows, words) stacks -> one stacked-grid kernel launch."""
    arrays = [jnp.asarray(env[n], jnp.uint32) for n in names]
    q, rows, words = arrays[0].shape
    padded = [_pad_to(a, (1, 8, 128)) for a in arrays]
    out = _bitwise.fused_bitwise_stacked(expression, tuple(names), *padded,
                                         interpret=_interpret())
    return out[:, :rows, :words]


def bitwise_eval(expression: E.Expr,
                 env: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Fused bitwise expression over packed uint32 arrays of equal shape."""
    names = tuple(sorted(env.keys()))
    _count_dispatch()
    return _eval_padded(expression, names, env)


def bitwise_eval_stacked(expression: E.Expr, names,
                         envs) -> list:
    """Evaluate one expression over a batch of shape-compatible operand
    environments in a single stacked kernel launch. ``envs`` is a list of
    name->(..., words) arrays, all equal-shaped; returns one result array
    per environment."""
    names = tuple(names)
    first = jnp.asarray(envs[0][names[0]], jnp.uint32)
    shape = first.shape
    lead, words = shape[:-1], shape[-1]
    rows = int(np.prod(lead)) if lead else 1
    stacked = {
        nm: jnp.stack([jnp.asarray(env[nm], jnp.uint32).reshape(rows, words)
                       for env in envs]) for nm in names}
    _count_dispatch()
    out = _eval_padded_stacked(expression, names, stacked)
    return [out[k].reshape(shape) for k in range(len(envs))]


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Per-row popcount: (..., words) uint32 -> (...,) int32."""
    x = jnp.asarray(x, jnp.uint32)
    lead = x.shape[:-1]
    words = x.shape[-1]
    rows = int(np.prod(lead)) if lead else 1
    x2 = _pad_to(x.reshape(rows, words), (8, 128))
    out = _pc.popcount_rows(x2, interpret=_interpret())[:rows]
    return out.reshape(lead) if lead else out[0]


def bitweaving_scan(planes: jnp.ndarray, c1: int, c2: int) -> jnp.ndarray:
    """(b, words) bit-sliced planes -> packed (words,) predicate bitvector."""
    planes = jnp.asarray(planes, jnp.uint32)
    b, words = planes.shape
    padded = _pad_to(planes, (1, 128))
    out = _bw.bitweaving_scan(padded, int(c1), int(c2),
                              interpret=_interpret())
    return out[:words]


def binary_matmul(a_packed: jnp.ndarray, b_packed: jnp.ndarray,
                  k_bits: int) -> jnp.ndarray:
    """Packed XNOR-popcount matmul: (M,Kw) x (N,Kw) -> (M,N) int32."""
    a = jnp.asarray(a_packed, jnp.uint32)
    b = jnp.asarray(b_packed, jnp.uint32)
    m, kw = a.shape
    n, _ = b.shape
    ap = _pad_to(a, (8, 128))
    bp = _pad_to(b, (8, 128))
    out = _bmm.binary_matmul(ap, bp, int(k_bits), interpret=_interpret())
    return out[:m, :n]


def binary_matmul_mxu(a_packed: jnp.ndarray, b_packed: jnp.ndarray,
                      k_bits: int) -> jnp.ndarray:
    """MXU alternative: unpack to +-1 and use the systolic array (see
    binary_matmul.py codesign note). Pure-XLA; lowers on any backend."""
    from . import ref
    return ref.binary_matmul_mxu(a_packed, b_packed, k_bits)
