"""XNOR-popcount binarized matmul kernel (Section 8.4.5, ML on Ambit).

For {-1,+1} vectors packed as bits (1 bit = +1), the dot product is
    a . b = K - 2 * popcount(a XOR b)
so a binary matmul is bulk XOR + popcount - exactly the bulk bitwise
workload Ambit targets (and the basis of XNOR-Net / bit-serial DNNs cited
by the paper).

TPU codesign note: two implementations are offered.
  * VPU path (this kernel): operands stay packed 32x dense; the inner block
    computes (bm, bn, kw) XORs + popcounts on the vector unit. Arithmetic
    intensity grows with bn, so unlike plain bitwise ops this CAN become
    compute-bound; the paper's "processing using memory" insight survives
    as: never unpack in HBM, only inside registers.
  * MXU path (ops.binary_matmul_mxu): unpack tiles to +-1 bf16 in VMEM and
    feed the 128x128 systolic array. On real TPU the MXU's 197 TFLOP/s
    usually beats VPU popcounting for large N; the right choice is
    shape-dependent and benchmarked in benchmarks/kernels_micro.py.

Block shapes: a (bm, kw), b (bn, kw), out (bm, bn); kw = K/32 words. All
dims padded to multiples of (8, 128) lanes by the ops.py wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 64
DEFAULT_BLOCK_N = 64
DEFAULT_BLOCK_K_WORDS = 512


def _bmm_kernel(k_bits: int):
    def kernel(a_ref, b_ref, o_ref):
        k = pl.program_id(2)
        a = a_ref[...]  # (bm, kw)
        b = b_ref[...]  # (bn, kw)
        x = a[:, None, :] ^ b[None, :, :]          # (bm, bn, kw)
        pc = lax.population_count(x).astype(jnp.int32).sum(-1)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.int32(k_bits) - 2 * pc

        @pl.when(k != 0)
        def _acc():
            o_ref[...] = o_ref[...] - 2 * pc

    return kernel


@functools.partial(jax.jit, static_argnames=("k_bits", "block_m", "block_n",
                                             "block_k_words", "interpret"))
def binary_matmul(a_packed: jnp.ndarray, b_packed: jnp.ndarray, k_bits: int,
                  block_m: int = DEFAULT_BLOCK_M,
                  block_n: int = DEFAULT_BLOCK_N,
                  block_k_words: int = DEFAULT_BLOCK_K_WORDS,
                  interpret: bool = True) -> jnp.ndarray:
    """(M, Kw) x (N, Kw) packed uint32 -> (M, N) int32 = K - 2*popcnt(xor).

    Padding bits beyond k_bits must be zero in both operands (0 XOR 0
    contributes nothing)."""
    m, kw = a_packed.shape
    n, kw2 = b_packed.shape
    assert kw == kw2
    bm, bn = min(block_m, m), min(block_n, n)
    bk = min(block_k_words, kw)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(kw, bk))
    return pl.pallas_call(
        _bmm_kernel(k_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_packed, b_packed)
