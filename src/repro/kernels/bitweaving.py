"""BitWeaving-V predicate scan kernel (Section 8.2).

Evaluates `c1 <= v <= c2` over a bit-sliced column: plane i of the input
holds bit (b-1-i) (MSB first) of every value, packed 32 values per uint32
word. The comparison runs MSB->LSB keeping three packed masks (gt, lt, eq)
per constant - exactly the BitWeaving algorithm, where every step is a bulk
bitwise op (the workload Ambit accelerates; here fused into one VMEM pass).

The plane loop (b <= 32) is unrolled statically inside the kernel, so the
entire predicate costs one HBM read of the planes and one write of the
result bitvector: arithmetic intensity ~6b ops / (4b+4) bytes/word, still
memory-bound but ~32x less traffic than scanning 32-bit values.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_WORDS = 512


def _bw_kernel(b: int, c1: int, c2: int):
    def kernel(p_ref, o_ref):
        ones = jnp.uint32(0xFFFFFFFF)
        zero = jnp.uint32(0)
        shape = p_ref[0, :].shape

        def cmp(const):
            gt = jnp.zeros(shape, jnp.uint32)
            lt = jnp.zeros(shape, jnp.uint32)
            eq = jnp.full(shape, ones)
            for i in range(b):
                cbit = (const >> (b - 1 - i)) & 1
                p = p_ref[i, :]
                if cbit:
                    lt = lt | (eq & ~p)
                else:
                    gt = gt | (eq & p)
                eq = eq & ~(p ^ (ones if cbit else zero))
            return gt, lt, eq

        gt1, lt1, eq1 = cmp(c1)
        gt2, lt2, eq2 = cmp(c2)
        o_ref[...] = ((gt1 | eq1) & (lt2 | eq2)).reshape(o_ref.shape)

    return kernel


@functools.partial(jax.jit, static_argnames=("c1", "c2", "block_words",
                                             "interpret"))
def bitweaving_scan(planes: jnp.ndarray, c1: int, c2: int,
                    block_words: int = DEFAULT_BLOCK_WORDS,
                    interpret: bool = True) -> jnp.ndarray:
    """(b, words) uint32 planes -> (words,) packed predicate bitvector."""
    b, words = planes.shape
    bw = min(block_words, words)
    grid = (pl.cdiv(words, bw),)
    out = pl.pallas_call(
        _bw_kernel(b, c1, c2),
        grid=grid,
        in_specs=[pl.BlockSpec((b, bw), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, words), jnp.uint32),
        interpret=interpret,
    )(planes)
    return out[0]
