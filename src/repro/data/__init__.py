from .pipeline import (CorpusMeta, DataConfig, FilteredSyntheticLM,
                       SyntheticLM, filter_documents, synth_corpus_meta)
