"""Deterministic, resumable synthetic data pipeline with BitWeaving-based
document filtering (the paper's Section 8.2 workload embedded in the LM
data path).

Design for fault tolerance: batches are a pure function of the step index
(`batch_at(step)`), so resuming after a failure needs only the step number
from the checkpoint manifest - no iterator state, no data loss, identical
batches on replay. Sharding: each data-parallel shard slices its rows from
the global batch deterministically.

The synthetic corpus is a mixture of "documents" with metadata columns
(quality score, length, language id). The pipeline bit-slices the metadata
and evaluates the selection predicate (q1 <= quality <= q2 AND len >= L)
with the BitWeaving kernel + bulk bitwise AND - the Ambit engine doing
real work in the data path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structured-sequence params (makes loss learnable: next token is a
    # deterministic function of the previous two plus noise)
    noise: float = 0.05


class SyntheticLM:
    """Stateless synthetic LM stream: batch_at(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.uint64(cfg.seed * 1_000_003 + step * 65_537 + shard))
        s = cfg.seq_len + 1
        # Markov-ish structure: x[t] = (a*x[t-1] + b*x[t-2] + c) % vocab
        a = rng.integers(1, 7, size=(b, 1))
        c = rng.integers(0, cfg.vocab, size=(b, 1))
        x = np.zeros((b, s), np.int64)
        x[:, 0] = rng.integers(0, cfg.vocab, size=b)
        x[:, 1] = rng.integers(0, cfg.vocab, size=b)
        for t in range(2, s):
            x[:, t] = (a[:, 0] * x[:, t - 1] + x[:, t - 2] + c[:, 0]) \
                % cfg.vocab
        noise_mask = rng.random((b, s)) < cfg.noise
        x = np.where(noise_mask, rng.integers(0, cfg.vocab, size=(b, s)), x)
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# BitWeaving document filter (Ambit engine in the data path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CorpusMeta:
    """Bit-sliced metadata columns for n documents."""

    quality: np.ndarray  # (n,) uint8  0..255
    length: np.ndarray   # (n,) uint16 in tokens
    lang: np.ndarray     # (n,) uint8 language id


def synth_corpus_meta(n_docs: int, seed: int = 0) -> CorpusMeta:
    rng = np.random.default_rng(seed)
    return CorpusMeta(
        quality=rng.integers(0, 256, n_docs).astype(np.uint16),
        length=rng.integers(0, 4096, n_docs).astype(np.uint16),
        lang=rng.integers(0, 16, n_docs).astype(np.uint16),
    )


def filter_documents(meta: CorpusMeta, q_min: int, q_max: int,
                     len_min: int, use_kernel: bool = True) -> np.ndarray:
    """Selection mask via BitWeaving predicate scans + bulk AND.

    Returns a boolean (n_docs,) mask. The scans run on the packed
    bit-sliced columns (32 docs/word); the combine is one fused bitwise
    AND - the exact Section 8.2 pattern."""
    from ..core.bitvector import unpack_bits
    from ..kernels import ops, ref

    n = len(meta.quality)
    pad = (-n) % 32
    q = np.pad(meta.quality, (0, pad))
    ln = np.pad(meta.length, (0, pad))
    qp = ref.bitslice(jnp.asarray(q), 8)
    lp = ref.bitslice(jnp.asarray(ln), 12)
    if use_kernel:
        sel_q = ops.bitweaving_scan(qp, q_min, q_max)
        sel_l = ops.bitweaving_scan(lp, len_min, 4095)
    else:
        sel_q = ref.bitweaving_scan(qp, q_min, q_max)
        sel_l = ref.bitweaving_scan(lp, len_min, 4095)
    both = jnp.asarray(sel_q) & jnp.asarray(sel_l)
    return np.asarray(unpack_bits(both, n))


class FilteredSyntheticLM(SyntheticLM):
    """SyntheticLM whose per-step document ids pass the BitWeaving filter
    (demonstrates the engine in the ingest path; selection is still a pure
    function of (seed, predicate) so resume determinism holds)."""

    def __init__(self, cfg: DataConfig, n_docs: int = 4096,
                 q_min: int = 64, q_max: int = 250, len_min: int = 256):
        super().__init__(cfg)
        self.meta = synth_corpus_meta(n_docs, cfg.seed)
        self.mask = filter_documents(self.meta, q_min, q_max, len_min)
        self.doc_ids = np.nonzero(self.mask)[0]
        if len(self.doc_ids) == 0:
            raise ValueError("filter selected zero documents")

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        batch = super().batch_at(step, shard, n_shards)
        rng = np.random.default_rng(np.uint64(self.cfg.seed + step))
        b = batch["tokens"].shape[0]
        batch["doc_ids"] = rng.choice(self.doc_ids, size=b).astype(np.int32)
        return batch
