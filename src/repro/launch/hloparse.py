"""Post-SPMD HLO text analysis: FLOPs / HBM traffic / collective bytes
with while-loop trip counts.

Why not compiled.cost_analysis(): XLA counts a while (lax.scan) body ONCE,
under-counting an L-layer scanned model by ~L x. This parser assigns every
computation an execution-count multiplier (while bodies x trip count,
fusion bodies inherit their caller) and weights costs accordingly.

The module analyzed is the per-partition SPMD program, so all returned
numbers are PER-DEVICE.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\([^)]*\)[^\n]*condition=%?([\w\.\-]+)[^\n]*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_PARAM_DECL_RE = re.compile(r"%?([\w\.\-]+):\s*(\(?[\w\[\],\s]+\)?)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

MAX_TRIP = 1_000_000  # ignore sentinel constants (INT_MAX bounds)


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _all_shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_nbytes(dt: str, dims: List[int]) -> int:
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES[dt]


class HloModule:
    """Parsed view: computations, per-op definitions, symbol shapes."""

    def __init__(self, hlo: str):
        self.comps: Dict[str, List[str]] = {}
        self.shapes: Dict[str, Tuple[str, List[int]]] = {}
        self.comp_params: Dict[str, List[str]] = {}
        name = None
        for raw in hlo.splitlines():
            ln = raw.strip()
            hdr = _COMP_HDR.match(raw) if raw and raw[0] in "%E" else None
            if hdr and raw.rstrip().endswith("{"):
                name = hdr.group(1)
                self.comps[name] = []
                self.comp_params[name] = []
                # parameter declarations carry shapes (ordered)
                header = raw.split("(", 1)[1].rsplit("->", 1)[0]
                for pm in _PARAM_DECL_RE.finditer(header):
                    dt, dims = _first_shape(pm.group(2))
                    if dt:
                        self.shapes[pm.group(1)] = (dt, dims)
                    self.comp_params[name].append(pm.group(1))
                continue
            if name is None or not ln or ln == "}":
                continue
            self.comps[name].append(ln)
            dm = _DEF_RE.match(ln)
            if dm:
                dt, dims = _first_shape(dm.group(2))
                self.shapes[dm.group(1)] = (dt, dims)

        self.mult = self._multipliers()

    def _multipliers(self) -> Dict[str, int]:
        mult: Dict[str, int] = defaultdict(lambda: 1)
        for _ in range(4):
            for cname, lines in self.comps.items():
                outer = mult[cname]
                body_txt = "\n".join(lines)
                for m in _WHILE_RE.finditer(body_txt):
                    cond, wbody = m.group(1), m.group(2)
                    tc = self._trip_count(cond)
                    mult[wbody] = max(mult[wbody], outer * tc)
                    mult[cond] = max(mult[cond], outer * tc)
                for m in _CALLS_RE.finditer(body_txt):
                    callee = m.group(1)
                    if callee in self.comps:
                        mult[callee] = max(mult[callee], outer)
        return mult

    def _trip_count(self, cond_name: str) -> int:
        lines = self.comps.get(cond_name, [])
        consts = []
        for ln in lines:
            for c in _CONST_RE.findall(ln):
                v = int(c)
                if 1 <= v <= MAX_TRIP:
                    consts.append(v)
        return max(consts) if consts else 1

    # -- queries ----------------------------------------------------------

    def dot_flops(self) -> float:
        """2 * prod(result) * prod(contracted lhs dims), trip-weighted."""
        total = 0.0
        for cname, lines in self.comps.items():
            factor = self.mult[cname]
            for ln in lines:
                if " dot(" not in ln:
                    continue
                dm = _DEF_RE.match(ln)
                if not dm:
                    continue
                rhs = dm.group(2)
                _, out_dims = _first_shape(rhs)
                args = rhs.split(" dot(", 1)[1].split(")", 1)[0]
                ops = _OPERAND_RE.findall(args)
                cm = _CONTRACT_RE.search(rhs)
                if not ops or cm is None:
                    continue
                lhs_dt, lhs_dims = self.shapes.get(ops[0], ("", []))
                k = 1
                for d in cm.group(1).split(","):
                    if d != "" and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                total += 2.0 * out_n * k * factor
        return total

    _TRIVIAL_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
                    "transpose", "reshape", "broadcast", "tuple",
                    "get-tuple-element", "iota", ""}

    def _is_trivial_fusion(self, callee: str) -> bool:
        """Fusions that only convert/copy/reshape would not exist on TPU
        (the CPU backend materializes bf16<->f32 promotion); treat them as
        free - consumers still pay to read their output."""
        for ln in self.comps.get(callee, []):
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            if self._op_kind(dm.group(2)) not in self._TRIVIAL_OPS:
                return False
        return True

    def _dus_update_bytes(self, callee: str) -> int:
        """If `callee` contains dynamic-update-slice ops, the fusion's
        RESULT is aliased in place (XLA donates while-carried buffers):
        actual HBM writes = the update slices, not the full buffer.
        Returns the summed update-operand bytes, or -1 if no dus."""
        total = -1
        for ln in self.comps.get(callee, []):
            if "dynamic-update-slice(" not in ln:
                continue
            args = ln.split("dynamic-update-slice(", 1)[1].split(")", 1)[0]
            ops = _OPERAND_RE.findall(args)
            if len(ops) >= 2:
                dt, dims = self.shapes.get(ops[1], ("", []))
                ub = _shape_nbytes(dt, dims)
                total = ub if total < 0 else total + ub
        return total

    def _sliced_read_bytes(self, callee: str, pos: int,
                           full_bytes: int) -> int:
        """If callee parameter `pos` is consumed via dynamic-slice/gather,
        the per-call HBM read is the SLICE size, not the full buffer
        (scan-stacked weights would otherwise be charged L x per step)."""
        params = self.comp_params.get(callee, [])
        if pos >= len(params):
            return full_bytes
        pname = params[pos]
        for ln in self.comps.get(callee, []):
            if ("dynamic-slice(" in ln or " gather(" in ln) and \
                    f"%{pname}" in ln.split("(", 1)[1]:
                dm = _DEF_RE.match(ln)
                if dm:
                    dt, dims = _first_shape(dm.group(2))
                    return _shape_nbytes(dt, dims)
        return full_bytes

    # Ops that fundamentally move HBM bytes (cannot be fused away).
    _ANCHOR_OPS = {"dot", "convolution", "scatter", "gather", "sort",
                   "dynamic-slice", "dynamic-update-slice", "reduce",
                   "reduce-window", "rng", "rng-bit-generator"}

    def _is_anchor_fusion(self, callee: str) -> bool:
        for ln in self.comps.get(callee, []):
            dm = _DEF_RE.match(ln)
            if dm and self._op_kind(dm.group(2)) in self._ANCHOR_OPS:
                return True
        return False

    def traffic_bytes(self) -> float:
        """HBM traffic under an IDEAL-FUSION model: only anchor ops (dots,
        convolutions, scatter/gather, sorts, reductions, collectives, and
        fusions containing one) move HBM bytes - each writes its result
        once and reads each distinct operand once; elementwise chains
        between anchors are assumed fully fused (as the TPU backend does;
        the CPU backend materializes them, which would inflate the memory
        term ~5-10x). Operands consumed only through dynamic-slice/gather
        inside a fusion are charged at slice size (else scan-stacked
        weights would be charged L x per step). Trip-weighted, per-device.
        Residual bias: CPU promotes bf16 math to f32 (~2x on activation
        buffers) - documented in EXPERIMENTS.md."""
        fused = set()
        for lines in self.comps.values():
            for ln in lines:
                for m in _CALLS_RE.finditer(ln):
                    fused.add(m.group(1))
        total = 0.0
        for cname, lines in self.comps.items():
            if cname in fused:
                continue
            factor = self.mult[cname]
            writes = 0.0
            reads: Dict[str, float] = {}
            for ln in lines:
                dm = _DEF_RE.match(ln)
                if not dm:
                    continue
                rhs = dm.group(2)
                opkind = self._op_kind(rhs)
                callee = None
                result_bytes = _all_shapes_bytes(rhs.split("(", 1)[0])
                if opkind == "fusion":
                    cm = _CALLS_RE.search(rhs)
                    callee = cm.group(1) if cm else None
                    if callee is None or not self._is_anchor_fusion(callee):
                        continue
                    # in-place dus: write = update slice, not full buffer
                    dus = self._dus_update_bytes(callee)
                    if dus >= 0:
                        writes += dus
                        continue  # carried buffer isn't re-read either
                elif opkind == "dynamic-update-slice":
                    args = rhs.split("(", 1)[1].split(")", 1)[0]
                    ops_ = _OPERAND_RE.findall(args)
                    if len(ops_) >= 2:
                        dt, dims = self.shapes.get(ops_[1], ("", []))
                        writes += _shape_nbytes(dt, dims)
                    continue
                elif opkind not in self._ANCHOR_OPS and not any(
                        opkind.startswith(c) for c in COLLECTIVES):
                    continue
                writes += result_bytes
                if opkind in ("dynamic-slice", "gather"):
                    # read ~= result size; big operand mostly untouched
                    writes += _all_shapes_bytes(rhs.split("(", 1)[0])
                    continue
                if "(" in rhs:
                    args = rhs.split("(", 1)[1].split(")", 1)[0]
                    for i, op in enumerate(_OPERAND_RE.findall(args)):
                        dt, dims = self.shapes.get(op, ("", []))
                        ob = _shape_nbytes(dt, dims)
                        if callee is not None and ob > 0:
                            ob = self._sliced_read_bytes(callee, i, ob)
                        if ob > 0:
                            prev = reads.get(op)
                            reads[op] = ob if prev is None else min(prev, ob)
            total += (writes + sum(reads.values())) * factor
        return total

    def collective_bytes(self) -> Tuple[int, Dict[str, int]]:
        """Wire-byte model per collective: result+operand sizes (a good
        proxy: ~2x tensor for ring all-reduce, ~tensor for gather/permute).
        -start ops are skipped; -done ops carry the result shape."""
        per_kind: Dict[str, int] = defaultdict(int)
        for cname, lines in self.comps.items():
            factor = self.mult[cname]
            for ln in lines:
                if "-start" in ln:
                    continue
                dm = _DEF_RE.match(ln)
                if not dm:
                    continue
                rhs = dm.group(2)
                opkind = self._op_kind(rhs)
                for kind in COLLECTIVES:
                    if opkind.startswith(kind):
                        nbytes = _all_shapes_bytes(rhs.split("(", 1)[0])
                        if "(" in rhs and not opkind.endswith("-done"):
                            args = rhs.split("(", 1)[1].split(")", 1)[0]
                            for op in _OPERAND_RE.findall(args):
                                dt, dims = self.shapes.get(op, ("", []))
                                nbytes += _shape_nbytes(dt, dims)
                        per_kind[kind] += nbytes * factor
                        break
        return sum(per_kind.values()), dict(per_kind)

    @staticmethod
    def _op_kind(rhs: str) -> str:
        """Op name from the rhs of '%x = type opname(...)'."""
        before_paren = rhs.split("(", 1)[0].strip()
        parts = before_paren.split()
        return parts[-1] if parts else ""


def dot_flops(hlo: str) -> float:
    return HloModule(hlo).dot_flops()


def traffic_bytes(hlo: str) -> float:
    return HloModule(hlo).traffic_bytes()


def collective_bytes(hlo: str) -> Tuple[int, Dict[str, int]]:
    return HloModule(hlo).collective_bytes()
