import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production meshes and record memory/cost/collective analyses.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices for the
(2,16,16) multi-pod mesh. Smoke tests and benchmarks do NOT import this
module, so they see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import REGISTRY, get_config, shape_applicable, SHAPES
from ..models import build_model
from ..models.param import ShardingRules, map_tree, spec_tree
from ..models.sharding_ctx import axis_rules
from ..optim.optimizer import OptimizerConfig
from ..train.step import make_train_step
from .hloparse import collective_bytes, dot_flops, traffic_bytes
from .mesh import make_production_mesh, mesh_shape_dict

# Hardware model (assignment constants): TPU v5e-like.
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def sharding_rules_for(shape_name: str, batch: int,
                       mesh_axes, ep2d: bool = False) -> ShardingRules:
    """Baseline rules per shape kind.

    decode_32k: the KV cache dominates memory and GQA kv_heads rarely
    divide the 16-way TP axis, so the cache SEQUENCE dim shards over
    "model" (decode softmax over a sharded seq lowers to psum-style
    collectives). kv_seq is listed before kv_heads in the cache axes, so
    it claims "model" first; archs whose kv_heads could shard get the
    same (equivalent-memory) layout.

    long_500k (batch=1): batch axes idle; the cache seq shards over BOTH
    data and model (512-way on the multi-pod mesh)."""
    rules = ShardingRules()
    if shape_name == "long_500k" or batch == 1:
        # batch axes idle; cache seq shards 512-way; weights replicate
        # over the idle data axis (FSDP gathers per decoded token would
        # dominate the collective term - SSPerf hillclimb 2, iter 3).
        return rules.with_overrides(batch=(), kv_seq=("data", "model"),
                                    embed=(), embed_pod=())
    if shape_name.startswith("decode"):
        # Serving: no FSDP on weights (per-token regathering would bind
        # the collective term); TP sharding carries the memory. 2D-EP
        # cells shard experts over (data x model) so the shard_map
        # boundary needs no weight movement (SSPerf hillclimb 3).
        over = dict(kv_seq=("model",), embed=(), embed_pod=())
        if ep2d:
            over["expert"] = ("data", "model")
        return rules.with_overrides(**over)
    return rules


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": sds((b, 1), i32), "pos": sds((b,), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["vision_embeds"] = sds((b, cfg.vision_tokens, cfg.d_model),
                                     jnp.bfloat16)
        batch["vision_positions"] = sds((b, cfg.vision_tokens), i32)
        batch["mrope_positions"] = sds((3, b, s), i32)
    if cfg.enc_dec and shape.kind != "decode":
        batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


def batch_spec(batch: Dict[str, Any], rules: ShardingRules,
               mesh_shape: Dict[str, int]) -> Dict[str, Any]:
    """PartitionSpecs for the input batch (batch dim over DP axes)."""
    from ..models.param import ParamDef, spec_for
    table = {}
    for k, v in batch.items():
        if k == "mrope_positions":
            axes = (None, "batch") + (None,) * (len(v.shape) - 2)
        else:
            axes = ("batch",) + (None,) * (len(v.shape) - 1)
        table[k] = spec_for(ParamDef(v.shape, axes, v.dtype), rules,
                            mesh_shape)
    return table


def build_cell(arch: str, shape_name: str, mesh) -> Tuple[Any, tuple, tuple]:
    """Returns (fn, arg_shapes, in_shardings) for jit lowering."""
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ms = mesh_shape_dict(mesh)
    ep2d = (shape.kind == "decode" and cfg.moe is not None
            and cfg.moe.n_experts >= 64)
    if ep2d:
        # serving config: pad experts to data*model for the 2D
        # expert-parallel path (weights stationary; SSPerf hillclimb 3)
        pad2d = ms.get("data", 1) * ms.get("model", 1)
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, pad_to=pad2d))
    model = build_model(cfg)
    rules = sharding_rules_for(shape_name, shape.global_batch, ms,
                               ep2d=ep2d)
    pspecs = model.param_specs(rules, ms)
    pshapes = model.param_shapes()
    batch = input_specs(arch, shape_name)
    bspecs = batch_spec(batch, rules, ms)

    def shard(tree_specs):
        return map_tree(lambda s: NamedSharding(mesh, s), tree_specs)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        step_fn = make_train_step(model, opt_cfg, mesh=mesh,
                                  remat="save_attn")
        state_shapes = {
            "params": pshapes,
            "opt": {"m": pshapes, "v": pshapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)},
        }
        state_specs = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()},
        }
        return (step_fn, (state_shapes, batch),
                (shard(state_specs), shard(bspecs)))

    if shape.kind == "prefill":
        def fn(params, b):
            return model.prefill(params, b, skv=shape.seq_len, mesh=mesh)
        serve_shapes = model.param_shapes(dtype=jnp.bfloat16)
        return fn, (serve_shapes, batch), (shard(pspecs), shard(bspecs))

    # decode
    cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len,
                                    rules, ms)

    def fn(params, caches, b):
        return model.decode_step(params, caches, b, mesh=mesh)

    serve_shapes = model.param_shapes(dtype=jnp.bfloat16)
    return (fn, (serve_shapes, cache_shapes, batch),
            (shard(pspecs), shard(cache_specs), shard(bspecs)))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    fn, arg_shapes, in_shardings = build_cell(arch, shape_name, mesh)

    ms = mesh_shape_dict(mesh)
    rules = sharding_rules_for(shape_name, shape.global_batch, ms)
    with mesh, axis_rules(rules, ms):
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*arg_shapes)
        compiled = lowered.compile()

    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        }
    except Exception as e:  # pragma: no cover - backend specific
        mem_info = {"error": str(e)}
    hlo = compiled.as_text()
    # Per-device, trip-count weighted (XLA cost_analysis counts scan bodies
    # once; see hloparse.py). collective bytes model: result+operand sizes.
    coll_total, coll_kinds = collective_bytes(hlo)
    flops_dev = dot_flops(hlo)
    bytes_dev = traffic_bytes(hlo)

    model = build_model(cfg)
    n_params = model.n_params()
    n_active = model.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch

    hlo_flops = flops_dev * n_chips        # global
    hlo_bytes = bytes_dev * n_chips
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    # collective bytes are parsed from the per-partition module = bytes
    # through EACH chip's links
    t_coll = coll_total / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # fraction of roofline: useful model FLOPs time vs the binding term
    ideal_s = model_flops / (n_chips * PEAK_FLOPS)
    roofline_fraction = ideal_s / bound if bound > 0 else 0.0

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "compile_s": round(t_compile, 1),
        "hlo_flops": hlo_flops,
        "hlo_bytes": hlo_bytes,
        "flops_per_chip": flops_dev,
        "bytes_per_chip": bytes_dev,
        "collective_bytes_per_chip": coll_total,
        "collective_bytes": coll_total,
        "collective_kinds": coll_kinds,
        "xla_cost_raw": {k: cost.get(k) for k in
                         ("flops", "bytes accessed")},
        "memory_analysis": mem_info,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops) if hlo_flops else 0,
        "roofline_fraction": roofline_fraction,
        "n_params": n_params, "n_active_params": n_active,
        "roofline": terms, "dominant": dominant,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{result['mesh']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in REGISTRY:
            for shape_name, shape in SHAPES.items():
                if shape_applicable(get_config(arch), shape):
                    cells.append((arch, shape_name))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape_name in cells:
        try:
            r = run_cell(arch, shape_name, args.multi_pod, args.out)
            terms = r["roofline"]
            print(f"OK  {arch:24s} {shape_name:12s} {r['mesh']:20s} "
                  f"compile={r['compile_s']:6.1f}s "
                  f"flops={r['hlo_flops']:.3e} bytes={r['hlo_bytes']:.3e} "
                  f"coll={r['collective_bytes']:.3e} "
                  f"dom={r['dominant']} "
                  f"roofline={r['roofline_fraction']:.3f} "
                  f"useful={r['useful_flops_ratio']:.3f}", flush=True)
            print(f"    memory_analysis: {r['memory_analysis']}", flush=True)
        except Exception:
            failures += 1
            print(f"FAIL {arch} {shape_name}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
