"""Production training driver: --arch <id> on whatever mesh is available.

Composes the full stack: mesh + sharding rules + model + AdamW +
BitWeaving-filtered data + async checkpointing + fault-tolerant
supervisor. On a multi-device host (or real pods) it shards via the same
ShardingRules the dry-run validates; on one device it runs locally.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 50 [--data-parallel 2 --model-parallel 4]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import Checkpointer
from ..configs import REGISTRY, get_config
from ..data.pipeline import DataConfig, FilteredSyntheticLM
from ..models import build_model
from ..models.param import ShardingRules, map_tree
from ..models.sharding_ctx import axis_rules
from ..optim.optimizer import OptimizerConfig
from ..runtime import Supervisor
from ..train.step import init_state, make_train_step
from .mesh import make_host_mesh, mesh_shape_dict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="0 = all devices on data axis")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/launch_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    n_dev = len(jax.devices())
    dp = args.data_parallel or max(1, n_dev // args.model_parallel)
    mesh = make_host_mesh(data=dp, model=args.model_parallel)
    ms = mesh_shape_dict(mesh)
    rules = ShardingRules()
    print(f"arch={cfg.name} N={model.n_params()/1e6:.1f}M params "
          f"mesh=({dp},{args.model_parallel}) devices={n_dev}")

    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = make_train_step(model, opt, mesh=mesh,
                              microbatches=args.microbatches)
    data = FilteredSyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch))

    pspecs = model.param_specs(rules, ms)
    shard = lambda t: map_tree(lambda s: NamedSharding(mesh, s), t)
    state_sharding = {"params": shard(pspecs),
                      "opt": {"m": shard(pspecs), "v": shard(pspecs),
                              "step": NamedSharding(mesh, P())}}
    bspec = NamedSharding(mesh, P(("data",), None))

    ck = Checkpointer(args.ckpt_dir, keep_n=3)
    start = 0
    if args.resume and ck.latest_step() is not None:
        start, tree = ck.restore(mesh=mesh,
                                 spec_tree={"params": pspecs,
                                            "opt": {"m": pspecs,
                                                    "v": pspecs,
                                                    "step": P()}})
        state = tree
        print(f"resumed from step {start} (elastic reshard onto "
              f"{n_dev} devices)")
    else:
        state = jax.device_put(init_state(model, jax.random.PRNGKey(0)),
                               state_sharding)

    def batch_at(s):
        b = data.batch_at(s)
        return {"tokens": jax.device_put(jnp.asarray(b["tokens"]), bspec),
                "labels": jax.device_put(jnp.asarray(b["labels"]), bspec)}

    with mesh, axis_rules(rules, ms):
        jitted = jax.jit(step_fn)
        sup = Supervisor(ck, checkpoint_every=25)
        state, hist = sup.run(state, batch_at, jitted, start, args.steps)
    losses = [h["loss"] for h in hist if "loss" in h]
    print(f"steps {start}->{args.steps}: loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
