"""Production serving driver: --arch <id>, batched prefill+decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --requests 8 --max-new 16
"""

import argparse
import time

import jax
import numpy as np

from ..configs import REGISTRY, get_config
from ..models import build_model
from ..serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_seq=args.max_seq,
                      batch_slots=args.slots,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(2, 12))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {len(r.prompt)} prompt -> {len(r.out)} tokens")
    print(f"{n_tok} tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s, "
          f"{args.slots} slots)")


if __name__ == "__main__":
    main()
