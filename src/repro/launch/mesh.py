"""Production mesh definitions.

make_production_mesh is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""

from __future__ import annotations

from typing import Dict

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16,16) ("data","model") = 256 chips.
    Multi-pod: (2,16,16) ("pod","data","model") = 512 chips; the pod axis
    composes with data for DP/FSDP (and optionally hosts pipeline stages).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_shape_dict(mesh: jax.sharding.Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
