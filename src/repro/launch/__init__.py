"""Launch layer: production meshes, multi-pod dry-run, training driver."""
