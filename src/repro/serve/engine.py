"""Batched serving loop: prefill + decode with fixed batch slots.

Continuous-batching-lite: a fixed number of decode slots; finished
sequences are replaced by queued requests at the next prefill boundary.
Greedy or temperature sampling. This is the host-side loop around the
jitted prefill/decode_step functions that the dry-run lowers for the
production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, max_seq: int,
                 batch_slots: int = 8, temperature: float = 0.0,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, skv=max_seq))
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve all requests, `slots` at a time (padded static batch)."""
        for lo in range(0, len(requests), self.slots):
            self._generate_batch(requests[lo:lo + self.slots])
        return requests

    def _generate_batch(self, reqs: List[Request]) -> None:
        b = self.slots
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, caches = self._prefill(self.params, batch)
        pos = jnp.full((b,), plen, jnp.int32)
        tok = self._sample(logits)
        max_new = max(r.max_new_tokens for r in reqs)
        done = np.zeros(b, bool)
        for i, r in enumerate(reqs):
            r.out.append(int(tok[i]))
        for _ in range(max_new - 1):
            logits, caches = self._decode(
                self.params, caches,
                {"tokens": tok[:, None], "pos": pos})
            tok = self._sample(logits)
            pos = pos + 1
            if bool((pos >= self.max_seq - 1).any()):
                break
            for i, r in enumerate(reqs):
                if done[i] or len(r.out) >= r.max_new_tokens:
                    done[i] = True
                    continue
                t = int(tok[i])
                if r.eos_id is not None and t == r.eos_id:
                    done[i] = True
                    r.done = True
                    continue
                r.out.append(t)
            if done.all():
                break
        for r in reqs:
            r.done = True
