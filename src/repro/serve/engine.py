"""Batched serving loop: prefill + decode with fixed batch slots.

Continuous-batching-lite: a fixed number of decode slots; finished
sequences are replaced by queued requests at the next prefill boundary.
Greedy or temperature sampling. This is the host-side loop around the
jitted prefill/decode_step functions that the dry-run lowers for the
production mesh.

Termination contract: EVERY sampled token - including the one sampled
from the prefill logits - is checked against ``eos_id`` before it is
recorded; a request is marked ``done`` the moment it finishes (EOS or
``max_new_tokens`` reached), not in a blanket pass afterwards; and the
decode loop stops as soon as every *real* request is finished - padded
slots of a partial batch never keep it alive. ``decode_steps`` counts
the decode iterations actually executed, so tests (and the serving
metrics) can assert no wasted steps.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from ..obs import MetricsRegistry


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, max_seq: int,
                 batch_slots: int = 8, temperature: float = 0.0,
                 seed: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.decode_steps = 0       # decode iterations actually executed
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, skv=max_seq))
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve all requests, `slots` at a time (padded static batch).
        ``generate([])`` is a no-op; invalid requests raise before any
        prefill runs (no partial generation on bad input)."""
        for r in requests:
            if len(r.prompt) == 0:
                raise ValueError("empty prompt (nothing to prefill)")
            if len(r.prompt) > self.max_seq:
                raise ValueError(
                    f"prompt length {len(r.prompt)} exceeds max_seq="
                    f"{self.max_seq} (the KV cache would be written out "
                    "of range)")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"max_new_tokens={r.max_new_tokens} must be >= 1")
        for lo in range(0, len(requests), self.slots):
            self._generate_batch(requests[lo:lo + self.slots])
        return requests

    def _record(self, reqs: Sequence[Request], tok: jnp.ndarray,
                done: np.ndarray) -> None:
        """Record one sampled token per still-running request, applying
        the EOS check and max_new_tokens cutoff uniformly (the prefill
        token goes through this exact path too)."""
        for i, r in enumerate(reqs):
            if done[i]:
                continue
            t = int(tok[i])
            if r.eos_id is not None and t == r.eos_id:
                done[i] = True
                r.done = True
                self.metrics.counter("serve_requests_completed").inc(
                    1, reason="eos")
                continue
            r.out.append(t)
            self.metrics.counter("serve_tokens_sampled").inc(1)
            if len(r.out) >= r.max_new_tokens:
                done[i] = True
                r.done = True
                self.metrics.counter("serve_requests_completed").inc(
                    1, reason="max_new_tokens")

    def _generate_batch(self, reqs: List[Request]) -> None:
        b = self.slots
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, caches = self._prefill(self.params, batch)
        self.metrics.counter("serve_prefill_batches").inc(1)
        self.metrics.counter("serve_prefill_tokens").inc(len(reqs) * plen)
        pos = jnp.full((b,), plen, jnp.int32)
        tok = self._sample(logits)
        max_new = max(r.max_new_tokens for r in reqs)
        done = np.zeros(b, bool)
        done[len(reqs):] = True         # padded slots: nothing to serve
        self._record(reqs, tok, done)
        for _ in range(max_new - 1):
            if done.all() or bool((pos >= self.max_seq - 1).all()):
                break                   # pos is uniform across slots
            logits, caches = self._decode(
                self.params, caches,
                {"tokens": tok[:, None], "pos": pos})
            self.decode_steps += 1
            self.metrics.counter("serve_decode_steps").inc(1)
            tok = self._sample(logits)
            pos = pos + 1
            self._record(reqs, tok, done)
        for r in reqs:
            if not r.done:      # decode loop exhausted max_seq first
                self.metrics.counter("serve_requests_completed").inc(
                    1, reason="truncated")
            r.done = True
