from .engine import Request, ServeEngine
from .frontend import (QueryFrontend, QueryRecord, ServingReport,
                       TenantQuota, roofline_epoch_cost, run_closed_loop)

__all__ = [
    "Request", "ServeEngine",
    "QueryFrontend", "QueryRecord", "ServingReport", "TenantQuota",
    "roofline_epoch_cost", "run_closed_loop",
]
