"""Continuous-batching query frontend over ``AmbitRuntime.submit/drain``.

PRs 4-5 built the batch substrate - tickets, epoch packing, fused
stacked dispatch - but nothing *drove* it under load. ``QueryFrontend``
is the serving layer a deployment would run: many tenants submit bulk
bitwise queries, an admission queue applies per-tenant quotas, and a
batching window collects admitted queries until it either fills
(``max_batch`` queries - the epoch-packing sweet spot) or a deadline
expires (``window_ns`` on the simulated clock) - the continuous-batching
idiom from LLM serving, applied to in-DRAM analytics.

Everything is measured, nothing is wall clock:

  * the simulated clock advances by the scheduler's **drain timeline** -
    epochs laid end to end, each costing its measured DRAM-model ns
    (``ambit_sim``) or a deterministic roofline model over measured
    bytes (accelerator backends, whose DRAM ledger is zero by design);
  * per-query latency = completion time minus *arrival* time on that
    clock, so it includes backlog wait (quota), window wait (batching)
    and execution (epoch packing);
  * ``report()`` derives p50/p99/mean latency and queries/sec from the
    recorded timestamps - the ledgers are the ground truth, so the
    numbers are bit-reproducible across machines (CI diffs them).

Per-tenant state: ``TenantQuota.max_inflight`` caps how many of a
tenant's queries may be admitted-but-unfinished (admission skips
over-quota tenants WITHOUT blocking the queue behind them - a greedy
tenant cannot starve the rest), and ``TenantQuota.pin_bytes`` budgets
the tenant's pinned working set (``pin_working_set``), layered on the
store-level ``pin_budget_bytes`` cap.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from ..core import expr as E
from ..core.engine import OpStats
from ..core.simulator import AmbitError
from ..obs import NULL_TRACER, MetricsRegistry
from ..pim.scheduler import DONE, EpochReport, Ticket


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission-control knobs for one tenant."""

    max_inflight: int = 4       # admitted-but-unfinished query cap
    pin_bytes: int = 0          # pinned working-set budget
    #: per-query deadline on the simulated clock (None = none). A
    #: backlogged query already past its deadline is rejected at
    #: admission (error result, never executed); one that finishes past
    #: it is delivered but flagged ``timed_out``.
    deadline_ns: Optional[float] = None


@dataclasses.dataclass(eq=False)
class QueryRecord:
    """One query's life through the frontend, on the simulated clock:
    arrival (submit call) -> admission (quota passed, ticket created) ->
    finish (its drain epoch completed)."""

    seq: int
    tenant: str
    expression: E.Expr
    env: Dict[str, object]
    arrival_ns: float
    admitted_ns: float = -1.0
    finished_ns: float = -1.0
    ticket: Optional[Ticket] = None
    result: Optional[object] = None
    # Reliability surface: unrecoverable faults land here as an error
    # string (result stays None unless the host fallback served it);
    # ``fallback`` marks results computed on the host after the PIM
    # path failed; ``timed_out`` marks deadline misses.
    error: Optional[str] = None
    timed_out: bool = False
    fallback: bool = False

    @property
    def ok(self) -> bool:
        """The query produced a result (PIM path or host fallback)."""
        return self.error is None

    @property
    def latency_ns(self) -> float:
        """Arrival-to-completion, including backlog + window wait."""
        return self.finished_ns - self.arrival_ns

    @property
    def queue_ns(self) -> float:
        """Backlog wait before admission (quota / window pressure)."""
        return self.admitted_ns - self.arrival_ns

    def __repr__(self):
        return (f"<QueryRecord #{self.seq} {self.tenant!r} "
                f"lat={self.latency_ns:.0f}ns>")


@dataclasses.dataclass
class ServingReport:
    """Ledger-derived serving metrics. Latency percentiles use the
    nearest-rank definition over completed queries' arrival-to-completion
    times on the simulated clock; ``qps`` is completed queries divided by
    the clock span from first arrival to last completion."""

    completed: int = 0
    drains: int = 0
    fill_drains: int = 0        # window filled (max_batch admitted)
    deadline_drains: int = 0    # window_ns expired on the oldest query
    flush_drains: int = 0       # explicit flush() at end of load
    epochs: int = 0
    span_ns: float = 0.0
    qps: float = 0.0
    p50_ns: float = 0.0
    p99_ns: float = 0.0
    mean_ns: float = 0.0
    max_ns: float = 0.0
    stats: OpStats = dataclasses.field(default_factory=OpStats)
    # Reliability: queries surfaced as errors (unrecoverable faults /
    # admission-time deadline rejections), deadline misses, and queries
    # served by the host (jnp) fallback after the PIM path failed.
    errors: int = 0
    timeouts: int = 0
    fallbacks: int = 0


def _nearest_rank(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1,
            max(0, math.ceil(p * len(sorted_vals)) - 1))
    return sorted_vals[k]


def roofline_epoch_cost(launch_ns: float = 2_000.0,
                        bytes_per_ns: float = 819.0) -> Callable:
    """Deterministic epoch-cost model for the accelerator backends,
    whose DRAM-model ledger is zero by design (device_store.py): each
    epoch is ONE stacked kernel launch (the DevicePlanner contract), so
    it costs a fixed launch overhead plus HBM-roofline streaming time
    for the bytes it touches - every distinct operand array once, plus
    each query's result (819 bytes/ns = the 819 GB/s roofline
    benchmarks/kernels_micro.py models). Built from handle sizes, not
    wall clock, so the serving numbers stay machine-independent."""

    def cost(erep: EpochReport, tickets: List[Ticket]) -> float:
        seen, nbytes = set(), 0
        for t in tickets:
            for nm in sorted(t.env):
                v = t.env[nm]
                h = v.result if isinstance(v, Ticket) else v
                if h is not None and id(h) not in seen:
                    seen.add(id(h))
                    nbytes += h.device_bytes
            if t.result is not None and id(t.result) not in seen:
                seen.add(id(t.result))
                nbytes += t.result.device_bytes
        return launch_ns + nbytes / bytes_per_ns

    return cost


class QueryFrontend:
    """Admission queue + batching window over one AmbitRuntime.

    ``submit()`` never executes anything by itself: queries join the
    backlog, admission moves them into the current batching window
    (scheduler tickets) as quotas allow, and the window drains when it
    fills (``max_batch``) or its oldest admitted query has waited
    ``window_ns`` on the simulated clock. ``take_completed()`` hands
    finished queries back; ``flush()`` force-drains at end of load."""

    def __init__(self, runtime, window_ns: float = 50_000.0,
                 max_batch: int = 16,
                 default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 epoch_cost: Optional[Callable] = None,
                 optimize: bool = False):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.runtime = runtime
        self.window_ns = float(window_ns)
        self.max_batch = int(max_batch)
        # optimize=True routes every window drain through the scheduler's
        # cost-based optimizer (CSE + result cache); cache hits are
        # attributed per tenant on the shared opt_cache_hits counter.
        self.optimize = bool(optimize)
        self._host_engine = None    # lazy jnp fallback engine
        self.default_quota = default_quota
        self.quotas = dict(quotas or {})
        if epoch_cost is None and \
                getattr(runtime, "backend", "ambit_sim") != "ambit_sim":
            epoch_cost = roofline_epoch_cost()
        self._epoch_cost = epoch_cost
        self.clock_ns = 0.0
        self._first_arrival_ns: Optional[float] = None
        self._seq = 0
        self.backlog: deque = deque()       # arrived, not yet admitted
        self.window: List[QueryRecord] = []  # admitted, not yet drained
        self.completed: List[QueryRecord] = []
        self._inflight: Dict[str, int] = {}
        self._tenant_pinned: Dict[str, int] = {}
        self.report_counters = ServingReport()
        # Observability: share the runtime's registry/tracer so serving
        # series (admissions, quota skips, the latency histogram that
        # p50/p99 are views over) land next to the store/scheduler ones.
        self.metrics = getattr(runtime, "metrics", None)
        if self.metrics is None:
            self.metrics = MetricsRegistry()
        self.tracer = getattr(runtime, "tracer", NULL_TRACER)

    # -- quotas / pinned working sets -----------------------------------------

    def quota(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def inflight(self, tenant: str) -> int:
        return self._inflight.get(tenant, 0)

    def pin_working_set(self, tenant: str, handles: Iterable) -> int:
        """Pin a tenant's hot operands against BOTH budgets: the
        tenant's ``TenantQuota.pin_bytes`` and the store's global
        ``pin_budget_bytes``. All-or-nothing; returns bytes pinned."""
        handles = list(handles)
        budget = self.quota(tenant).pin_bytes
        used = self._tenant_pinned.get(tenant, 0)
        pinned: List[object] = []
        try:
            for h in handles:
                if used + h.device_bytes > budget:
                    raise AmbitError(
                        f"tenant {tenant!r} pin budget exceeded: "
                        f"{used} B pinned + {h.device_bytes} B would "
                        f"pass {budget} B")
                self.runtime.pin(h)     # store-level budget checks here
                pinned.append(h)
                used += h.device_bytes
        except AmbitError:
            for h in pinned:
                self.runtime.unpin(h)
            raise
        self._tenant_pinned[tenant] = used
        return sum(h.device_bytes for h in pinned)

    def unpin_working_set(self, tenant: str, handles: Iterable) -> None:
        for h in handles:
            self.runtime.unpin(h)
            self._tenant_pinned[tenant] = max(
                0, self._tenant_pinned.get(tenant, 0) - h.device_bytes)

    # -- submission / clock ----------------------------------------------------

    def submit(self, tenant: str, expression: E.Expr,
               env: Dict[str, object],
               arrival_ns: Optional[float] = None) -> QueryRecord:
        """Enqueue one query for ``tenant``. ``arrival_ns`` places the
        arrival on the simulated clock (defaults to "now"); the clock
        never runs backwards."""
        if arrival_ns is not None:
            self.clock_ns = max(self.clock_ns, float(arrival_ns))
        q = QueryRecord(seq=self._seq, tenant=tenant,
                        expression=expression, env=env,
                        arrival_ns=self.clock_ns if arrival_ns is None
                        else float(arrival_ns))
        self._seq += 1
        if self._first_arrival_ns is None:
            self._first_arrival_ns = q.arrival_ns
        self.backlog.append(q)
        self.metrics.counter("serve_submitted").inc(1, tenant=tenant)
        if self.tracer.enabled:
            self.tracer.instant(("frontend",), "arrive", "serve",
                                ts_ns=q.arrival_ns,
                                args={"tenant": tenant, "seq": q.seq})
        self._pump()
        return q

    def tick(self, now_ns: float) -> None:
        """Advance the simulated clock (e.g. between sparse arrivals) and
        fire any deadline drain that became due."""
        self.clock_ns = max(self.clock_ns, float(now_ns))
        self._pump()

    def take_completed(self) -> List[QueryRecord]:
        done, self.completed = self.completed, []
        return done

    def flush(self) -> None:
        """Drain until no query is backlogged or windowed (end of load)."""
        while self.window or self.backlog:
            if not self.window:
                self._admit()
                if not self.window:     # every backlogged tenant over
                    break               # quota with nothing in flight:
            self._drain("flush")        # impossible, but don't spin
            self._pump()

    # -- the batching window ---------------------------------------------------

    def _pump(self) -> None:
        """Admit from the backlog and drain the window until quiescent:
        fill drains when ``max_batch`` queries are admitted, deadline
        drains when the oldest admitted query has waited ``window_ns``."""
        while True:
            self._admit()
            if len(self.window) >= self.max_batch:
                self._drain("fill")
                continue
            if self.window and self.clock_ns - min(
                    q.admitted_ns for q in self.window) >= self.window_ns:
                self._drain("deadline")
                continue
            return

    def _admit(self) -> None:
        """FIFO admission with quota skips: walk the backlog in arrival
        order, admitting every query whose tenant is under its
        ``max_inflight`` quota until the window is full. Over-quota
        tenants are skipped, NOT blocked on - later tenants' queries
        admit past them, so one greedy tenant cannot starve the rest."""
        if len(self.window) >= self.max_batch:
            return
        keep: deque = deque()
        while self.backlog and len(self.window) < self.max_batch:
            q = self.backlog.popleft()
            ddl = self.quota(q.tenant).deadline_ns
            if ddl is not None and self.clock_ns - q.arrival_ns >= ddl:
                # Already overdue while backlogged: reject instead of
                # burning DRAM work on an answer nobody will take.
                q.error = (f"deadline exceeded in backlog "
                           f"({self.clock_ns - q.arrival_ns:.0f}ns "
                           f">= {ddl:.0f}ns)")
                q.timed_out = True
                q.admitted_ns = self.clock_ns
                q.finished_ns = self.clock_ns
                self.report_counters.timeouts += 1
                self.report_counters.errors += 1
                self.metrics.counter("serve_timeouts").inc(
                    1, tenant=q.tenant)
                self.metrics.counter("serve_errors").inc(1, tenant=q.tenant)
                if self.tracer.enabled:
                    self.tracer.instant(("frontend",), "timeout", "serve",
                                        ts_ns=self.clock_ns,
                                        args={"tenant": q.tenant,
                                              "seq": q.seq})
                self.completed.append(q)
                continue
            if self.inflight(q.tenant) >= self.quota(q.tenant).max_inflight:
                keep.append(q)          # over quota: skip, don't block
                self.metrics.counter("serve_quota_skips").inc(
                    1, tenant=q.tenant)
                if self.tracer.enabled:
                    self.tracer.instant(("frontend",), "quota_skip",
                                        "serve", ts_ns=self.clock_ns,
                                        args={"tenant": q.tenant,
                                              "seq": q.seq})
                continue
            q.ticket = self.runtime.submit(q.expression, q.env,
                                           now_ns=self.clock_ns)
            q.admitted_ns = self.clock_ns
            self._inflight[q.tenant] = self.inflight(q.tenant) + 1
            self.window.append(q)
            self.metrics.counter("serve_admitted").inc(1, tenant=q.tenant)
            if self.tracer.enabled:
                self.tracer.instant(("frontend",), "admit", "serve",
                                    ts_ns=self.clock_ns,
                                    args={"tenant": q.tenant,
                                          "seq": q.seq})
        keep.extend(self.backlog)
        self.backlog = keep

    def _drain(self, reason: str) -> None:
        group, self.window = self.window, []
        start_ns = self.clock_ns
        self.runtime.drain(now_ns=self.clock_ns,
                           epoch_cost=self._epoch_cost,
                           optimize=self.optimize)
        rep = self.runtime.last_drain
        self.clock_ns = rep.end_ns
        rc = self.report_counters
        rc.drains += 1
        rc.epochs += len(rep.epochs)
        if reason == "fill":
            rc.fill_drains += 1
        elif reason == "deadline":
            rc.deadline_drains += 1
        else:
            rc.flush_drains += 1
        rc.stats += rep.stats
        lat_hist = self.metrics.histogram("serve_latency_ns")
        queue_hist = self.metrics.histogram("serve_queue_ns")
        for q in group:
            tk = q.ticket
            q.finished_ns = tk.finished_ns if tk.finished_ns >= 0.0 \
                else rep.end_ns
            self._inflight[q.tenant] = max(0, self.inflight(q.tenant) - 1)
            if tk.state == DONE:
                q.result = tk.result
                if tk.cache_hit:
                    # per-tenant attribution on the shared optimizer
                    # counter (total() stays the cross-tenant hit count)
                    self.metrics.counter("opt_cache_hits").inc(
                        1, tenant=q.tenant)
            elif not self._try_host_fallback(q):
                # PIM path unrecoverable and the host can't serve it:
                # surface the fault as an error result, never a crash.
                q.error = tk.error or f"ticket {tk.state}"
                rc.errors += 1
                self.metrics.counter("serve_errors").inc(1, tenant=q.tenant)
            ddl = self.quota(q.tenant).deadline_ns
            if ddl is not None and q.error is None \
                    and q.latency_ns > ddl:
                q.timed_out = True      # delivered, but past deadline
                rc.timeouts += 1
                self.metrics.counter("serve_timeouts").inc(
                    1, tenant=q.tenant)
            if q.error is None:
                lat_hist.observe(q.latency_ns)
                queue_hist.observe(q.queue_ns)
                rc.completed += 1
                self.metrics.counter("serve_completed").inc(
                    1, tenant=q.tenant)
            self.completed.append(q)
        self.metrics.counter("serve_drains").inc(1, reason=reason)
        self.metrics.counter("serve_batched_queries").inc(len(group))
        if self.tracer.enabled:
            self.tracer.span(("frontend",), f"drain:{reason}", "serve",
                             start_ns, rep.end_ns - start_ns,
                             args={"queries": len(group),
                                   "epochs": len(rep.epochs)})

    def _try_host_fallback(self, q: QueryRecord) -> bool:
        """Degraded-mode execution: when the PIM path failed, re-run the
        query on the host ``jnp`` engine from the operands' host copies.
        Only possible for unprotected handles whose data still exists -
        a lost handle (the failed device held the only copy) or a broken
        ticket dependency cannot be served. Billed honestly: reading a
        device-resident dirty operand back is a normal charged ``get``."""
        env: Dict[str, object] = {}
        try:
            for nm in sorted(q.env):
                v = q.env[nm]
                if isinstance(v, Ticket):
                    return False    # upstream ticket failed with it
                if getattr(v, "lost", False):
                    return False    # the data died with its device
                env[nm] = self.runtime.get(v)
            if self._host_engine is None:
                from ..core.engine import BulkBitwiseEngine
                self._host_engine = BulkBitwiseEngine(backend="jnp")
            q.result = self._host_engine.eval(q.expression, env)
        except AmbitError:
            return False
        q.fallback = True
        self.report_counters.fallbacks += 1
        self.metrics.counter("serve_host_fallbacks").inc(1, tenant=q.tenant)
        if self.tracer.enabled:
            self.tracer.instant(("frontend",), "host_fallback", "serve",
                                ts_ns=self.clock_ns,
                                args={"tenant": q.tenant, "seq": q.seq})
        return True

    # -- metrics ---------------------------------------------------------------

    def report(self) -> ServingReport:
        """Snapshot of the serving metrics so far, derived entirely from
        the recorded simulated-clock timestamps (see module docstring)."""
        rc = self.report_counters
        out = dataclasses.replace(rc, stats=OpStats())
        out.stats += rc.stats
        # p50/p99 are *views* over the shared registry's latency
        # histogram; with 0 completions everything degrades to 0.0 (and
        # the snapshot reports None, never NaN) - see metrics_snapshot().
        lat = sorted(self.metrics.histogram("serve_latency_ns").values())
        out.p50_ns = _nearest_rank(lat, 0.50)
        out.p99_ns = _nearest_rank(lat, 0.99)
        out.mean_ns = sum(lat) / len(lat) if lat else 0.0
        out.max_ns = lat[-1] if lat else 0.0
        t0 = self._first_arrival_ns or 0.0
        out.span_ns = max(0.0, self.clock_ns - t0)
        out.qps = (out.completed / out.span_ns * 1e9
                   if out.span_ns > 0 else 0.0)
        return out

    def metrics_snapshot(self) -> Dict[str, object]:
        """JSON-safe snapshot of the shared registry plus the derived
        serving view. Percentiles over 0 completions are ``None`` (JSON
        null) - never NaN, never an exception - so downstream tooling can
        serialize with ``allow_nan=False``."""
        lat = self.metrics.histogram("serve_latency_ns")
        rep = self.report()
        snap = self.metrics.snapshot()
        snap["serving"] = {
            "completed": rep.completed,
            "drains": rep.drains,
            "epochs": rep.epochs,
            "span_ns": rep.span_ns,
            "qps": rep.qps,
            "p50_ns": lat.percentile(0.50),
            "p99_ns": lat.percentile(0.99),
            "mean_ns": rep.mean_ns if lat.count() else None,
            "max_ns": rep.max_ns if lat.count() else None,
            "errors": rep.errors,
            "timeouts": rep.timeouts,
            "fallbacks": rep.fallbacks,
        }
        return snap


def run_closed_loop(frontend: QueryFrontend, tenants: List[str],
                    next_query: Callable[[str, int],
                                         Tuple[E.Expr, Dict[str, object]]],
                    total_queries: int,
                    on_complete: Optional[Callable[[QueryRecord],
                                                   None]] = None) -> int:
    """Closed-loop load driver: every tenant keeps exactly one query
    outstanding - its next arrival is scheduled at the simulated instant
    its previous query finished (the standard closed-loop workload
    model, so offered load adapts to measured service rate instead of
    assuming one). ``next_query(tenant, k)`` supplies tenant's k-th
    query as ``(expression, env)``; issuance stops after
    ``total_queries`` and the frontend is flushed. Returns the number of
    completed queries observed."""
    import heapq

    heap = [(0.0, i, t) for i, t in enumerate(tenants)]
    heapq.heapify(heap)
    order = len(tenants)
    issued = 0
    seen = 0
    per_tenant: Dict[str, int] = {}

    def collect(resubmit: bool) -> None:
        nonlocal order, seen
        for done in frontend.take_completed():
            seen += 1
            if on_complete is not None:
                on_complete(done)
            if resubmit:
                heapq.heappush(heap, (done.finished_ns, order, done.tenant))
                order += 1

    while heap and issued < total_queries:
        ready_ns, _, tenant = heapq.heappop(heap)
        k = per_tenant.get(tenant, 0)
        expression, env = next_query(tenant, k)
        per_tenant[tenant] = k + 1
        frontend.submit(tenant, expression, env, arrival_ns=ready_ns)
        issued += 1
        collect(resubmit=issued < total_queries)
    frontend.flush()
    collect(resubmit=False)
    return seen
