from . import compression, step
