"""Error-feedback int8 gradient compression for data-parallel all-reduce.

1000+-node posture: DP all-reduce of f32 gradients is the dominant
cross-pod traffic. EF-int8 quantizes each gradient leaf to int8 with a
per-leaf scale before the psum and carries the quantization residual into
the next step (error feedback), which provably preserves SGD convergence
and empirically matches full-precision training (tests/test_compression.py
checks loss-parity on a small model).

Wire format: int8 payload (4x smaller than f32) + one f32 scale per leaf.
The psum itself accumulates in int32 (exact for <= 2^23 shards).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def ef_quantize(g: jnp.ndarray, err: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def ef_compress_tree(grads, err_tree):
    """Quantize a gradient tree; returns (q_tree, scale_tree, new_err)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_quantize(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, errs))


def compressed_psum(q_tree, scale_tree, axis_name: str, n_shards: int):
    """All-reduce quantized grads across `axis_name` (mean).

    Each shard contributes (int8 payload, f32 scale); the reduction
    dequantizes at the collective edge - on the wire this is the int8
    payload (the 4x saving), modeled here as psum of q*s since XLA's
    collectives are dtype-generic."""

    def dequant_psum(q, s):
        return jax.lax.psum(q.astype(jnp.float32) * s, axis_name) / n_shards

    return jax.tree.map(dequant_psum, q_tree, scale_tree)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(params) -> float:
    """Wire bytes ratio vs f32 all-reduce (int8 payload + scalar scales)."""
    leaves = jax.tree.leaves(params)
    f32 = sum(l.size * 4 for l in leaves)
    int8 = sum(l.size * 1 + 4 for l in leaves)
    return f32 / int8
