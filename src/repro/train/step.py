"""Training step builder: loss, grads, microbatch accumulation, optimizer.

`make_train_step(model, opt_cfg, ...)` returns a pure step function
suitable for jax.jit with in/out shardings from the model's spec trees.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..optim import optimizer as opt

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over masked tokens + z-loss (logit-norm regularizer)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    ce = lse - label_logit
    zl = jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(ce)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (ce * mask).sum() / denom, (zl * mask).sum() / denom


def make_loss_fn(model: Model, mesh=None, remat="save_attn"):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, mesh=mesh, remat=remat)
        ce, zl = cross_entropy(logits, batch["labels"],
                               batch.get("loss_mask"))
        loss = ce + AUX_LOSS_WEIGHT * aux + Z_LOSS_WEIGHT * zl
        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "ppl_log": ce}
        return loss, metrics

    return loss_fn


def init_state(model: Model, key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params)}


def make_train_step(model: Model, opt_cfg: opt.OptimizerConfig, mesh=None,
                    remat="save_attn", microbatches: int = 1):
    loss_fn = make_loss_fn(model, mesh=mesh, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // microbatches
                return x.reshape((microbatches, mb) + x.shape[1:])

            mbatches = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (zero, jnp.float32(0.0)), mbatches)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"loss": loss, "ce": loss,
                       "aux": jnp.float32(0.0), "ppl_log": loss}
        new_params, new_opt, opt_metrics = opt.update(
            opt_cfg, grads, state["opt"], params)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
