from . import optimizer
