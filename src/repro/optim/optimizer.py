"""AdamW with warmup+cosine schedule and global-norm clipping.

Hand-rolled (no optax dependency). Optimizer state mirrors the parameter
tree, so the same PartitionSpecs shard it (ZeRO-style: FSDP-sharded params
imply FSDP-sharded moments for free under GSPMD).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: OptimizerConfig, grads, opt_state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(tdef, new_p),
            {"m": jax.tree.unflatten(tdef, new_m),
             "v": jax.tree.unflatten(tdef, new_v), "step": step},
            metrics)
