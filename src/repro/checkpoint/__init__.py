from .checkpointing import Checkpointer
