"""Async checkpointing with elastic (mesh-changing) restore.

Layout:  <dir>/step_<N>/
           manifest.json   {step, keys, shapes, dtypes, partition specs}
           <flatkey>.npy   one file per leaf (per-shard in multi-host
                           deployments; this container has one host)

Properties needed at 1000+-node scale, all exercised in tests:
  * async: save runs on a background thread; training continues.
  * atomic: written into step_<N>.tmp then renamed - a crash mid-save
    never corrupts the latest checkpoint.
  * elastic restore: the manifest stores global shapes; restore rebuilds
    arrays and device_puts them under a NEW mesh/sharding (different pod
    count), which is exactly the reshard-on-recovery path.
  * retention: keep_n newest checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(_flatten(tree[k], f"{prefix}{k}{SEP}"))
        return out
    return [(prefix.rstrip(SEP), tree)]


def _unflatten(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in items.items():
        parts = key.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False) -> None:
        # Snapshot to host memory synchronously (cheap), write async.
        flat = _flatten(tree)
        host = [(k, np.asarray(v)) for k, v in flat]
        self.wait()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host:
            fname = key.replace(SEP, "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, mesh=None,
                spec_tree=None) -> Tuple[int, Any]:
        """Load a checkpoint; if (mesh, spec_tree) are given, device_put
        each leaf with its NamedSharding - this is the elastic-resharding
        path (the mesh may differ from the one that saved)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        specs = dict(_flatten(spec_tree)) if spec_tree is not None else {}
        items = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            if mesh is not None and key in specs:
                sharding = jax.sharding.NamedSharding(mesh, specs[key])
                items[key] = jax.device_put(arr, sharding)
            else:
                items[key] = jax.numpy.asarray(arr)
        return step, _unflatten(items)
