from .fault_tolerance import (HostFailure, StragglerWatchdog, Supervisor,
                              elastic_mesh_shape)
