"""Pipeline parallelism (GPipe-style) over a mesh axis via shard_map +
collective-permute.

Completes the parallelism family (DP/FSDP/TP/EP/SP + PP): on the
multi-pod mesh the "pod" axis can host pipeline stages instead of data
parallelism - stage s holds layers [s*L/S, (s+1)*L/S); microbatches
stream through with the classic (n_micro + n_stages - 1)-tick schedule;
inter-stage activations move by one ppermute hop per tick (neighbor
traffic only - exactly the cross-pod link topology, where all-reduce
bandwidth is scarcest).

The stage function must be shape-preserving ((mb, ...) -> (mb, ...)),
which transformer blocks satisfy. Differentiable end to end (autodiff
flows through ppermute and the schedule scan), so it composes with
jax.grad for training. Bubble fraction = (S-1)/(T+S-1); pick
n_micro >> n_stages.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.sharding_ctx import shard_map


def pipeline(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
             stage_params: Any, x_micro: jnp.ndarray, mesh,
             axis: str = "pod") -> jnp.ndarray:
    """Run x_micro (n_micro, mb, ...) through n_stages = mesh.shape[axis]
    pipeline stages. stage_params leaves are stacked (n_stages, ...) and
    sharded over `axis`. Returns (n_micro, mb, ...) outputs (replicated
    over `axis`)."""
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def shard_fn(params_local, xs):
        params_here = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        last = n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            cur, outputs = carry
            m_in = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(s == 0, xs[m_in], cur)
            y = stage_fn(params_here, inp)
            m_out = t - last
            emit = (s == last) & (m_out >= 0) & (m_out < n_micro)
            m_out_c = jnp.clip(m_out, 0, n_micro - 1)
            outputs = outputs.at[m_out_c].set(
                jnp.where(emit, y, outputs[m_out_c]))
            cur_next = jax.lax.ppermute(y, axis, perm) \
                if n_stages > 1 else y
            return (cur_next, outputs), None

        cur0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (cur, outputs), _ = jax.lax.scan(
            tick, (cur0, out0), jnp.arange(n_micro + n_stages - 1))
        # outputs live on the last stage only; share them with every stage
        outputs = jnp.where(s == last, outputs, 0)
        return jax.lax.psum(outputs, axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                     out_specs=P())(stage_params, x_micro)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule."""
    total = n_micro + n_stages - 1
    return (n_stages - 1) / total
