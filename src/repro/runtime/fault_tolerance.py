"""Fault tolerance: supervised step loop, straggler watchdog, elastic mesh.

The Supervisor wraps the training loop with checkpoint/restart semantics:
on a (simulated or real) host failure it restores the latest checkpoint
and continues - with a *smaller* mesh if hosts were lost (elastic).
The same code drives real multi-host recovery; the container exercises it
with injected failures (tests/test_fault_tolerance.py).

Straggler mitigation: per-step wall times feed an EWMA; steps slower than
`threshold x` the EWMA are flagged, and the policy hook decides (re-issue
the batch / drop the host from the next elastic mesh). At 1000+ nodes this
watchdog runs on the coordinator with per-host step acks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax

from ..checkpoint.checkpointing import Checkpointer


class HostFailure(RuntimeError):
    """Raised (or injected) when a host drops out mid-step."""

    def __init__(self, lost_hosts: int = 1):
        super().__init__(f"lost {lost_hosts} host(s)")
        self.lost_hosts = lost_hosts


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time monitor (Section: straggler mitigation)."""

    alpha: float = 0.1
    threshold: float = 3.0
    ewma: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        # slow steps don't poison the baseline estimate
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        else:
            self.flagged.append(step)
        return slow


def elastic_mesh_shape(n_devices: int, model_parallel: int
                       ) -> Dict[str, int]:
    """Largest (data, model) mesh using <= n_devices with fixed TP degree.
    Elastic policy: TP degree is preserved (resharding TP weights is
    expensive); the data axis shrinks to what survives."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than TP degree")
    data = n_devices // model_parallel
    return {"data": data, "model": model_parallel}


@dataclasses.dataclass
class Supervisor:
    """Checkpoint/restart wrapper around a step loop."""

    checkpointer: Checkpointer
    checkpoint_every: int = 50
    max_restarts: int = 10
    watchdog: StragglerWatchdog = dataclasses.field(
        default_factory=StragglerWatchdog)

    def run(self, state, data_fn: Callable[[int], dict],
            step_fn: Callable, start_step: int, n_steps: int,
            on_restore: Optional[Callable] = None,
            failure_injector: Optional[Callable[[int], None]] = None):
        """Runs steps [start_step, n_steps); returns (state, history).

        `on_restore(state_tree) -> state` lets the caller re-device_put
        under a (possibly new) mesh after a failure."""
        step = start_step
        restarts = 0
        history: List[Dict] = []
        while step < n_steps:
            try:
                t0 = time.monotonic()
                if failure_injector is not None:
                    failure_injector(step)
                batch = data_fn(step)
                state, metrics = step_fn(state, batch)
                dt = time.monotonic() - t0
                slow = self.watchdog.observe(step, dt)
                history.append({"step": step, "dt": dt, "slow": slow,
                                **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.checkpoint_every == 0:
                    self.checkpointer.save(step, state)
            except HostFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restore_step = self.checkpointer.latest_step()
                if restore_step is None:
                    restore_step, tree = start_step, None
                else:
                    self.checkpointer.wait()
                    restore_step, tree = self.checkpointer.restore()
                if tree is not None:
                    state = on_restore(tree) if on_restore else tree
                step = restore_step
                history.append({"step": step, "restart": restarts})
        self.checkpointer.save(n_steps, state, blocking=True)
        return state, history
