"""Labeled counters/gauges/histograms with one-source-of-truth intent.

The repo accounts everything in model units (simulated ns, nJ, bytes,
AAP macros) across several ad-hoc ledgers - ``OpStats``,
``ChannelLedger``, per-store byte counters, the serving frontend's
latency list. ``MetricsRegistry`` is the superset view: the layers
increment named, labeled series at the *same call sites* that update the
legacy ledgers, so the two stay bit-exactly reconciled (asserted by
tests/test_obs.py) and the legacy structs become views that can
eventually retire.

Design points:

  * label sets are canonicalised to sorted ``(key, value)`` tuples, so
    series identity never depends on kwarg order or dict iteration;
  * metrics are *always on* - increments are a dict add, cheap enough
    to not need gating, which is what makes reconciliation with the
    legacy ledgers unconditional (the opt-in knob is the span tracer);
  * ``Histogram.percentile`` uses the same nearest-rank definition as
    serve/frontend and returns ``None`` (never NaN, never raises) on an
    empty series - the p50/p99-on-0-or-1-completions edge cases;
  * ``snapshot()`` emits plain JSON-safe dicts with
    ``name{k=v,...}`` flat keys, byte-stable under ``json.dumps``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_key(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone sum per label set."""

    def __init__(self, name: str):
        self.name = name
        self.series: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _labels_key(labels)
        self.series[key] = self.series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return self.series.get(_labels_key(labels), 0.0)

    def total(self) -> float:
        return sum(self.series.values())


class Gauge:
    """Last-set value per label set."""

    def __init__(self, name: str):
        self.name = name
        self.series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self.series[_labels_key(labels)] = value

    def value(self, **labels) -> Optional[float]:
        return self.series.get(_labels_key(labels))


class Histogram:
    """Full-sample histogram (observations are kept, not bucketed -
    sample counts here are thousands, not billions, and exact
    percentiles are what the differential tests compare)."""

    def __init__(self, name: str):
        self.name = name
        self.series: Dict[LabelKey, List[float]] = {}

    def observe(self, value: float, **labels) -> None:
        self.series.setdefault(_labels_key(labels), []).append(value)

    def values(self, **labels) -> List[float]:
        return self.series.get(_labels_key(labels), [])

    def count(self, **labels) -> int:
        return len(self.values(**labels))

    def sum(self, **labels) -> float:
        return sum(self.values(**labels))

    def percentile(self, p: float, **labels) -> Optional[float]:
        """Nearest-rank percentile; ``None`` on an empty series (a
        single observation is every percentile of itself)."""
        vals = sorted(self.values(**labels))
        if not vals:
            return None
        import math
        k = min(len(vals) - 1, max(0, math.ceil(p * len(vals)) - 1))
        return vals[k]


class MetricsRegistry:
    """Namespace of metrics; ``counter``/``gauge``/``histogram`` are
    idempotent get-or-create so layers can share series by name."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def snapshot(self) -> dict:
        """JSON-safe dump: flat ``name{k=v}`` keys, sorted; histograms
        summarised as count/sum/p50/p99 (``None`` percentiles stay
        ``None`` -> JSON null, never NaN)."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self.counters):
            c = self.counters[name]
            for key in sorted(c.series):
                out["counters"][_fmt_key(name, key)] = c.series[key]
        for name in sorted(self.gauges):
            g = self.gauges[name]
            for key in sorted(g.series):
                out["gauges"][_fmt_key(name, key)] = g.series[key]
        for name in sorted(self.histograms):
            h = self.histograms[name]
            for key in sorted(h.series):
                vals = sorted(h.series[key])
                import math
                def _pct(p: float) -> Optional[float]:
                    if not vals:
                        return None
                    k = min(len(vals) - 1, max(0, math.ceil(p * len(vals)) - 1))
                    return vals[k]
                out["histograms"][_fmt_key(name, key)] = {
                    "count": len(vals),
                    "sum": sum(vals),
                    "p50": _pct(0.50),
                    "p99": _pct(0.99),
                }
        return out
