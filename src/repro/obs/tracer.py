"""Deterministic simulated-clock span tracer.

Every number this repo reports is model-derived (DRAM timing rules, the
channel cost model, the scheduler's epoch timeline) - never wall clock -
so a trace of a run is *reproducible*: identical inputs produce
byte-identical traces, and CI can diff them the same way it diffs
ledgers. The tracer records spans on that simulated clock:

  * **clocked spans** carry explicit ``[start_ns, start_ns + dur_ns)``
    positions on a caller-owned simulated clock (the scheduler's drain
    timeline, the serving frontend's arrival clock);
  * **cursor spans** (``tick``) land on a per-track *busy-time* cursor -
    each track is its own cumulative timeline of simulated busy ns
    (engine AAP batches, RowClone/PSM migrations), advanced only by the
    spans recorded on it;
  * **sequence instants** mark unclocked events (store IO, fused
    dispatches) in deterministic call order on their track.

Zero overhead when disabled: every method returns immediately off a
single ``enabled`` check and records nothing - the disabled singleton
``NULL_TRACER`` is the default everywhere, so untraced runs execute the
exact same accounting code paths (the differential tests assert the
ledgers are bit-identical with tracing on and off).

A ``track`` is a tuple of names, e.g. ``("device0", "bank3")`` or
``("scheduler",)``: the first element becomes the Perfetto process, the
full tuple the thread (see obs.export).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

Track = Tuple[str, ...]


@dataclasses.dataclass
class TraceEvent:
    """One recorded event. ``kind`` follows the Chrome trace-event
    phases: "X" complete span, "i" instant, "b"/"e" async span begin/end
    (``span_id`` scopes the pair)."""

    kind: str
    track: Track
    name: str
    cat: str
    ts_ns: float
    dur_ns: float = 0.0
    span_id: Optional[int] = None
    args: Optional[dict] = None


class Tracer:
    """Span recorder over simulated clocks (see module docstring).

    ``events`` is the append-only record in call order; exporters decide
    the wire format (obs.export.chrome_trace). ``enabled=False``
    constructs a no-op tracer - ``NULL_TRACER`` is the shared disabled
    instance layers default to."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[TraceEvent] = []
        self._cursors: Dict[Track, float] = {}
        self._seq: Dict[Track, int] = {}

    def clear(self) -> None:
        self.events.clear()
        self._cursors.clear()
        self._seq.clear()

    # -- clocked spans --------------------------------------------------------

    def span(self, track: Track, name: str, cat: str, start_ns: float,
             dur_ns: float, args: Optional[dict] = None) -> None:
        """Complete span at an explicit simulated-clock position."""
        if not self.enabled:
            return
        self.events.append(TraceEvent("X", track, name, cat,
                                      float(start_ns), float(dur_ns), None,
                                      args))

    def instant(self, track: Track, name: str, cat: str,
                ts_ns: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        """Instant event. With ``ts_ns=None`` the event lands at the
        track's sequence position (deterministic call order) instead of
        a clock position - unclocked layers (store IO) use this."""
        if not self.enabled:
            return
        if ts_ns is None:
            ts_ns = float(self._seq.get(track, 0))
            self._seq[track] = int(ts_ns) + 1
        self.events.append(TraceEvent("i", track, name, cat,
                                      float(ts_ns), 0.0, None, args))

    def async_begin(self, track: Track, name: str, cat: str, span_id: int,
                    ts_ns: float, args: Optional[dict] = None) -> None:
        """Begin an async (overlappable) span - query lifetimes overlap
        freely on one track, scoped by ``span_id``."""
        if not self.enabled:
            return
        self.events.append(TraceEvent("b", track, name, cat,
                                      float(ts_ns), 0.0, span_id, args))

    def async_end(self, track: Track, name: str, cat: str, span_id: int,
                  ts_ns: float, args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self.events.append(TraceEvent("e", track, name, cat,
                                      float(ts_ns), 0.0, span_id, args))

    # -- cursor (busy-time) spans ---------------------------------------------

    def cursor(self, track: Track) -> float:
        """The track's cumulative busy-time position."""
        return self._cursors.get(track, 0.0)

    def advance(self, track: Track, dur_ns: float) -> None:
        if not self.enabled:
            return
        self._cursors[track] = self._cursors.get(track, 0.0) + float(dur_ns)

    def tick(self, track: Track, name: str, cat: str, dur_ns: float,
             args: Optional[dict] = None) -> None:
        """Span at the track's busy-time cursor; advances the cursor by
        ``dur_ns`` so successive ticks lay end to end."""
        if not self.enabled:
            return
        t0 = self._cursors.get(track, 0.0)
        self.events.append(TraceEvent("X", track, name, cat, t0,
                                      float(dur_ns), None, args))
        self._cursors[track] = t0 + float(dur_ns)

    # -- introspection --------------------------------------------------------

    def __len__(self) -> int:
        return len(self.events)

    def spans(self, cat: Optional[str] = None) -> List[TraceEvent]:
        """Complete ("X") events, optionally filtered by category."""
        return [e for e in self.events
                if e.kind == "X" and (cat is None or e.cat == cat)]

    def __repr__(self):
        state = "enabled" if self.enabled else "disabled"
        return f"<Tracer {state} events={len(self.events)}>"


#: Shared disabled tracer: the default for every layer, so untraced runs
#: pay one boolean check per trace point and record nothing.
NULL_TRACER = Tracer(enabled=False)
