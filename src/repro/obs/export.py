"""Trace and metrics exporters.

``chrome_trace`` renders a Tracer's events as Chrome/Perfetto
trace-event JSON (load in https://ui.perfetto.dev or chrome://tracing):
each track's first name becomes the process, the full track tuple the
thread, so banks and devices show up as parallel swimlanes on the
simulated clock. Everything is deterministic - pids/tids are assigned
from the *sorted* track list, events stay in recorded order, and
``write_chrome_trace`` serialises with sorted keys - so identical runs
produce byte-identical files and CI diffs them directly.

Timestamps: Chrome's ``ts`` field is microseconds; we emit ``ns/1000``
for display but keep the exact simulated ``ns`` (and ``dur_ns``) in each
event's ``args`` so reports and tests reconcile without float-division
loss.

``utilization_report`` turns a drained runtime's metrics + drain report
into the text summary the benchmarks print: per-bank busy%, epoch
packing efficiency, channel-vs-compute overlap.
"""

from __future__ import annotations

import json
from typing import Dict, Optional

from .tracer import Tracer, Track


def chrome_trace(tracer: Tracer, process_name: str = "repro") -> dict:
    """Render events as a trace-event JSON object (dict, not string)."""
    tracks = sorted({e.track for e in tracer.events})
    pids: Dict[str, int] = {}
    tids: Dict[Track, int] = {}
    for track in tracks:
        group = track[0] if track else ""
        if group not in pids:
            pids[group] = len(pids) + 1
        if track not in tids:
            tids[track] = len(tids) + 1

    events = []
    for group in sorted(pids):
        events.append({
            "ph": "M", "name": "process_name", "pid": pids[group], "tid": 0,
            "args": {"name": f"{process_name}:{group}"},
        })
    for track in tracks:
        events.append({
            "ph": "M", "name": "thread_name", "pid": pids[track[0]],
            "tid": tids[track],
            "args": {"name": "/".join(track)},
        })
    for e in tracer.events:
        ev = {
            "ph": e.kind,
            "name": e.name,
            "cat": e.cat,
            "pid": pids[e.track[0]],
            "tid": tids[e.track],
            "ts": e.ts_ns / 1000.0,
            "args": dict(e.args or {}),
        }
        ev["args"]["ns"] = e.ts_ns
        if e.kind == "X":
            ev["dur"] = e.dur_ns / 1000.0
            ev["args"]["dur_ns"] = e.dur_ns
        if e.kind == "i":
            ev["s"] = "t"
        if e.span_id is not None:
            ev["id"] = e.span_id
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ns"}


def write_chrome_trace(tracer: Tracer, path: str,
                       process_name: str = "repro") -> None:
    """Serialise deterministically (sorted keys, fixed separators,
    trailing newline) so byte-level diffs work in CI."""
    doc = chrome_trace(tracer, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, separators=(",", ":"),
                  allow_nan=False)
        f.write("\n")


def utilization_report(tracer: Optional[Tracer] = None,
                       registry=None,
                       drain=None,
                       max_batch: Optional[int] = None) -> str:
    """Text utilization summary from any subset of {tracer, registry,
    drain report}; sections for absent inputs are skipped.

    - per-bank busy% comes from the ``bank_busy_ns`` counter over the
      drain wall time;
    - packing efficiency = queries / (epochs * max_batch) when
      ``max_batch`` is known, else mean queries-per-epoch;
    - channel-vs-compute overlap compares serialized channel ns with
      the compute-only epoch ns.
    """
    lines = []
    if drain is not None:
        wall = getattr(drain, "wall_ns", None)
        if wall is None:
            wall = sum(e.ns for e in drain.epochs)
        n_q = sum(len(e.tickets) for e in drain.epochs)
        lines.append("== drain ==")
        lines.append(f"epochs={len(drain.epochs)} queries={n_q} "
                     f"wall_ns={wall:.1f} serial_ns={drain.serial_ns:.1f}")
        if drain.epochs:
            chan = sum(e.channel_ns for e in drain.epochs)
            comp = sum(e.ns - e.channel_ns for e in drain.epochs)
            denom = chan + comp
            pct = (100.0 * chan / denom) if denom else 0.0
            lines.append(f"channel_ns={chan:.1f} compute_ns={comp:.1f} "
                         f"channel_share={pct:.1f}%")
            stall = getattr(drain, "refresh_stall_ns", 0.0)
            if stall:
                share = 100.0 * stall / wall if wall else 0.0
                lines.append(f"refresh_stall_ns={stall:.1f} "
                             f"refresh_share={share:.1f}%")
            if max_batch:
                eff = 100.0 * n_q / (len(drain.epochs) * max_batch)
                lines.append(f"packing_efficiency={eff:.1f}% "
                             f"(max_batch={max_batch})")
            else:
                lines.append(
                    f"queries_per_epoch={n_q / len(drain.epochs):.2f}")
    if registry is not None:
        busy = registry.counters.get("bank_busy_ns")
        if busy is not None and busy.series:
            lines.append("== per-bank busy ==")
            wall = None
            if drain is not None:
                wall = getattr(drain, "wall_ns", None)
            for key in sorted(busy.series):
                ns = busy.series[key]
                label = ",".join(f"{k}={v}" for k, v in key)
                if wall:
                    lines.append(f"bank[{label}] busy_ns={ns:.1f} "
                                 f"busy={100.0 * ns / wall:.1f}%")
                else:
                    lines.append(f"bank[{label}] busy_ns={ns:.1f}")
        stolen = registry.counters.get("refresh_stolen_ns")
        if stolen is not None and stolen.series:
            # The planner's steady-state refresh tax per bank: tRFC out
            # of every tREFI interleaved with the busy time above.
            lines.append("== refresh ==")
            for key in sorted(stolen.series):
                ns = stolen.series[key]
                label = ",".join(f"{k}={v}" for k, v in key)
                lines.append(f"refresh[{label}] stolen_ns={ns:.1f}")
        io = registry.counters.get("store_io_bytes")
        if io is not None and io.series:
            lines.append("== bytes by cause ==")
            for key in sorted(io.series):
                label = ",".join(f"{k}={v}" for k, v in key)
                lines.append(f"io[{label}] bytes={int(io.series[key])}")
    if tracer is not None and tracer.events:
        cats: Dict[str, int] = {}
        for e in tracer.events:
            cats[e.cat] = cats.get(e.cat, 0) + 1
        lines.append("== trace ==")
        lines.append(f"events={len(tracer.events)} " + " ".join(
            f"{c}={n}" for c, n in sorted(cats.items())))
    return "\n".join(lines)
