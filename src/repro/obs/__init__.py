"""Deterministic observability: simulated-clock tracing + metrics.

See tracer.py (spans), metrics.py (registry), export.py (Perfetto JSON
and text reports). Layers accept ``tracer=``/``metrics=`` and default to
the disabled ``NULL_TRACER`` / a private registry.
"""

from .tracer import NULL_TRACER, TraceEvent, Tracer
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .export import chrome_trace, utilization_report, write_chrome_trace

__all__ = [
    "NULL_TRACER",
    "TraceEvent",
    "Tracer",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "chrome_trace",
    "utilization_report",
    "write_chrome_trace",
]
