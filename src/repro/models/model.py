"""Public model API: build_model(cfg) -> Model with init / forward /
prefill / decode plus parameter-count accounting used by the roofline
(MODEL_FLOPS = 6*N*D, 2*N_active per decoded token).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import transformer
from .param import (ParamDef, ShardingRules, count_params, init_tree,
                    map_tree, shape_tree, spec_tree)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # -- parameters -----------------------------------------------------------

    def param_defs(self):
        return transformer.model_defs(self.cfg)

    def init(self, key: jax.Array):
        return init_tree(self.param_defs(), key)

    def param_shapes(self, dtype=None):
        defs = self.param_defs()
        if dtype is not None:
            import dataclasses as _dc
            defs = map_tree(lambda d: _dc.replace(d, dtype=dtype), defs)
        return shape_tree(defs)

    def param_specs(self, rules: ShardingRules, mesh_shape: Dict[str, int]):
        return spec_tree(self.param_defs(), rules, mesh_shape)

    def n_params(self) -> int:
        return count_params(self.param_defs())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of E experts)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.n_params()
        defs = self.param_defs()
        moe_total = count_params(defs["layers"]["moe"]) - int(
            np.prod(defs["layers"]["moe"]["router"].shape))
        from .moe import padded_experts
        e_pad = padded_experts(cfg.moe)
        active = moe_total * cfg.moe.top_k / e_pad
        return int(self.n_params() - moe_total + active)

    # -- compute --------------------------------------------------------------

    def forward(self, params, batch, mesh=None, remat: bool = False):
        return transformer.forward(params, self.cfg, batch, mesh=mesh,
                                   remat=remat)

    def prefill(self, params, batch, skv: Optional[int] = None, mesh=None):
        return transformer.prefill(params, self.cfg, batch, skv=skv,
                                   mesh=mesh)

    def decode_step(self, params, caches, batch, mesh=None):
        return transformer.decode_step(params, self.cfg, caches, batch,
                                       mesh=mesh)

    def cache_defs(self, batch: int, skv: int):
        return transformer.cache_defs(self.cfg, batch, skv)

    def cache_shapes(self, batch: int, skv: int):
        return shape_tree(self.cache_defs(batch, skv))

    def cache_specs(self, batch: int, skv: int, rules: ShardingRules,
                    mesh_shape: Dict[str, int]):
        return spec_tree(self.cache_defs(batch, skv), rules, mesh_shape)

    def init_cache(self, batch: int, skv: int):
        defs = self.cache_defs(batch, skv)
        return map_tree(lambda d: jnp.zeros(d.shape, d.dtype), defs)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
