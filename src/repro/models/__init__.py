"""Model zoo: the 10 assigned architectures as composable JAX stacks."""

from .model import Model, build_model
from .param import (ParamDef, ShardingRules, count_params, init_tree,
                    shape_tree, spec_tree)

__all__ = ["Model", "ParamDef", "ShardingRules", "build_model",
           "count_params", "init_tree", "shape_tree", "spec_tree"]
