"""Attention: chunked flash attention (training/prefill) + cached decode.

Flash attention is implemented as a lax.scan over KV blocks with an online
softmax (running max / normalizer / accumulator in f32), so the S x S score
matrix is never materialized - mandatory at 32k prefill. Masks (causal /
sliding-window / full) are computed from position arithmetic inside each
block; `window` may be a *traced* scalar so heterogeneous stacks (gemma3's
5:1 local:global pattern) scan a per-layer window through one compiled body.

GQA is computed in grouped form (B, S, Hkv, G, D) without materializing
repeated KV heads. KV heads shard over the model axis when divisible;
otherwise they replicate (e.g. qwen2.5's kv=2 on a 16-way TP axis) - the
ShardingRules handle this automatically.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef
from .layers import cast
from .sharding_ctx import axis_size, hint

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
DEFAULT_BLOCK_KV = 1024


def attn_defs(d: int, n_heads: int, n_kv: int, d_head: int, layers: int,
              qkv_bias: bool = False, dtype=jnp.float32, prefix_ok=True):
    defs = {
        "wq": ParamDef((layers, d, n_heads, d_head),
                       ("layers", "embed", "heads", None), dtype),
        "wk": ParamDef((layers, d, n_kv, d_head),
                       ("layers", "embed", "kv_heads", None), dtype),
        "wv": ParamDef((layers, d, n_kv, d_head),
                       ("layers", "embed", "kv_heads", None), dtype),
        "wo": ParamDef((layers, n_heads, d_head, d),
                       ("layers", "heads", None, "embed"), dtype),
    }
    if qkv_bias:
        defs["bq"] = ParamDef((layers, n_heads, d_head),
                              ("layers", "heads", None), dtype, init="zeros")
        defs["bk"] = ParamDef((layers, n_kv, d_head),
                              ("layers", "kv_heads", None), dtype,
                              init="zeros")
        defs["bv"] = ParamDef((layers, n_kv, d_head),
                              ("layers", "kv_heads", None), dtype,
                              init="zeros")
    return defs


def qkv_proj(p, x):
    """x (B,S,d) -> q (B,S,Hq,D), k,v (B,S,Hkv,D)."""
    q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"], x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, cast(p["wk"], x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, cast(p["wv"], x.dtype))
    if "bq" in p:
        q = q + cast(p["bq"], x.dtype)
        k = k + cast(p["bk"], x.dtype)
        v = v + cast(p["bv"], x.dtype)
    return q, k, v


def out_proj(p, o):
    return jnp.einsum("bshe,hed->bsd", o, cast(p["wo"], o.dtype))


# ---------------------------------------------------------------------------
# Flash attention (training / prefill)
# ---------------------------------------------------------------------------


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[jnp.ndarray] = None,
                    q_offset: int = 0,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    remat_blocks: bool = True) -> jnp.ndarray:
    """Online-softmax attention over KV blocks.

    q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D); Hq % Hkv == 0.
    window: optional traced scalar - attend only to kv in
    (q_pos - window, q_pos]; None = unbounded (plain causal/full).

    GQA note: KV heads are repeated to Hq before the einsums. Under GSPMD
    this keeps the head axis sharding unambiguous (q heads shard over the
    TP axis; the repeat of replicated KV is a local slice, no collective),
    where the grouped (B,S,Hkv,G,D) formulation lets the partitioner pick
    pathological shardings of the (Hkv,G) split.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    scale = 1.0 / (d ** 0.5)

    # Anchor shardings: scan carries/xs otherwise risk whole-subgraph
    # replication by the partitioner (see sharding_ctx.py). When the head
    # count does not divide the TP axis (granite 24H, gemma3 4H, whisper
    # 12H, qwen2-vl 28H on a 16-way axis) heads would replicate - shard
    # the q SEQUENCE over "model" instead (flash attention is
    # embarrassingly parallel over q blocks); KV stays replicated.
    heads_sharded = hq % axis_size("heads") == 0
    if heads_sharded:
        q_axes = ("batch", "seq", "heads", None)
        c_axes = ("batch", "heads", "seq")
    else:
        q_axes = ("batch", "attn_q_seq", None, None)
        c_axes = ("batch", None, "attn_q_seq")
    q = hint(q, *q_axes)
    k = hint(k, "batch", "seq", "heads" if heads_sharded else None, None)
    v = hint(v, "batch", "seq", "heads" if heads_sharded else None, None)

    bk = min(block_kv, skv)
    pad = (-skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (skv + pad) // bk
    kb = k.reshape(b, nb, bk, hq, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, bk, hq, d).transpose(1, 0, 2, 3, 4)
    kb = hint(kb, None, "batch", None,
              "heads" if heads_sharded else None, None)
    vb = hint(vb, None, "batch", None,
              "heads" if heads_sharded else None, None)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, idx = inputs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        kv_pos = idx * bk + jnp.arange(bk)
        valid = kv_pos[None, :] < skv  # padded tail
        mask = jnp.broadcast_to(valid, (sq, bk))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = hint(jnp.full((b, hq, sq), NEG_INF, jnp.float32), *c_axes)
    l0 = hint(jnp.zeros((b, hq, sq), jnp.float32), *c_axes)
    acc0 = hint(jnp.zeros((b, hq, sq, d), jnp.float32), *c_axes, None)
    # Flash-attention backward: rematerialize the per-block probability
    # matrices instead of letting autodiff stack them as (nb,B,H,Sq,bk)
    # f32 scan residuals - the classic FA recompute trade (2 extra block
    # matmuls in bwd for an O(S*S) -> O(S) memory/traffic cut). See
    # EXPERIMENTS.md SSPerf iteration A.
    scan_body = jax.checkpoint(body) if remat_blocks else body
    (m, l, acc), _ = jax.lax.scan(
        scan_body, (m0, l0, acc0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cached decode (one new token against a seq_len cache)
# ---------------------------------------------------------------------------


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray,
                     window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q (B,1,Hq,D); caches (B,Skv,Hkv,D); pos (B,) = index of the new token
    (entries kv_pos <= pos are valid). Single-pass softmax: the (B,Hq,Skv)
    score tensor is linear in Skv, which is the whole point of decode.

    GQA stays in GROUPED form here - repeating KV to Hq would read the
    cache Hq/Hkv (up to 16x) wider (SSPerf hillclimb 3). Decode shards the
    cache on kv_seq (not heads), so the grouped split is sharding-safe,
    unlike the training path (see flash_attention's GQA note)."""
    b, _, hq, d = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q[:, 0].reshape(b, hkv, g, d)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    # Scores must FOLLOW the cache's seq sharding: if kv_seq is sharded,
    # sharding heads here instead makes the PV einsum all-gather the whole
    # V cache (SSPerf hillclimb 2, zamba2 long_500k: 5.4 GB x9 gathers).
    if axis_size("kv_seq") > 1:
        s = hint(s, "batch", None, None, "kv_seq")
    else:
        s = hint(s, "batch", "kv_heads", None, "kv_seq")
    kv_pos = jnp.arange(skv)
    mask = kv_pos[None, :] <= pos[:, None]  # (B,Skv)
    if window is not None:
        mask = mask & (kv_pos[None, :] > (pos[:, None] - window))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    # Mirror flash_attention's accumulation order exactly (unnormalized
    # exp cast to the cache dtype, f32 PV accumulate, divide by the f32
    # normalizer last). softmax-then-cast rounds the probabilities in a
    # different direction than flash's cast-then-normalize; that ~1-ulp
    # per-layer skew compounds through deep stacks (gemma3's 5:1 pattern
    # forces 12 reduced layers) into >10% decode-vs-forward logit drift.
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-20)[..., None]
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def update_cache(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Insert one token's K/V at per-sequence positions. caches
    (B,Skv,Hkv,D); k_new/v_new (B,1,Hkv,D); pos (B,).

    Implemented as a masked elementwise write, NOT a scatter: GSPMD
    cannot partition a scatter into a seq-sharded cache and falls back to
    full rematerialization (replicate + re-shard = gathering the whole
    cache per token). The where-write keeps every shard local - each
    shard compares its own positions against `pos` (SSPerf hillclimb 2)."""
    skv = k_cache.shape[1]
    sel = (jnp.arange(skv)[None, :] == pos[:, None])[..., None, None]
    k_cache = jnp.where(sel, k_new.astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(sel, v_new.astype(v_cache.dtype), v_cache)
    return k_cache, v_cache
