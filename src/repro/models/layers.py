"""Common layers: RMSNorm, RoPE/M-RoPE, SwiGLU MLP, embeddings.

Pure functions over ParamDef-described pytrees; compute dtype is bf16 with
f32 for normalization statistics and softmax accumulators (MaxText-style
mixed precision). Weights stay in their stored dtype until cast at use.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef

COMPUTE_DTYPE = jnp.bfloat16


def cast(x, dtype=COMPUTE_DTYPE):
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_def(d: int, layers: Optional[int] = None) -> ParamDef:
    if layers is None:
        return ParamDef((d,), (None,), init="ones")
    return ParamDef((layers, d), ("layers", None), init="ones")


def rmsnorm(w, x, eps: float = 1e-6):
    # f32 statistics + f32 normalize, cast at the output. A bf16-rsqrt
    # variant was tried (SSPerf iteration D) and REFUTED: no traffic win
    # (the CPU backend promotes bf16 chains regardless; on TPU the norm
    # fuses into its neighbours) and a 20x decode-parity regression from
    # per-layer scale quantization. Keep f32.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * cast(w, x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x (B,S,H,D), positions (B,S) int -> rotated x. `theta` may be a traced
    scalar (gemma3 scans per-layer theta through the stack)."""
    d = x.shape[-1]
    half = d // 2
    log_theta = jnp.log(jnp.asarray(theta, jnp.float32))
    freqs = jnp.exp(-log_theta * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
          sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): positions (3,B,S) for (t,h,w); frequency
    bands are split across the three position streams per `sections`
    (which sum to head_dim/2)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angs = []
    lo = 0
    for s_idx, width in enumerate(sections):
        f = freqs[lo:lo + width]
        p = positions[s_idx].astype(jnp.float32)  # (B,S)
        angs.append(p[..., None] * f)
        lo += width
    ang = jnp.concatenate(angs, axis=-1)  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal absolute position embeddings (n, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) *
                    jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_defs(d: int, ff: int, layers: int, dtype=jnp.float32):
    lax_ = ("layers", "embed", "ffn")
    return {
        "w1": ParamDef((layers, d, ff), lax_, dtype),
        "w3": ParamDef((layers, d, ff), lax_, dtype),
        "w2": ParamDef((layers, ff, d), ("layers", "ffn", "embed"), dtype),
    }


def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def mlp(p, x, act: str = "silu"):
    h = _act(act)(x @ cast(p["w1"], x.dtype)) * (x @ cast(p["w3"], x.dtype))
    return h @ cast(p["w2"], x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(vocab: int, d: int, tie: bool, dtype=jnp.float32):
    defs = {"embed": ParamDef((vocab, d), ("vocab", "embed"), dtype,
                              scale=1.0)}
    if not tie:
        defs["unembed"] = ParamDef((d, vocab), ("embed", "vocab"), dtype)
    return defs


def embed(p, tokens: jnp.ndarray, dtype=COMPUTE_DTYPE) -> jnp.ndarray:
    return cast(p["embed"], dtype)[tokens]


def unembed(p, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in p:
        return x @ cast(p["unembed"], x.dtype)
    return x @ cast(p["embed"], x.dtype).T
