"""Ambient activation-sharding hints.

GSPMD propagates parameter shardings well, but scan carries (flash
attention's online-softmax state, decode caches, the layer residual
stream) need explicit anchors or the partitioner may replicate whole
subgraphs (observed: flash attention running with the full global batch
per device). `hint(x, *logical_axes)` applies
jax.lax.with_sharding_constraint using the ambient logical->mesh mapping;
outside a mesh context it is a no-op, so smoke tests and single-device
runs are unaffected.

The context is set at trace time by the launcher (dryrun/train) via
`axis_rules(rules, mesh_shape)`.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from .param import ParamDef, ShardingRules, spec_for

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_axis_rules", default=None)


@contextlib.contextmanager
def axis_rules(rules: ShardingRules, mesh_shape: Dict[str, int]):
    token = _CTX.set((rules, dict(mesh_shape)))
    try:
        yield
    finally:
        _CTX.reset(token)


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 if no ctx)."""
    ctx = _CTX.get()
    if ctx is None:
        return 1
    rules, mesh_shape = ctx
    size = 1
    for a in rules.lookup().get(logical, ()):
        size *= mesh_shape.get(a, 1)
    return size


def hint(x, *axes: Optional[str]):
    """Constrain activation x to the logical axes (None = replicated dim).
    Applies the same divisibility fallbacks as parameter sharding."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    rules, mesh_shape = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"hint axes {axes} vs shape {x.shape}")
    spec = spec_for(ParamDef(tuple(x.shape), tuple(axes)), rules, mesh_shape)
    return jax.lax.with_sharding_constraint(x, spec)


def hint_tree(tree, axes_fn):
    """Apply hints across a pytree; axes_fn(leaf) -> logical axes."""
    return jax.tree.map(lambda l: hint(l, *axes_fn(l)), tree)


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: new releases expose it as
    ``jax.shard_map(..., check_vma=)``, older ones as
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``. ``check``
    maps onto whichever replication-check kwarg the version has."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
