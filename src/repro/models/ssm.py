"""Mamba2 block: chunked SSD (state-space duality) + single-step decode.

The SSD dual form (arXiv:2405.21060) splits the sequence into chunks of
length Q: within a chunk the recurrence is computed as a masked quadratic
attention-like product (dense matmuls - MXU-friendly); across chunks a
linear scan propagates the (H, P, N) state. Training/prefill use the
chunked form; decode is the O(1) recurrent update.

Projections are separate matmuls (wz/wx/wB/wC/wdt) rather than one fused
in_proj: this keeps sharding clean (d_inner shards over the model axis;
the small B/C/dt projections replicate) and costs nothing - XLA fuses them.

Causal depthwise conv (width 4) is computed as 4 shifted adds; its state
(last W-1 inputs) is carried in the decode cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, SSMConfig
from .layers import cast, rmsnorm
from .param import ParamDef
from .sharding_ctx import hint


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    n_groups: int
    d_state: int
    gn: int
    conv_w: int


def ssm_dims(cfg: ArchConfig) -> SSMDims:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return SSMDims(d_inner, n_heads, s.head_dim, s.n_groups, s.d_state,
                   s.n_groups * s.d_state, s.conv_width)


def ssm_defs(cfg: ArchConfig, layers: int, dtype=jnp.float32):
    d = cfg.d_model
    dims = ssm_dims(cfg)
    di, h, gn, w = dims.d_inner, dims.n_heads, dims.gn, dims.conv_w
    lef = ("layers", "embed", "ffn")
    return {
        "wz": ParamDef((layers, d, di), lef, dtype),
        "wx": ParamDef((layers, d, di), lef, dtype),
        "wB": ParamDef((layers, d, gn), ("layers", "embed", None), dtype),
        "wC": ParamDef((layers, d, gn), ("layers", "embed", None), dtype),
        "wdt": ParamDef((layers, d, h), ("layers", "embed", "ssm_heads"),
                        dtype),
        "dt_bias": ParamDef((layers, h), ("layers", "ssm_heads"), dtype,
                            init="zeros"),
        "A_log": ParamDef((layers, h), ("layers", "ssm_heads"), dtype,
                          init="zeros"),
        "Dskip": ParamDef((layers, h), ("layers", "ssm_heads"), dtype,
                          init="ones"),
        "conv_x": ParamDef((layers, w, di), ("layers", None, "ffn"), dtype,
                           scale=0.5),
        "conv_B": ParamDef((layers, w, gn), ("layers", None, None), dtype,
                           scale=0.5),
        "conv_C": ParamDef((layers, w, gn), ("layers", None, None), dtype,
                           scale=0.5),
        "norm": ParamDef((layers, di), ("layers", "ffn"), dtype,
                         init="ones"),
        "wo": ParamDef((layers, di, d), ("layers", "ffn", "embed"), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,C), w (W,C). If `state` (B,W-1,C) is
    given it provides left context (prefill continuation)."""
    width = w.shape[0]
    if state is None:
        ctx = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        ctx = state.astype(x.dtype)
    full = jnp.concatenate([ctx, x], axis=1)
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(width):
        out = out + full[:, i:i + s] * cast(w[i], x.dtype)
    return out


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, dA: jnp.ndarray,
                bm: jnp.ndarray, cm: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD dual form.

    x (B,S,H,P), dt/dA (B,S,H) f32, bm/cm (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s_orig, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    q = min(chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        # Zero-padding is exact: padded steps have dt=0 => no state update,
        # zero decay contribution, zero output rows (sliced off below).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    nc = s // q
    rep = h // g

    def c(t, extra=()):  # chunk reshape (B,S,...) -> (B,nc,Q,...)
        return t.reshape((b, nc, q) + t.shape[2:])

    xc = c(x)
    dtc = c(dt)
    dac = c(dA)
    bc = jnp.repeat(c(bm), rep, axis=3)  # (B,nc,Q,H,N)
    cc = jnp.repeat(c(cm), rep, axis=3)

    a_cs = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H) cumulative log-decay

    # --- intra-chunk (quadratic within Q) ---------------------------------
    # scores[i,j] = (C_i . B_j) * exp(a_i - a_j) * dt_j   for i >= j
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc,
                    preferred_element_type=jnp.float32)
    decay = jnp.exp(a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((q, q), bool))
    scores = cb * decay * dtc[:, :, None, :, :]
    scores = jnp.where(tri[None, None, :, :, None], scores, 0.0)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # --- chunk states ------------------------------------------------------
    # state_c = sum_j exp(a_last - a_j) * dt_j * B_j (x) x_j
    w = jnp.exp(a_cs[:, :, -1:, :] - a_cs) * dtc  # (B,nc,Q,H)
    states = jnp.einsum("bckh,bckhn,bckhp->bchpn", w.astype(x.dtype), bc, xc,
                        preferred_element_type=jnp.float32)

    # --- inter-chunk linear scan -------------------------------------------
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])  # (B,nc,H)

    def body(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    init = (jnp.zeros((b, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(a_cs).astype(x.dtype), cc,
                         prev_states.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssm_block(p, x: jnp.ndarray, cfg: ArchConfig,
              cache: Optional[dict] = None, pos=None,
              return_cache: bool = False):
    """Full Mamba2 block. x (B,S,d).

    Training: cache=None. Prefill: return_cache=True -> returns
    (out, cache). Decode: cache given, S==1 -> recurrent update."""
    dims = ssm_dims(cfg)
    b, s, d = x.shape
    decode = cache is not None and s == 1 and not return_cache

    x = hint(x, "batch", "seq", None)
    z = x @ cast(p["wz"], x.dtype)
    xin = hint(x @ cast(p["wx"], x.dtype), "batch", "seq", "ffn")
    bproj = x @ cast(p["wB"], x.dtype)
    cproj = x @ cast(p["wC"], x.dtype)
    dt = (x @ cast(p["wdt"], x.dtype)).astype(jnp.float32)

    if decode:
        new_cache = {}
        window_x = jnp.concatenate([cache["conv_x"].astype(x.dtype), xin], 1)
        window_b = jnp.concatenate([cache["conv_B"].astype(x.dtype), bproj],
                                   1)
        window_c = jnp.concatenate([cache["conv_C"].astype(x.dtype), cproj],
                                   1)
        new_cache["conv_x"] = window_x[:, 1:]
        new_cache["conv_B"] = window_b[:, 1:]
        new_cache["conv_C"] = window_c[:, 1:]
        xin = jnp.einsum("bwc,wc->bc", window_x, cast(p["conv_x"], x.dtype))
        bproj = jnp.einsum("bwc,wc->bc", window_b, cast(p["conv_B"], x.dtype))
        cproj = jnp.einsum("bwc,wc->bc", window_c, cast(p["conv_C"], x.dtype))
        xin, bproj, cproj = (jax.nn.silu(t) for t in (xin, bproj, cproj))

        dtv = jax.nn.softplus(dt[:, 0] + p["dt_bias"].astype(jnp.float32))
        a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
        da = jnp.exp(dtv * a)  # (B,H)
        xh = xin.reshape(b, dims.n_heads, dims.head_dim)
        bh = jnp.repeat(bproj.reshape(b, dims.n_groups, dims.d_state),
                        dims.n_heads // dims.n_groups, 1)
        ch = jnp.repeat(cproj.reshape(b, dims.n_groups, dims.d_state),
                        dims.n_heads // dims.n_groups, 1)
        state = hint(cache["state"].astype(jnp.float32),
                     "batch", "ssm_heads", None, None)
        state = state * da[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtv, bh.astype(jnp.float32),
            xh.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", ch.astype(jnp.float32), state)
        y = y + p["Dskip"].astype(jnp.float32)[None, :, None] \
            * xh.astype(jnp.float32)
        y = y.reshape(b, 1, dims.d_inner).astype(x.dtype)
        new_cache["state"] = state
        z = z.reshape(b, 1, dims.d_inner)
    else:
        conv_state = None
        xin_raw, b_raw, c_raw = xin, bproj, cproj
        xin = jax.nn.silu(_causal_conv(xin, p["conv_x"]))
        bproj = jax.nn.silu(_causal_conv(bproj, p["conv_B"]))
        cproj = jax.nn.silu(_causal_conv(cproj, p["conv_C"]))
        dtv = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        da = dtv * a  # (B,S,H) log-decay
        xh = xin.reshape(b, s, dims.n_heads, dims.head_dim)
        bh = bproj.reshape(b, s, dims.n_groups, dims.d_state)
        ch = cproj.reshape(b, s, dims.n_groups, dims.d_state)
        init_state = cache["state"] if cache is not None else None
        y, final_state = ssd_chunked(xh, dtv, da, bh, ch, cfg.ssm.chunk,
                                     init_state)
        y = y + p["Dskip"].astype(x.dtype)[None, None, :, None] * xh
        y = y.reshape(b, s, dims.d_inner)
        if return_cache:
            w = dims.conv_w
            new_cache = {
                "conv_x": xin_raw[:, -(w - 1):],
                "conv_B": b_raw[:, -(w - 1):],
                "conv_C": c_raw[:, -(w - 1):],
                "state": final_state,
            }

    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ cast(p["wo"], x.dtype)
    if decode or return_cache:
        return out, new_cache
    return out


def ssm_cache_defs(cfg: ArchConfig, layers: int, batch: int,
                   dtype=jnp.bfloat16):
    """ShapeDtypeStruct-compatible defs for the decode cache."""
    dims = ssm_dims(cfg)
    w = dims.conv_w
    return {
        "conv_x": ParamDef((layers, batch, w - 1, dims.d_inner),
                           ("layers", "batch", None, "ffn"), dtype,
                           init="zeros"),
        "conv_B": ParamDef((layers, batch, w - 1, dims.gn),
                           ("layers", "batch", None, None), dtype,
                           init="zeros"),
        "conv_C": ParamDef((layers, batch, w - 1, dims.gn),
                           ("layers", "batch", None, None), dtype,
                           init="zeros"),
        "state": ParamDef((layers, batch, dims.n_heads, dims.head_dim,
                           dims.d_state),
                          ("layers", "batch", "ssm_heads", None, None),
                          jnp.float32, init="zeros"),
    }
