"""Parameter definition trees: one source of truth for shapes, dtypes,
logical sharding axes, and initializers.

A model's parameters are a nested dict of ParamDef. From it we derive:
  * shape_tree()  -> jax.ShapeDtypeStruct tree (dry-run lowering, no alloc)
  * init_tree()   -> materialized arrays (smoke tests / real training)
  * spec_tree()   -> PartitionSpec tree via ShardingRules (logical->mesh),
                     with automatic divisibility fallback (e.g. 2 GQA KV
                     heads cannot shard over a 16-way model axis -> None).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (or None)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: Optional[float] = None  # stddev override for "normal"/"scaled"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Tree = Dict[str, Any]  # nested dict of ParamDef / subtrees


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_tree(fn: Callable[[ParamDef], Any], tree: Tree) -> Tree:
    if not isinstance(tree, dict):
        return fn(tree)
    return {k: map_tree(fn, v) for k, v in tree.items()}


def shape_tree(tree: Tree) -> Tree:
    return map_tree(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def count_params(tree: Tree) -> int:
    total = 0

    def add(d: ParamDef):
        nonlocal total
        total += int(np.prod(d.shape))

    map_tree(add, tree)
    return total


def init_tree(tree: Tree, key: jax.Array) -> Tree:
    """Materialize parameters (used by smoke tests and real training)."""
    leaves = []

    def collect(d: ParamDef):
        leaves.append(d)
        return len(leaves) - 1

    indexed = map_tree(collect, tree)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(i_def):
        d = leaves[i_def]
        k = keys[i_def]
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)

    return map_tree(lambda i: make(i), indexed)


# ---------------------------------------------------------------------------
# Sharding rules: logical axis -> mesh axes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical->physical mapping. Tuples are mesh axis names (joined)."""

    rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("batch", ("pod", "data")),
        ("embed", ("data",)),        # FSDP shard of weight embed dims
        ("embed_pod", ("pod", "data")),  # multi-pod FSDP variant
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("ffn", ("model",)),
        ("vocab", ("model",)),
        ("expert", ("model",)),
        ("seq", ()),                  # sequence parallelism off by default
        ("attn_q_seq", ("model",)),   # q-seq sharding when heads don't
                                      # divide the TP axis (SSPerf iter B)
        ("kv_seq", ()),               # decode-cache sequence sharding
        ("layers", ()),
        ("conv_dim", ("model",)),
        ("ssm_heads", ("model",)),
    )

    def lookup(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self.rules)

    def with_overrides(self, **kw) -> "ShardingRules":
        d = self.lookup()
        for k, v in kw.items():
            d[k] = tuple(v) if v else ()
        return ShardingRules(tuple(sorted(d.items())))


def _axes_size(mesh_shape: Dict[str, int], axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh_shape.get(a, 1)
    return size


def spec_for(d: ParamDef, rules: ShardingRules,
             mesh_shape: Dict[str, int]) -> P:
    """PartitionSpec for one param: apply rules with divisibility checks and
    never reuse a mesh axis across dims (GSPMD requirement)."""
    table = rules.lookup()
    used: set = set()
    parts = []
    for dim, logical in zip(d.shape, d.axes):
        if logical is None:
            parts.append(None)
            continue
        axes = tuple(a for a in table.get(logical, ())
                     if a in mesh_shape and a not in used)
        if not axes or dim % _axes_size(mesh_shape, axes) != 0:
            # try prefixes (e.g. ("pod","data") -> ("pod",)) before giving up
            ok = ()
            for cut in range(len(axes) - 1, 0, -1):
                sub = axes[:cut]
                if dim % _axes_size(mesh_shape, sub) == 0:
                    ok = sub
                    break
            axes = ok
        if not axes:
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def spec_tree(tree: Tree, rules: ShardingRules,
              mesh_shape: Dict[str, int]) -> Tree:
    return map_tree(lambda d: spec_for(d, rules, mesh_shape), tree)


def logical_batch_spec(axes: Tuple[Optional[str], ...], rules: ShardingRules,
                       mesh_shape: Dict[str, int],
                       shape: Optional[Tuple[int, ...]] = None) -> P:
    """Spec for activations/inputs given logical axes (+ divisibility)."""
    d = ParamDef(tuple(shape) if shape else tuple(1 for _ in axes), axes)
    if shape is None:
        # without shapes we cannot check divisibility; map directly
        table = rules.lookup()
        used: set = set()
        parts = []
        for logical in axes:
            ax = tuple(a for a in table.get(logical, ())
                       if a in mesh_shape and a not in used) if logical else ()
            used.update(ax)
            parts.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        return P(*parts)
    return spec_for(d, rules, mesh_shape)
