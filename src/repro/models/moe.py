"""Mixture-of-Experts block with sort-based (FLOP-free) dispatch.

Design (DESIGN.md SS6): experts shard over the TP ("model") axis - expert
parallelism. Between layers, activations are replicated across the model
axis (standard TP), so each model-rank already holds every token: dispatch
needs NO all-to-all. Each rank sorts token->expert assignments, scatters
the tokens bound for ITS local experts into an (E_local, capacity, d)
buffer, runs the expert FFNs, scatter-adds gated outputs back to token
order, and psums across the model axis (merging with the TP reduction that
a dense FFN would need anyway).

Why sort-based instead of the GShard dense-dispatch einsum: the one-hot
(tokens, E, capacity) dispatch einsum costs T*E*C*d MAC-FLOPs - for
qwen3's 128 experts that is ~500x the useful expert FLOPs, destroying the
MODEL_FLOPS/HLO_FLOPS roofline ratio. Sort+scatter is O(T*k log) with zero
matmul waste.

Expert-count padding: when E doesn't divide the model axis (granite's 40
experts on 16-way TP), the config pads E to the next multiple (48); padded
experts get -inf router logits and are never selected (they cost memory,
not compute, and the pad fraction is reported by param accounting).

Ambit tie-in: expert-assignment sets are packed bitvectors;
`expert_bitmask_stats` computes per-expert loads/overflow with the
BulkBitwiseEngine (popcount over packed masks) - the bookkeeping side of
dispatch expressed as bulk bitwise ops (paper Sections 8.1/9.1).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, MoEConfig
from .layers import _act, cast
from .param import ParamDef
from .sharding_ctx import shard_map


def padded_experts(moe: MoEConfig, pad_to: Optional[int] = None) -> int:
    pad = pad_to if pad_to is not None else moe.pad_to
    return int(math.ceil(moe.n_experts / pad) * pad)


def moe_defs(cfg: ArchConfig, layers: int, dtype=jnp.float32):
    d = cfg.d_model
    moe = cfg.moe
    e = padded_experts(moe)
    ffe = moe.d_ff_expert
    return {
        "router": ParamDef((layers, d, e), ("layers", "embed", None),
                           jnp.float32),
        "w1": ParamDef((layers, e, d, ffe),
                       ("layers", "expert", "embed", None), dtype),
        "w3": ParamDef((layers, e, d, ffe),
                       ("layers", "expert", "embed", None), dtype),
        "w2": ParamDef((layers, e, ffe, d),
                       ("layers", "expert", None, "embed"), dtype),
    }


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    return max(int(math.ceil(n_tokens * moe.top_k / moe.n_experts
                             * moe.capacity_factor)), moe.top_k)


def _moe_local(x2d: jnp.ndarray, router: jnp.ndarray, w1: jnp.ndarray,
               w3: jnp.ndarray, w2: jnp.ndarray, *, moe: MoEConfig,
               e_pad: int, n_local: int, e_lo, act: str,
               capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-shard MoE: x2d (T, d) -> (partial_out (T, d), aux_loss).

    `e_lo` is the first local expert id (traced under shard_map);
    n_local/capacity are static."""
    t, d = x2d.shape
    k = moe.top_k
    logits = (x2d @ cast(router, x2d.dtype)).astype(jnp.float32)  # (T, E)
    if e_pad > moe.n_experts:  # mask padding experts
        pad_mask = jnp.arange(e_pad) >= moe.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gates_k, idx = jax.lax.top_k(logits, k)          # (T, k)
    gates_k = jax.nn.softmax(gates_k, axis=-1)

    # Slot-major dispatch (SSPerf iteration C): index from the expert
    # buffer side, so each rank gathers/scatters only its OWN experts'
    # n_local*capacity rows instead of all T*k assignments - a
    # model_size/capacity_factor (~13x) cut in dispatch HBM traffic vs
    # the token-major gather+masked-scatter formulation.
    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates_k.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(e_pad + 1))  # segment bounds
    counts = starts[1:] - starts[:-1]                     # (e_pad,)

    e_ids = e_lo + jnp.arange(n_local)                    # local experts
    slot = jnp.arange(capacity)
    src = starts[e_ids][:, None] + slot[None, :]          # (n_local, C)
    valid = slot[None, :] < counts[e_ids][:, None]
    src = jnp.clip(src, 0, t * k - 1)
    tok = st[src]                                         # (n_local, C)
    buf = x2d[tok] * valid[..., None].astype(x2d.dtype)

    h = jnp.einsum("ecd,edf->ecf", buf, cast(w1, buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, cast(w3, buf.dtype))
    y = jnp.einsum("ecf,efd->ecd", _act(act)(h) * u, cast(w2, buf.dtype))

    gate = (sg[src] * valid).astype(y.dtype)              # (n_local, C)
    out = jnp.zeros((t, d), x2d.dtype).at[tok.reshape(-1)].add(
        (y * gate[..., None]).reshape(-1, d))

    # Switch-style load-balance aux loss (computed on real experts only).
    probs = jax.nn.softmax(logits[:, :moe.n_experts], axis=-1)
    frac = counts[:moe.n_experts].astype(jnp.float32) / (t * k)
    aux = moe.n_experts * jnp.sum(frac * probs.mean(0))
    return out, aux


def _moe_ep2d(x_loc, router, w1, w3, w2, *, moe: MoEConfig, e_pad: int,
              act: str, capacity: int, s: int, d: int,
              batch_axes: Tuple[str, ...], n_model: int, n_data: int):
    """2D expert-parallel serving path: experts shard over (data x model),
    ONE expert slot per device; the (small) token batch is all-gathered
    and each device computes only its own expert's slots. Weights never
    cross the wire - the decode collective budget drops from
    3 x E_local x d x ffe per layer (FSDP weight gathers) to
    ~tokens x d (SSPerf hillclimb 3)."""
    bl = x_loc.shape[0]
    x2 = x_loc.reshape(bl * s, d)
    x_all = jax.lax.all_gather(x2, batch_axes, axis=0, tiled=True)
    t = x_all.shape[0]
    k = moe.top_k
    logits = (x_all @ cast(router, x_all.dtype)).astype(jnp.float32)
    if e_pad > moe.n_experts:
        pad_mask = jnp.arange(e_pad) >= moe.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gates_k, idx = jax.lax.top_k(logits, k)
    gates_k = jax.nn.softmax(gates_k, axis=-1)

    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates_k.reshape(-1)
    mine = jax.lax.axis_index("data") * n_model + \
        jax.lax.axis_index("model")
    match = flat_e == mine
    order = jnp.argsort(~match)          # stable: my assignments first
    sel = order[:capacity]
    valid = match[sel]
    tok = flat_t[sel]
    buf = x_all[tok] * valid[:, None].astype(x_all.dtype)   # (C, d)

    w1l, w3l, w2l = w1[0], w3[0], w2[0]  # the single local expert slot
    h = buf @ cast(w1l, buf.dtype)
    u = buf @ cast(w3l, buf.dtype)
    y = (_act(act)(h) * u) @ cast(w2l, buf.dtype)
    gate = (flat_g[sel] * valid).astype(y.dtype)
    partial = jnp.zeros((t, d), x_all.dtype).at[tok].add(y * gate[:, None])
    out = jax.lax.psum(partial, ("data", "model"))

    # slice this shard's rows back out (batch-major gather order)
    b_idx = jnp.int32(0)
    for a in batch_axes:
        b_idx = b_idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    out_loc = jax.lax.dynamic_slice_in_dim(out, b_idx * (bl * s), bl * s)

    probs = jax.nn.softmax(logits[:, :moe.n_experts], axis=-1)
    counts = jnp.zeros((e_pad,), jnp.float32).at[flat_e].add(1.0)
    frac = counts[:moe.n_experts] / (t * k)
    aux = moe.n_experts * jnp.sum(frac * probs.mean(0))
    return out_loc.reshape(bl, s, d), aux


def moe_block(p, x: jnp.ndarray, cfg: ArchConfig,
              mesh: Optional[jax.sharding.Mesh], act: str
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux scalar). Uses shard_map EP when the
    mesh has a >1 model axis; plain single-shard math otherwise. When the
    expert padding matches data*model (serving configs), the 2D
    expert-parallel path keeps weights stationary."""
    moe = cfg.moe
    e_pad = padded_experts(moe)
    b, s, d = x.shape

    if mesh is None or "model" not in mesh.axis_names or \
            mesh.shape["model"] == 1:
        cap = _capacity(b * s, moe)
        fn = functools.partial(_moe_local, moe=moe, e_pad=e_pad,
                               n_local=e_pad, e_lo=0, act=act, capacity=cap)
        out, aux = fn(x.reshape(b * s, d), p["router"], p["w1"], p["w3"],
                      p["w2"])
        return out.reshape(b, s, d), aux

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_shards = 1
    for a in batch_axes:
        n_shards *= mesh.shape[a]
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)

    # 2D expert-parallel serving path: one expert slot per (data,model)
    # device, token batch gathered. Selected when the expert padding
    # matches the 2D device count (set via MoEConfig.pad_to in serving
    # configs) and the token count is gather-cheap.
    if e_pad == n_data * n_model and b * s <= 4096 and "data" in \
            mesh.axis_names and batch_axes:
        cap = max(_capacity(b * s, moe), 8)
        fn2 = functools.partial(
            _moe_ep2d, moe=moe, e_pad=e_pad, act=act, capacity=cap, s=s,
            d=d, batch_axes=batch_axes, n_model=n_model, n_data=n_data)
        out, aux = shard_map(
            fn2, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(None, None),
                      P(("data", "model"), None, None),
                      P(("data", "model"), None, None),
                      P(("data", "model"), None, None)),
            out_specs=(P(batch_axes, None, None), P()),
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
        return out, aux

    n_local = e_pad // n_model
    t_local = (b // n_shards) * s
    cap = _capacity(t_local, moe)

    def shard_fn(x_loc, router, w1, w3, w2):
        bl = x_loc.shape[0]
        e_lo = jax.lax.axis_index("model") * n_local
        out, aux = _moe_local(
            x_loc.reshape(bl * s, d), router, w1, w3, w2, moe=moe,
            e_pad=e_pad, n_local=n_local, e_lo=e_lo, act=act, capacity=cap)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(bl, s, d), aux

    out, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(batch_axes if batch_axes else None, None, None),
                  P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(batch_axes if batch_axes else None, None, None),
                   P()),
    )(x, p["router"], p["w1"], p["w3"], p["w2"])
    return out, aux


# ---------------------------------------------------------------------------
# Ambit-engine dispatch bookkeeping (bulk bitwise over packed masks)
# ---------------------------------------------------------------------------


def expert_bitmask_stats(idx: jnp.ndarray, n_experts: int, engine=None):
    """idx (T, k) expert assignments -> per-expert packed bitmasks + loads.

    Builds one packed bitvector per expert (bit t = expert serves token t)
    and popcounts them with the BulkBitwiseEngine - the paper's bitmap-
    index pattern (Section 8.1) applied to MoE bookkeeping. Also returns
    the overlap matrix (popcount of pairwise AND) used to measure routing
    correlation."""
    from ..core import BitVector, BulkBitwiseEngine
    eng = engine or BulkBitwiseEngine("jnp")
    t, k = idx.shape
    onehot = jnp.zeros((n_experts, t), jnp.bool_)
    onehot = onehot.at[idx.reshape(-1),
                       jnp.repeat(jnp.arange(t), k)].set(True)
    masks = BitVector.from_bits(onehot)
    loads = eng.popcount(masks)
    return masks, loads
