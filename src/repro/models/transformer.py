"""Family stacks: dense / MoE / VLM decoders, SSM (Mamba2), hybrid
(Zamba2), and encoder-decoder (Whisper). One scan-over-layers body per
family; heterogeneous layer patterns (gemma3's 5:1 local:global windows,
zamba2's shared block) are expressed as *scanned per-layer scalars* so a
single compiled body serves the whole stack.

Public entry points (used by model.py):
  model_defs(cfg)                          parameter tree
  forward(params, cfg, batch, ...)         train-mode logits (B,S,V)
  prefill(params, cfg, batch, ...)         (last-token logits, caches)
  decode_step(params, cfg, caches, batch)  (logits, new caches)
  cache_defs(cfg, batch, skv)              decode-cache ParamDef tree
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (COMPUTE_DTYPE, cast, embed, embed_defs, mlp, mlp_defs,
                     mrope, rmsnorm, rmsnorm_def, rope, sinusoidal_positions,
                     unembed)
from .param import ParamDef
from .sharding_ctx import hint

Tree = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------


def _decoder_layer_defs(cfg: ArchConfig, layers: int) -> Tree:
    d = cfg.d_model
    defs: Tree = {
        "ln1": rmsnorm_def(d, layers),
        "ln2": rmsnorm_def(d, layers),
        "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                               layers, cfg.qkv_bias),
    }
    if cfg.moe is not None:
        defs["moe"] = moe_mod.moe_defs(cfg, layers)
    else:
        defs["mlp"] = mlp_defs(d, cfg.d_ff, layers)
    return defs


def model_defs(cfg: ArchConfig) -> Tree:
    d = cfg.d_model
    defs: Tree = embed_defs(cfg.vocab, d, cfg.tie_embeddings)
    defs["final_norm"] = rmsnorm_def(d)

    if cfg.family == "ssm":
        defs["layers"] = dict(ssm_mod.ssm_defs(cfg, cfg.n_layers))
        defs["layers"]["ln"] = rmsnorm_def(d, cfg.n_layers)
    elif cfg.family == "hybrid":
        defs["layers"] = dict(ssm_mod.ssm_defs(cfg, cfg.n_layers))
        defs["layers"]["ln"] = rmsnorm_def(d, cfg.n_layers)
        defs["shared"] = {
            "ln1": rmsnorm_def(d), "ln2": rmsnorm_def(d),
            "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, 1, cfg.qkv_bias),
            "mlp": mlp_defs(d, cfg.d_ff, 1),
        }
    elif cfg.enc_dec:
        defs["enc_layers"] = {
            "ln1": rmsnorm_def(d, cfg.n_enc_layers),
            "ln2": rmsnorm_def(d, cfg.n_enc_layers),
            "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.n_enc_layers),
            "mlp": mlp_defs(d, cfg.d_ff, cfg.n_enc_layers),
        }
        defs["enc_norm"] = rmsnorm_def(d)
        dec = _decoder_layer_defs(cfg, cfg.n_layers)
        dec["ln3"] = rmsnorm_def(d, cfg.n_layers)
        dec["cross"] = attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim, cfg.n_layers)
        defs["layers"] = dec
    else:  # dense / moe / vlm decoders
        defs["layers"] = _decoder_layer_defs(cfg, cfg.n_layers)
    return defs


def cache_defs(cfg: ArchConfig, batch: int, skv: int) -> Tree:
    """Decode-cache tree (ShapeDtypeStructs via param.shape_tree)."""
    kv = ("layers", "batch", "kv_seq", "kv_heads", None)
    hd = cfg.head_dim

    def kv_pair(layers: int, length: int) -> Tree:
        return {
            "k": ParamDef((layers, batch, length, cfg.n_kv_heads, hd), kv,
                          COMPUTE_DTYPE, init="zeros"),
            "v": ParamDef((layers, batch, length, cfg.n_kv_heads, hd), kv,
                          COMPUTE_DTYPE, init="zeros"),
        }

    if cfg.family == "ssm":
        return {"ssm": ssm_mod.ssm_cache_defs(cfg, cfg.n_layers, batch)}
    if cfg.family == "hybrid":
        n_shared = cfg.n_layers // cfg.shared_attn_every
        return {
            "ssm": ssm_mod.ssm_cache_defs(cfg, cfg.n_layers, batch),
            "shared": kv_pair(n_shared, skv),
        }
    if cfg.enc_dec:
        return {
            "self": kv_pair(cfg.n_layers, skv),
            "cross": kv_pair(cfg.n_layers, cfg.n_frames),
        }
    return {"self": kv_pair(cfg.n_layers, skv)}


# ---------------------------------------------------------------------------
# Per-layer attention windows / rope thetas (gemma3 pattern)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig, skv: int) -> Optional[jnp.ndarray]:
    """(L,) per-layer window, or None when every layer is full-causal.
    Global layers get window = skv+1 (never binds)."""
    if not cfg.sliding_window or not cfg.global_every:
        return None
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(is_global, skv + 1, cfg.sliding_window)


def layer_thetas(cfg: ArchConfig) -> Optional[jnp.ndarray]:
    if cfg.global_rope_theta is None or not cfg.global_every:
        return None
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(is_global, cfg.global_rope_theta, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Shared building blocks
# ---------------------------------------------------------------------------


def _apply_rope(cfg: ArchConfig, q, k, positions, theta):
    if cfg.rope_kind == "none":
        return q, k
    if cfg.rope_kind == "mrope":
        return (mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    return rope(q, positions, theta), rope(k, positions, theta)


def _attn_layer(lp, cfg, x, positions, theta, window, block_kv):
    x = hint(x, "batch", "seq", None)
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.qkv_proj(lp["attn"], h)
    q, k = _apply_rope(cfg, q, k, positions, theta)
    o = attn.flash_attention(q, k, v, causal=True, window=window,
                             block_kv=block_kv)
    # Saved across the layer-remat boundary (SSPerf iteration E): backward
    # re-runs norms/projections but NOT the flash scan.
    o = checkpoint_name(o, "attn_out")
    return x + attn.out_proj(lp["attn"], o)


def _ffn_layer(lp, cfg, x, mesh):
    x = hint(x, "batch", "seq", None)
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_block(lp["moe"], h, cfg, mesh, cfg.act)
        return x + y, aux
    return x + mlp(lp["mlp"], h, cfg.act), jnp.float32(0.0)


def _embed_in(params, cfg, batch) -> jnp.ndarray:
    x = hint(embed(params, batch["tokens"]), "batch", "seq", None)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        b = x.shape[0]
        bidx = jnp.arange(b)[:, None]
        x = x.at[bidx, batch["vision_positions"]].set(
            batch["vision_embeds"].astype(x.dtype))
    return x


def _positions(cfg, batch, b, s):
    if cfg.rope_kind == "mrope":
        if "mrope_positions" in batch:
            return batch["mrope_positions"]
        base = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return jnp.broadcast_to(base[None], (3, b, s))
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


def _maybe_remat(fn, remat):
    # remat: False | True ("full") | "save_attn" (keep attention outputs
    # resident across the remat boundary - trades ~B*S*d bf16 per layer
    # of HBM residency for skipping the flash-scan recompute in backward).
    if not remat:
        return fn
    if remat == "save_attn":
        policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Train-mode forward (full-sequence logits)
# ---------------------------------------------------------------------------


def forward(params, cfg: ArchConfig, batch, mesh=None, remat: bool = False,
            block_kv: int = attn.DEFAULT_BLOCK_KV):
    """Returns (logits (B,S,V), aux_loss scalar)."""
    if cfg.enc_dec:
        return _whisper_forward(params, cfg, batch, remat, block_kv)
    if cfg.family == "ssm":
        return _ssm_forward(params, cfg, batch, remat)
    if cfg.family == "hybrid":
        return _hybrid_forward(params, cfg, batch, remat, block_kv)

    b, s = batch["tokens"].shape
    x = _embed_in(params, cfg, batch)
    positions = _positions(cfg, batch, b, s)
    windows = layer_windows(cfg, s)
    thetas = layer_thetas(cfg)

    def body(carry, lp_and_sc):
        x, aux = carry
        lp, window, theta = lp_and_sc
        x = _attn_layer(lp, cfg, x, positions, theta, window, block_kv)
        x, aux_l = _ffn_layer(lp, cfg, x, mesh)
        return (x, aux + aux_l), None

    L = cfg.n_layers
    win_xs = windows if windows is not None else jnp.zeros((L,))
    th_xs = thetas if thetas is not None else \
        jnp.full((L,), cfg.rope_theta)

    def scan_body(carry, xs):
        lp, w, th = xs
        window = w if windows is not None else None
        return body(carry, (lp, window, th))

    (x, aux), _ = jax.lax.scan(
        _maybe_remat(scan_body, remat), (x, jnp.float32(0.0)),
        (params["layers"], win_xs, th_xs))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x), 'batch', 'seq', 'vocab'), aux


def _ssm_forward(params, cfg, batch, remat):
    x = _embed_in(params, cfg, batch)

    def scan_body(x, lp):
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        return x + ssm_mod.ssm_block(lp_ssm, h, cfg), None

    x, _ = jax.lax.scan(_maybe_remat(scan_body, remat), x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x), 'batch', 'seq', 'vocab'), jnp.float32(0.0)


def _shared_block(sp, cfg, x, positions, block_kv, kv_cache=None, pos=None):
    """Zamba2 weight-tied shared attention+MLP block. Params have a leading
    length-1 'layers' dim (sliced here). Returns (x, (k,v)) in train/prefill
    or (x, new_kv) in decode when kv_cache is given."""
    sl = jax.tree.map(lambda a: a[0], sp)
    h = rmsnorm(sl["ln1"], x, cfg.norm_eps)
    q, k, v = attn.qkv_proj(sl["attn"], h)
    q, k = _apply_rope(cfg, q, k, positions, cfg.rope_theta)
    if kv_cache is None:
        o = attn.flash_attention(q, k, v, causal=True, block_kv=block_kv)
        new_kv = (k, v)
    else:
        kc, vc = kv_cache
        kc, vc = attn.update_cache(kc, vc, k, v, pos)
        o = attn.decode_attention(q, kc, vc, pos)
        new_kv = (kc, vc)
    x = x + attn.out_proj(sl["attn"], o)
    h2 = rmsnorm(sl["ln2"], x, cfg.norm_eps)
    x = x + mlp(sl["mlp"], h2, cfg.act)
    return x, new_kv


def _hybrid_forward(params, cfg, batch, remat, block_kv):
    b, s = batch["tokens"].shape
    x = _embed_in(params, cfg, batch)
    positions = _positions(cfg, batch, b, s)
    per = cfg.shared_attn_every
    groups = cfg.n_layers // per

    gl = jax.tree.map(
        lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

    def inner(x, lp):
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        return x + ssm_mod.ssm_block(lp_ssm, h, cfg), None

    for g in range(groups):
        lp_g = jax.tree.map(lambda a: a[g], gl)
        x, _ = jax.lax.scan(_maybe_remat(inner, remat), x, lp_g)
        x, _ = _shared_block(params["shared"], cfg, x, positions, block_kv)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x), 'batch', 'seq', 'vocab'), jnp.float32(0.0)


def _whisper_forward(params, cfg, batch, remat, block_kv):
    frames = batch["frames"].astype(COMPUTE_DTYPE)  # (B,F,d) stub frontend
    b, f, _ = frames.shape
    xe = frames + sinusoidal_positions(f, cfg.d_model)[None].astype(
        frames.dtype)

    def enc_body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        o = attn.flash_attention(q, k, v, causal=False, block_kv=block_kv)
        x = x + attn.out_proj(lp["attn"], o)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2, cfg.act), None

    xe, _ = jax.lax.scan(_maybe_remat(enc_body, remat), xe,
                         params["enc_layers"])
    enc_out = rmsnorm(params["enc_norm"], xe, cfg.norm_eps)

    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params, tokens) + sinusoidal_positions(
        s, cfg.d_model)[None].astype(COMPUTE_DTYPE)

    def dec_body(carry, lp):
        x = carry
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        o = attn.flash_attention(q, k, v, causal=True, block_kv=block_kv)
        x = x + attn.out_proj(lp["attn"], o)
        hc = rmsnorm(lp["ln3"], x, cfg.norm_eps)
        qc, kc, vc = _cross_qkv(lp["cross"], hc, enc_out)
        oc = attn.flash_attention(qc, kc, vc, causal=False,
                                  block_kv=block_kv)
        x = x + attn.out_proj(lp["cross"], oc)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2, cfg.act), None

    x, _ = jax.lax.scan(_maybe_remat(dec_body, remat), x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x), 'batch', 'seq', 'vocab'), jnp.float32(0.0)


def _cross_qkv(p, x_dec, enc_out):
    q = jnp.einsum("bsd,dhe->bshe", x_dec, cast(p["wq"], x_dec.dtype))
    k = jnp.einsum("bsd,dhe->bshe", enc_out, cast(p["wk"], enc_out.dtype))
    v = jnp.einsum("bsd,dhe->bshe", enc_out, cast(p["wv"], enc_out.dtype))
    if "bq" in p:
        q = q + cast(p["bq"], x_dec.dtype)
        k = k + cast(p["bk"], enc_out.dtype)
        v = v + cast(p["bv"], enc_out.dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# Prefill: forward pass that also emits decode caches
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, batch, skv: Optional[int] = None,
            mesh=None, block_kv: int = attn.DEFAULT_BLOCK_KV):
    """Returns (last-token logits (B,V), caches sized for skv)."""
    if cfg.enc_dec:
        return _whisper_prefill(params, cfg, batch, skv, block_kv)
    if cfg.family == "ssm":
        return _ssm_prefill(params, cfg, batch)
    if cfg.family == "hybrid":
        return _hybrid_prefill(params, cfg, batch, skv, block_kv)

    b, s = batch["tokens"].shape
    skv = skv or s
    x = _embed_in(params, cfg, batch)
    positions = _positions(cfg, batch, b, s)
    windows = layer_windows(cfg, skv)
    thetas = layer_thetas(cfg)
    L = cfg.n_layers
    win_xs = windows if windows is not None else jnp.zeros((L,))
    th_xs = thetas if thetas is not None else jnp.full((L,), cfg.rope_theta)

    def scan_body(carry, xs):
        x, aux = carry
        lp, w, th = xs
        window = w if windows is not None else None
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        q, k = _apply_rope(cfg, q, k, positions, th)
        o = attn.flash_attention(q, k, v, causal=True, window=window,
                                 block_kv=block_kv)
        x = x + attn.out_proj(lp["attn"], o)
        x, aux_l = _ffn_layer(lp, cfg, x, mesh)
        kc = _pad_cache(k, skv)
        vc = _pad_cache(v, skv)
        return (x, aux + aux_l), {"k": kc, "v": vc}

    (x, _aux), caches = jax.lax.scan(
        scan_body, (x, jnp.float32(0.0)), (params["layers"], win_xs, th_xs))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = hint(unembed(params, x[:, -1]), 'batch', 'vocab')
    return logits, {"self": caches}


def _pad_cache(k: jnp.ndarray, skv: int) -> jnp.ndarray:
    s = k.shape[1]
    if s == skv:
        return k.astype(COMPUTE_DTYPE)
    return jnp.pad(k, ((0, 0), (0, skv - s), (0, 0), (0, 0))).astype(
        COMPUTE_DTYPE)


def _ssm_prefill(params, cfg, batch):
    x = _embed_in(params, cfg, batch)

    def scan_body(x, lp):
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        y, cache = ssm_mod.ssm_block(lp_ssm, h, cfg, return_cache=True)
        return x + y, cache

    x, caches = jax.lax.scan(scan_body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {"ssm": caches}


def _hybrid_prefill(params, cfg, batch, skv, block_kv):
    b, s = batch["tokens"].shape
    skv = skv or s
    x = _embed_in(params, cfg, batch)
    positions = _positions(cfg, batch, b, s)
    per = cfg.shared_attn_every
    groups = cfg.n_layers // per
    gl = jax.tree.map(
        lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])

    def inner(x, lp):
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        y, cache = ssm_mod.ssm_block(lp_ssm, h, cfg, return_cache=True)
        return x + y, cache

    ssm_caches, shared_k, shared_v = [], [], []
    for g in range(groups):
        lp_g = jax.tree.map(lambda a: a[g], gl)
        x, cache_g = jax.lax.scan(inner, x, lp_g)
        ssm_caches.append(cache_g)
        x, (k, v) = _shared_block(params["shared"], cfg, x, positions,
                                  block_kv)
        shared_k.append(_pad_cache(k, skv))
        shared_v.append(_pad_cache(v, skv))
    ssm_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *ssm_caches)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {
        "ssm": ssm_cache,
        "shared": {"k": jnp.stack(shared_k), "v": jnp.stack(shared_v)},
    }


def _whisper_prefill(params, cfg, batch, skv, block_kv):
    frames = batch["frames"].astype(COMPUTE_DTYPE)
    b, f, _ = frames.shape
    xe = frames + sinusoidal_positions(f, cfg.d_model)[None].astype(
        frames.dtype)

    def enc_body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        o = attn.flash_attention(q, k, v, causal=False, block_kv=block_kv)
        x = x + attn.out_proj(lp["attn"], o)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2, cfg.act), None

    xe, _ = jax.lax.scan(enc_body, xe, params["enc_layers"])
    enc_out = rmsnorm(params["enc_norm"], xe, cfg.norm_eps)

    tokens = batch["tokens"]
    b, s = tokens.shape
    skv = skv or s
    x = embed(params, tokens) + sinusoidal_positions(
        s, cfg.d_model)[None].astype(COMPUTE_DTYPE)

    def dec_body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        o = attn.flash_attention(q, k, v, causal=True, block_kv=block_kv)
        x = x + attn.out_proj(lp["attn"], o)
        hc = rmsnorm(lp["ln3"], x, cfg.norm_eps)
        qc, kc, vc = _cross_qkv(lp["cross"], hc, enc_out)
        oc = attn.flash_attention(qc, kc, vc, causal=False,
                                  block_kv=block_kv)
        x = x + attn.out_proj(lp["cross"], oc)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h2, cfg.act)
        return x, {"self_k": _pad_cache(k, skv), "self_v": _pad_cache(v, skv),
                   "cross_k": kc.astype(COMPUTE_DTYPE),
                   "cross_v": vc.astype(COMPUTE_DTYPE)}

    x, ys = jax.lax.scan(dec_body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = hint(unembed(params, x[:, -1]), 'batch', 'vocab')
    caches = {"self": {"k": ys["self_k"], "v": ys["self_v"]},
              "cross": {"k": ys["cross_k"], "v": ys["cross_v"]}}
    return logits, caches


# ---------------------------------------------------------------------------
# Decode: one token against seq_len caches
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, caches, batch, mesh=None):
    """batch: tokens (B,1), pos (B,). Returns (logits (B,V), new caches)."""
    if cfg.enc_dec:
        return _whisper_decode(params, cfg, caches, batch)
    if cfg.family == "ssm":
        return _ssm_decode(params, cfg, caches, batch)
    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, caches, batch)

    tokens, pos = batch["tokens"], batch["pos"]
    b = tokens.shape[0]
    x = embed(params, tokens)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    skv = caches["self"]["k"].shape[2]
    positions = pos[:, None]
    if cfg.rope_kind == "mrope":
        positions = jnp.broadcast_to(pos[None, :, None], (3, b, 1))
    windows = layer_windows(cfg, skv)
    thetas = layer_thetas(cfg)
    L = cfg.n_layers
    win_xs = windows if windows is not None else jnp.zeros((L,))
    th_xs = thetas if thetas is not None else jnp.full((L,), cfg.rope_theta)

    def scan_body(carry, xs):
        x, aux = carry
        lp, kc, vc, w, th = xs
        kc = hint(kc, "batch", "kv_seq", "kv_heads", None)
        vc = hint(vc, "batch", "kv_seq", "kv_heads", None)
        window = w if windows is not None else None
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        q, k = _apply_rope(cfg, q, k, positions, th)
        kc, vc = attn.update_cache(kc, vc, k, v, pos)
        o = attn.decode_attention(q, kc, vc, pos, window=window)
        x = x + attn.out_proj(lp["attn"], o)
        x, aux_l = _ffn_layer(lp, cfg, x, mesh)
        return (x, aux + aux_l), {"k": kc, "v": vc}

    (x, _), new_kv = jax.lax.scan(
        scan_body, (x, jnp.float32(0.0)),
        (params["layers"], caches["self"]["k"], caches["self"]["v"],
         win_xs, th_xs))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {"self": new_kv}


def _ssm_decode(params, cfg, caches, batch):
    tokens = batch["tokens"]
    x = embed(params, tokens)

    def scan_body(x, xs):
        lp, cache = xs
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        y, new_cache = ssm_mod.ssm_block(lp_ssm, h, cfg, cache=cache)
        return x + y, new_cache

    x, new_caches = jax.lax.scan(scan_body, x,
                                 (params["layers"], caches["ssm"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {"ssm": new_caches}


def _hybrid_decode(params, cfg, caches, batch):
    tokens, pos = batch["tokens"], batch["pos"]
    x = embed(params, tokens)
    positions = pos[:, None]
    per = cfg.shared_attn_every
    groups = cfg.n_layers // per
    gl = jax.tree.map(
        lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])
    gc = jax.tree.map(
        lambda a: a.reshape((groups, per) + a.shape[1:]), caches["ssm"])

    def inner(x, xs):
        lp, cache = xs
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        lp_ssm = {k: v for k, v in lp.items() if k != "ln"}
        y, new_cache = ssm_mod.ssm_block(lp_ssm, h, cfg, cache=cache)
        return x + y, new_cache

    new_ssm, new_k, new_v = [], [], []
    for g in range(groups):
        lp_g = jax.tree.map(lambda a: a[g], gl)
        cache_g = jax.tree.map(lambda a: a[g], gc)
        x, nc = jax.lax.scan(inner, x, (lp_g, cache_g))
        new_ssm.append(nc)
        kv = (caches["shared"]["k"][g], caches["shared"]["v"][g])
        x, (kc, vc) = _shared_block(params["shared"], cfg, x, positions,
                                    attn.DEFAULT_BLOCK_KV, kv_cache=kv,
                                    pos=pos)
        new_k.append(kc)
        new_v.append(vc)
    ssm_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {
        "ssm": ssm_cache,
        "shared": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
    }


def _whisper_decode(params, cfg, caches, batch):
    tokens, pos = batch["tokens"], batch["pos"]
    b = tokens.shape[0]
    x = embed(params, tokens)
    # sinusoidal position of the current step, gathered per sequence
    skv = caches["self"]["k"].shape[2]
    pos_table = sinusoidal_positions(skv, cfg.d_model).astype(x.dtype)
    x = x + pos_table[pos][:, None]

    def scan_body(x, xs):
        lp, kc, vc, ck, cv = xs
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h)
        kc, vc = attn.update_cache(kc, vc, k, v, pos)
        o = attn.decode_attention(q, kc, vc, pos)
        x = x + attn.out_proj(lp["attn"], o)
        hc = rmsnorm(lp["ln3"], x, cfg.norm_eps)
        qc = jnp.einsum("bsd,dhe->bshe", hc, cast(lp["cross"]["wq"],
                                                  hc.dtype))
        f = ck.shape[1]
        oc = attn.decode_attention(
            qc, ck, cv, jnp.full((b,), f - 1, jnp.int32))
        x = x + attn.out_proj(lp["cross"], oc)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h2, cfg.act)
        return x, {"k": kc, "v": vc}

    x, new_kv = jax.lax.scan(
        scan_body, x,
        (params["layers"], caches["self"]["k"], caches["self"]["v"],
         caches["cross"]["k"], caches["cross"]["v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(unembed(params, x[:, -1]), 'batch', 'vocab'), {
        "self": new_kv, "cross": caches["cross"]}
