"""Timing and energy model for the Ambit device.

Timing constants are DDR3-1600 (Table 1). AAP latency follows Section 4.3:
80 ns naive (2*tRAS + tRP), 49 ns with the split row decoder, which applies
whenever exactly one of the two ACTIVATEs targets a B-group address (the
paper notes one AAP in `nand` - AAP(B12, B5) - cannot overlap; plain
data->data AAPs are RowClone-FPM at 80 ns).

Energy follows Section 7: activation energy grows 22% per additional raised
wordline. The base activation energy E_ACT is calibrated so the per-op
energies reproduce Table 4 (nJ/KB) to within ~5%:

    op        paper   model
    not       1.6     1.53
    and/or    3.2     3.24
    nand/nor  4.0     4.01
    xor       5.5     5.36

DDR3 baseline energy is modeled as channel-energy-per-byte-moved, derived
from Table 4's DDR3 row (93.7 nJ/KB for `not` = 2 KB moved per KB of result
=> ~45.9-46.9 nJ per KB moved).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .commands import AAP, AP, Macro, RowAddr, num_wordlines


@dataclasses.dataclass(frozen=True)
class TimingParams:
    # Table 1 (DDR3-1600), nanoseconds.
    tRAS: float = 35.0
    tRCD: float = 15.0
    tRP: float = 15.0
    tWR: float = 15.0
    # Section 4.3.
    aap_naive_ns: float = 80.0      # 2*tRAS + tRP, paper quotes 80 ns
    aap_overlap_extra_ns: float = 4.0  # back-to-back ACTs cost tRAS + 4 ns
    # Rank-level four-activate window (DDR3-1600 1KB-page tFAW).
    tFAW: float = 40.0
    # Refresh (DDR3 8Gb-class): one all-bank refresh every tREFI, each
    # stalling the bank for tRFC. Banks lose tRFC out of every tREFI of
    # wall clock, a steady-state ~4.7% throughput tax.
    tREFI: float = 7800.0
    tRFC: float = 350.0
    # Section 7 energy model.
    e_act_nj: float = 3.07           # calibrated base activation energy
    extra_wordline_factor: float = 0.22
    # DDR3 channel energy per KB moved (derived from Table 4, see module doc).
    ddr3_nj_per_kb_moved: float = 46.0

    @property
    def ap_ns(self) -> float:
        return self.tRAS + self.tRP  # 50 ns

    @property
    def refresh_overhead(self) -> float:
        """Steady-state stolen-time fraction: for every unit of useful busy
        time the bank also sits through tRFC/(tREFI - tRFC) of refresh."""
        return self.tRFC / (self.tREFI - self.tRFC)

    def refresh_stolen_ns(self, busy_ns: float) -> float:
        """Refresh time interleaved with ``busy_ns`` of useful bank work in
        steady state (amortized model; the event-accurate timeline lives in
        ``refresh_schedule``)."""
        return busy_ns * self.refresh_overhead

    @property
    def aap_opt_ns(self) -> float:
        # overlapped ACT-ACT (tRAS + 4 ns) + precharge
        return self.tRAS + self.aap_overlap_extra_ns + self.tRP  # 54 ns... see note

    def aap_ns(self, src: RowAddr, dst: RowAddr) -> float:
        """Latency of one AAP. The split decoder overlaps the two ACTIVATEs
        when exactly one address is in the B-group (Section 4.3)."""
        b_count = (src.group == "B") + (dst.group == "B")
        if b_count == 1:
            return 49.0  # paper's SPICE-derived figure for DDR3-1600
        return self.aap_naive_ns


DEFAULT_TIMING = TimingParams()


# -- refresh windows ----------------------------------------------------------
# The k-th refresh window occupies [k*tREFI, k*tREFI + tRFC), k >= 1 (the
# first refresh falls due one tREFI after the epoch starts). No command may
# issue inside a window; the two helpers below place work around them.


def _next_window(t_ns: float, params: TimingParams):
    """(start, end) of the first refresh window ending after ``t_ns``."""
    k = max(1, int(t_ns // params.tREFI))
    start = k * params.tREFI
    if t_ns >= start + params.tRFC:
        start += params.tREFI
    return start, start + params.tRFC


def defer_for_refresh(t_ns: float, dur_ns: float,
                      params: TimingParams = DEFAULT_TIMING) -> float:
    """Issue time for an *atomic* burst of ``dur_ns`` wanting to start at
    ``t_ns``: if the burst would start inside or straddle a refresh window
    it is deferred until the window closes. Bursts must fit between
    consecutive windows (every Ambit macro does: <= 85 ns vs 7450 ns)."""
    if dur_ns > params.tREFI - params.tRFC:
        raise ValueError(
            f"atomic burst of {dur_ns} ns cannot fit between refresh "
            f"windows ({params.tREFI - params.tRFC} ns apart)")
    while True:
        start, end = _next_window(t_ns, params)
        if t_ns + dur_ns <= start or t_ns >= end:
            return t_ns
        t_ns = end


def refresh_schedule(start_ns: float, work_ns: float,
                     params: TimingParams = DEFAULT_TIMING):
    """Lay ``work_ns`` of *pausable* work on the wall clock from
    ``start_ns``, pausing through every refresh window it crosses.
    Returns ``(work_start_ns, finish_ns)``; the stolen time is
    ``finish - work_start - work_ns``."""
    t = start_ns
    win_start, win_end = _next_window(t, params)
    if win_start <= t < win_end:
        t = win_end
    work_start = t
    remaining = work_ns
    while remaining > 0:
        win_start, win_end = _next_window(t, params)
        slice_ns = min(remaining, win_start - t)
        t += slice_ns
        remaining -= slice_ns
        if remaining > 0:
            t = win_end
    return work_start, t


@dataclasses.dataclass
class CommandStats:
    """Ledger accumulated while executing Ambit programs."""

    activates: int = 0
    wordlines: int = 0
    precharges: int = 0
    aap_count: int = 0
    ap_count: int = 0
    ns: float = 0.0
    energy_nj: float = 0.0

    def add_activate(self, addr: RowAddr, params: TimingParams,
                     rows: int = 1) -> None:
        n_wl = num_wordlines(addr)
        self.activates += rows
        self.wordlines += rows * n_wl
        self.energy_nj += rows * params.e_act_nj * (
            1.0 + params.extra_wordline_factor * (n_wl - 1))

    def add_macro(self, macro: Macro, params: TimingParams,
                  rows: int = 1) -> None:
        """Account one macro executed over a batch of ``rows`` subarray rows
        (batched execution: the costs of every lockstep instance are summed,
        exactly as the per-row loop summed them)."""
        if isinstance(macro, AAP):
            self.aap_count += rows
            self.ns += rows * params.aap_ns(macro.src, macro.dst)
            self.add_activate(macro.src, params, rows)
            self.add_activate(macro.dst, params, rows)
            self.precharges += rows
        elif isinstance(macro, AP):
            self.ap_count += rows
            self.ns += rows * params.ap_ns
            self.add_activate(macro.addr, params, rows)
            self.precharges += rows
        else:
            raise TypeError(macro)

    def merge(self, other: "CommandStats") -> None:
        self.activates += other.activates
        self.wordlines += other.wordlines
        self.precharges += other.precharges
        self.aap_count += other.aap_count
        self.ap_count += other.ap_count
        self.ns += other.ns
        self.energy_nj += other.energy_nj


def program_stats(prog: Sequence[Macro],
                  params: TimingParams = DEFAULT_TIMING) -> CommandStats:
    st = CommandStats()
    for m in prog:
        st.add_macro(m, params)
    return st


def op_energy_nj_per_kb(op: str, params: TimingParams = DEFAULT_TIMING,
                        row_bytes: int = 8192) -> float:
    """Modeled Ambit energy per KB of result for a Figure-20 op."""
    from .commands import D, OP_ARITY, OP_TEMPLATES  # local: avoid cycle

    tmpl = OP_TEMPLATES[op]
    args = [D(i) for i in range(OP_ARITY[op])]
    prog = tmpl(*args)
    st = program_stats(prog, params)
    return st.energy_nj / (row_bytes / 1024.0)


def ddr3_energy_nj_per_kb(op: str,
                          params: TimingParams = DEFAULT_TIMING) -> float:
    """Baseline: CPU reads sources over the channel and writes the result."""
    kb_moved = {"not": 2.0, "copy": 2.0, "zero": 1.0, "one": 1.0}.get(op, 3.0)
    return params.ddr3_nj_per_kb_moved * kb_moved


# Paper's Table 4 reference values (nJ/KB) for validation/benchmarks.
TABLE4_PAPER = {
    "ddr3": {"not": 93.7, "and": 137.9, "or": 137.9, "nand": 137.9,
             "nor": 137.9, "xor": 137.9, "xnor": 137.9},
    "ambit": {"not": 1.6, "and": 3.2, "or": 3.2, "nand": 4.0, "nor": 4.0,
              "xor": 5.5, "xnor": 5.5},
}

# Paper's Table 3: TRA failure rate vs process variation (for validation).
TABLE3_PAPER = {0.00: 0.0, 0.05: 0.0, 0.10: 0.0029, 0.15: 0.0601,
                0.20: 0.1636, 0.25: 0.2619}
