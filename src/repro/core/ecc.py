"""ECC for in-memory bitwise computation (paper Section 5.5).

Conventional SECDED is not homomorphic over bitwise ops: if Ambit computes
C = A and B directly in DRAM, ECC(C) != f(ECC(A), ECC(B)) for any bitwise
f, so the stored check bits go stale. The paper notes the ONLY known
homomorphic scheme is triple modular redundancy (TMR): ECC(A) = AA (store
the word multiple times); every bitwise op applied replica-wise commutes
with encoding, and decode is a bitwise majority vote - which Ambit itself
computes natively with one TRA.

This module implements TMR over BitVectors: encode (x3 storage), any
engine op applied replica-wise, majority-vote decode (via the engine's
MAJ, i.e. a TRA on the device model), and error detection/scrubbing.
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp

from .bitvector import BitVector
from .engine import BulkBitwiseEngine


class TMRCodec:
    """Triple-modular-redundancy codec over the bulk bitwise engine."""

    REPLICAS = 3

    def __init__(self, engine: BulkBitwiseEngine):
        self.engine = engine

    def encode(self, x: BitVector) -> List[BitVector]:
        # Each replica gets its OWN storage: aliasing one buffer three
        # times would let a single underlying flip corrupt all votes,
        # which defeats the entire point of modular redundancy.
        return [BitVector(jnp.array(x.data, copy=True), x.n_bits)
                for _ in range(self.REPLICAS)]

    def apply(self, op: str, a: List[BitVector], b: List[BitVector]
              ) -> List[BitVector]:
        """Replica-wise bitwise op: homomorphism means no re-encoding."""
        fn = getattr(self.engine, op)
        return [fn(ra, rb) for ra, rb in zip(a, b)]

    def apply1(self, op: str, a: List[BitVector]) -> List[BitVector]:
        fn = getattr(self.engine, op)
        return [fn(ra) for ra in a]

    def decode(self, replicas: List[BitVector]) -> BitVector:
        """Majority vote = one TRA on the Ambit device model."""
        return self.engine.maj(*replicas)

    def scrub(self, replicas: List[BitVector]
              ) -> Tuple[List[BitVector], int]:
        """Correct single-replica bit flips in place; returns (clean
        replicas, #corrected bits)."""
        voted = self.decode(replicas)
        corrected = 0
        for r in replicas:
            diff = self.engine.xor(r, voted)
            corrected += int(self.engine.popcount(diff))
        return self.encode(voted), corrected

    def storage_overhead(self) -> float:
        return float(self.REPLICAS)  # 3x, as the paper notes (costly)
