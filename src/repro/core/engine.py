"""BulkBitwiseEngine: the `bbop` execution model exposed to applications.

Three interchangeable backends compute identical results:

  * "jnp"       - jitted jax.numpy over packed uint32 (portable reference).
  * "pallas"    - fused Pallas TPU kernel per expression (interpret=True on
                  CPU); the TPU-native realization of AAP-chain fusion.
  * "ambit_sim" - the bit-accurate DRAM device model (core/simulator.py),
                  which also returns the paper's DRAM timing/energy ledger.

The engine is the system-integration layer of Section 5: the bbop ISA
(and/or/xor/... over row-aligned operands), the driver's co-location
contract (operands of one call share sharding), and the accounting needed
by the paper-table benchmarks.

ambit_sim execution model (batched + cached)
--------------------------------------------
An eval call maps every row of the packed operands to one D-group row of a
simulated subarray (the Section 5.2 co-location contract). Two levers make
this fast enough for paper-table workloads at realistic bitvector sizes:

  * **Compiled-program cache.** ``compile_expr`` output depends only on
    ``(expression, sorted variable names, optimize, geometry.data_rows,
    timing)`` - expressions are hash-consed (expr.py), so an LRU keyed on
    those fields compiles each expression shape exactly once per process.
    Inspect/reset with ``compile_cache_info()`` / ``compile_cache_clear()``.
  * **Batched device execution.** All operand rows are written into one
    ``AmbitSubarray(n_rows=N)`` and the AAP program runs **once** over the
    whole batch instead of once per row (seed behavior, still available as
    ``BulkBitwiseEngine(..., batch_rows=False)`` for differential testing
    and benchmarks). Stats are scaled per row-batch, so the reported DRAM
    ledger is identical to the per-row loop's.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import expr as E
from .bitvector import BitVector
from .compiler import CompiledProgram, compile_expr
from .geometry import DEFAULT_GEOMETRY, DRAMGeometry
from .simulator import AmbitSubarray
from .timing import DEFAULT_TIMING, CommandStats, TimingParams
from ..obs import NULL_TRACER, MetricsRegistry, Tracer


@dataclasses.dataclass
class OpStats:
    """Per-call accounting (DRAM model units when backend=ambit_sim).

    ``bytes_touched`` is host<->device traffic; ``channel_bytes`` /
    ``channel_ns`` are *inter-device* transfers on a multi-device
    cluster (pim.cluster) - measured from rows actually moved, never
    from an analytic formula. ``channel_ns`` is already included in
    ``ns`` (transfers serialize before the device programs run); the
    separate field exists so callers can see how much of the critical
    path the channel re-introduced.

    ``refresh_stolen_ns`` is DRAM refresh time interleaved with this
    call's bank-busy time (tRFC out of every tREFI, timing.py). It is
    deliberately NOT folded into ``ns`` - the base ledger stays the
    refresh-free device cost so results remain comparable across
    backends; refresh-aware wall clock is opt-in via
    ``AsyncScheduler.drain(refresh=True)``."""

    ns: float = 0.0
    energy_nj: float = 0.0
    aap_count: int = 0
    bytes_touched: int = 0
    channel_ns: float = 0.0
    channel_bytes: int = 0
    refresh_stolen_ns: float = 0.0

    def merge(self, other: "OpStats") -> "OpStats":
        """Accumulate another ledger into this one (all fields - callers
        used to sum ns/energy/aap by hand and silently drop
        bytes_touched)."""
        self.ns += other.ns
        self.energy_nj += other.energy_nj
        self.aap_count += other.aap_count
        self.bytes_touched += other.bytes_touched
        self.channel_ns += other.channel_ns
        self.channel_bytes += other.channel_bytes
        self.refresh_stolen_ns += other.refresh_stolen_ns
        return self

    def __iadd__(self, other: "OpStats") -> "OpStats":
        return self.merge(other)


@functools.lru_cache(maxsize=256)
def _compile_cached(expression: E.Expr, names: tuple, optimize: bool,
                    data_rows: int, timing: TimingParams) -> CompiledProgram:
    """Process-wide compiled-program cache.

    Valid because Expr nodes are interned (identity == structural equality),
    TimingParams is frozen, and CompiledProgram is immutable: the program
    depends only on the expression shape, the variable-name order (row
    assignment), the optimize flag and the D-group size."""
    var_rows = {nm: i for i, nm in enumerate(names)}
    return compile_expr(expression, var_rows, len(names), data_rows,
                        optimize, timing)


def compile_cache_info():
    """functools cache statistics for the ambit_sim compile cache."""
    return _compile_cached.cache_info()


def compile_cache_clear() -> None:
    _compile_cached.cache_clear()


@functools.lru_cache(maxsize=256)
def _device_compiled(expression: E.Expr, names: tuple, backend: str,
                     n_bits: int, donate_idx: Optional[int]):
    """Jitted-callable LRU for the accelerator-resident path - the
    jnp/pallas twin of ``_compile_cached``. One callable per
    ``(expression, names, backend, n_bits, donation slot)``; operand
    shapes specialize inside ``jax.jit`` exactly as ``data_rows`` does in
    the AAP cache. ``donate_idx`` donates that operand's buffer to XLA
    (``out=``-style in-place rebinds: the result reuses the rebound
    handle's storage instead of allocating). Donation is requested only
    off-CPU - the CPU runtime cannot honor it and would warn."""
    def compute(*arrays):
        env = dict(zip(names, arrays))
        if backend == "pallas":
            from ..kernels import ops as kops
            out = kops._eval_padded(expression, names, env)
        else:
            out = E.eval_expr(expression, env)
        from .bitvector import _mask_tail
        return _mask_tail(out, n_bits)

    donate = () if donate_idx is None or jax.default_backend() == "cpu" \
        else (donate_idx,)
    return jax.jit(compute, donate_argnums=donate)


@functools.lru_cache(maxsize=256)
def _device_compiled_stacked(expression: E.Expr, names: tuple, backend: str,
                             n_bits: int):
    """Epoch-stacked variant of ``_device_compiled``: operands are
    ``(queries, rows, words)`` stacks and the whole epoch evaluates in
    ONE dispatch (one stacked-grid pallas_call on the pallas backend)."""
    def compute(*arrays):
        env = dict(zip(names, arrays))
        if backend == "pallas":
            from ..kernels import ops as kops
            out = kops._eval_padded_stacked(expression, names, env)
        else:
            out = E.eval_expr(expression, env)
        from .bitvector import _mask_tail
        return _mask_tail(out, n_bits)

    return jax.jit(compute)


def device_compile_cache_info():
    """Cache statistics for the accelerator-resident jit LRUs."""
    return (_device_compiled.cache_info(),
            _device_compiled_stacked.cache_info())


def device_compile_cache_clear() -> None:
    _device_compiled.cache_clear()
    _device_compiled_stacked.cache_clear()


def binop_expr(op: str) -> E.Expr:
    """The bbop ISA's two-operand expressions over vars "a"/"b" (single
    source of truth for the engine and the pim runtime)."""
    x, y = E.Expr.var("a"), E.Expr.var("b")
    return {"and": x & y, "or": x | y, "xor": x ^ y,
            "nand": ~(x & y), "nor": ~(x | y), "xnor": ~(x ^ y)}[op]


class BulkBitwiseEngine:
    def __init__(self, backend: str = "jnp",
                 geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 optimize: bool = True, batch_rows: bool = True,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if backend not in ("jnp", "pallas", "ambit_sim"):
            raise ValueError(backend)
        self.backend = backend
        self.geometry = geometry
        self.timing = timing
        self.optimize = optimize
        # batch_rows=False forces the legacy one-subarray-per-row loop
        # (differential-testing / benchmark baseline; ambit_sim only).
        self.batch_rows = batch_rows
        self.last_stats: Optional[OpStats] = None
        # Observability: metrics are always on (cheap counter adds);
        # span tracing is opt-in via a live Tracer (zero overhead off).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # -- expression evaluation ------------------------------------------------

    def eval(self, expression: E.Expr,
             env: Dict[str, BitVector]) -> BitVector:
        some = next(iter(env.values()))
        n_bits = some.n_bits
        for v in env.values():
            if v.n_bits != n_bits or v.data.shape != some.data.shape:
                raise ValueError("bbop operands must be row-aligned and "
                                 "equal-sized (Section 5.3)")
        if self.backend == "ambit_sim":
            return self._eval_sim(expression, env, n_bits)
        arrays = {k: v.data for k, v in env.items()}
        if self.backend == "pallas":
            from ..kernels import ops as kops
            out = kops.bitwise_eval(expression, arrays)
        else:
            out = _jnp_eval(expression, arrays)
        self.last_stats = OpStats(
            bytes_touched=sum(v.nbytes for v in env.values())
            + (out.nbytes if hasattr(out, "nbytes") else 0))
        self.metrics.counter("engine_evals").inc(1, backend=self.backend)
        self.metrics.counter("engine_bytes_touched").inc(
            self.last_stats.bytes_touched, backend=self.backend)
        return BitVector(out, n_bits)

    # -- bbop-style binary ops -------------------------------------------------

    def _binop(self, op: str, a: BitVector, b: BitVector) -> BitVector:
        return self.eval(binop_expr(op), {"a": a, "b": b})

    def and_(self, a, b):
        return self._binop("and", a, b)

    def or_(self, a, b):
        return self._binop("or", a, b)

    def xor(self, a, b):
        return self._binop("xor", a, b)

    def nand(self, a, b):
        return self._binop("nand", a, b)

    def nor(self, a, b):
        return self._binop("nor", a, b)

    def xnor(self, a, b):
        return self._binop("xnor", a, b)

    def not_(self, a: BitVector) -> BitVector:
        return self.eval(~E.Expr.var("a"), {"a": a})

    def maj(self, a: BitVector, b: BitVector, c: BitVector) -> BitVector:
        return self.eval(E.maj(E.Expr.var("a"), E.Expr.var("b"),
                               E.Expr.var("c")), {"a": a, "b": b, "c": c})

    def masked_set(self, x: BitVector, mask: BitVector) -> BitVector:
        """Masked initialization (Section 8.4.2): x | mask."""
        return self.or_(x, mask)

    def masked_clear(self, x: BitVector, mask: BitVector) -> BitVector:
        return self.eval(E.Expr.var("x") & ~E.Expr.var("m"),
                         {"x": x, "m": mask})

    def popcount(self, a: BitVector) -> jnp.ndarray:
        """Bitcount (Section 9.1 future-op; we provide it natively)."""
        if self.backend == "pallas":
            from ..kernels import ops as kops
            out = kops.popcount(a.data)
        else:
            out = a.popcount()
        # Fresh ledger on every public entry point: callers accumulate
        # ``last_stats`` after each call, and a stale ledger here would
        # silently re-merge the previous op's DRAM cost.
        self.last_stats = OpStats(
            bytes_touched=a.nbytes
            + (out.nbytes if hasattr(out, "nbytes") else 0))
        return out

    def shift(self, a: BitVector, amount: int) -> BitVector:
        """Logical bit shift by `amount` positions (Section 9.1 future-op:
        "most arithmetic operations require some kind of bitwise shift").
        Positive = toward higher bit indices; zeros shift in. In the DRAM
        model a row-granular shift is a RowClone to an offset mapping; at
        word granularity it is two shifts + OR per word - implemented here
        over packed words for all backends (bit i of the result = bit
        i-amount of the input)."""
        from .bitvector import _mask_tail
        n = a.n_bits
        # Fresh ledger per entry point (host-side op: two buffers cross).
        self.last_stats = OpStats(bytes_touched=2 * a.nbytes)
        if amount == 0:
            return BitVector(a.data, n)
        data = a.data
        w = 32
        word_off, bit_off = divmod(abs(amount), w)
        if amount > 0:
            x = jnp.roll(data, word_off, axis=-1)
            idx = jnp.arange(data.shape[-1])
            x = jnp.where(idx < word_off, jnp.uint32(0), x)
            if bit_off:
                lo = x << jnp.uint32(bit_off)
                carry = jnp.roll(x, 1, axis=-1) >> jnp.uint32(w - bit_off)
                carry = jnp.where(idx == 0, jnp.uint32(0), carry)
                x = lo | carry
        else:
            x = jnp.roll(data, -word_off, axis=-1)
            idx = jnp.arange(data.shape[-1])
            nw = data.shape[-1]
            x = jnp.where(idx >= nw - word_off, jnp.uint32(0), x)
            if bit_off:
                hi = x >> jnp.uint32(bit_off)
                carry = jnp.roll(x, -1, axis=-1) << jnp.uint32(w - bit_off)
                carry = jnp.where(idx == nw - 1, jnp.uint32(0), carry)
                x = hi | carry
        return BitVector(_mask_tail(x, n), n)

    # -- ambit_sim backend ------------------------------------------------------

    def _eval_sim(self, expression: E.Expr, env: Dict[str, BitVector],
                  n_bits: int) -> BitVector:
        """Execute the compiled AAP program on the device model.

        Each 'row' of the operand bitvectors maps to one D-group row of a
        simulated subarray (the Section 5.2 driver's co-location contract:
        corresponding rows of all operands share a subarray). The program
        is fetched from the process-wide compile cache and - unless
        ``batch_rows=False`` - executed once over a batch-``n_rows``
        subarray: one write / one run / one read."""
        names = sorted(env.keys())
        var_rows = {nm: i for i, nm in enumerate(names)}
        dst_row = len(names)
        compiled = _compile_cached(expression, tuple(names), self.optimize,
                                   self.geometry.data_rows, self.timing)
        # Pack to uint64 words for the simulator.
        packed = {nm: _to_u64(np.asarray(env[nm].data)) for nm in names}
        some = packed[names[0]]
        lead = some.shape[:-1]
        flat = {nm: a.reshape(-1, a.shape[-1]) for nm, a in packed.items()}
        n_rows, words = next(iter(flat.values())).shape

        if n_rows == 0:  # zero-row operands: nothing to execute
            out_rows = np.empty((0, words), np.uint64)
            total = CommandStats()
        elif self.batch_rows:
            sub = AmbitSubarray(self.geometry, self.timing, words=words,
                                n_rows=n_rows)
            for nm in names:
                sub.write_row(var_rows[nm], flat[nm])
            sub.run(compiled.program)
            out_rows = sub.read_row(dst_row).reshape(n_rows, words)
            total = sub.stats
        else:  # legacy per-row loop (seed behavior; differential baseline)
            out_rows = np.empty((n_rows, words), np.uint64)
            total = CommandStats()
            sub = AmbitSubarray(self.geometry, self.timing, words=words)
            for r in range(n_rows):
                for nm in names:
                    sub.write_row(var_rows[nm], flat[nm][r])
                sub.stats = CommandStats()
                sub.run(compiled.program)
                out_rows[r] = sub.read_row(dst_row)
                total.merge(sub.stats)

        out32 = _to_u32(out_rows.reshape(lead + (words,)))
        # bytes_touched is host<->device traffic: every operand is written
        # to the subarray and the result is read back (same accounting as
        # the jnp path's inputs + output).
        self.last_stats = OpStats(ns=total.ns, energy_nj=total.energy_nj,
                                  aap_count=total.aap_count,
                                  bytes_touched=out32.nbytes +
                                  sum(v.nbytes for v in env.values()))
        self.metrics.counter("engine_evals").inc(1, backend=self.backend)
        self.metrics.counter("engine_bytes_touched").inc(
            self.last_stats.bytes_touched, backend=self.backend)
        self.metrics.counter("engine_aap_macros").inc(total.aap_count)
        self.metrics.counter("engine_ns").inc(total.ns)
        if self.tracer.enabled:
            # AAP macro batch: one span per compiled-program execution on
            # the engine's busy-time track.
            self.tracer.tick(("engine", "ambit_sim"), "aap_batch", "engine",
                             total.ns, args={"aaps": total.aap_count,
                                             "rows": n_rows,
                                             "vars": len(names)})
        bv = BitVector(jnp.asarray(out32), n_bits)
        # Padding rows beyond n_bits may be garbage from scratch state: mask.
        from .bitvector import _mask_tail
        return BitVector(_mask_tail(bv.data, n_bits), n_bits)


def _to_u64(a32: np.ndarray) -> np.ndarray:
    a32 = np.ascontiguousarray(a32, dtype=np.uint32)
    if a32.shape[-1] % 2:
        a32 = np.concatenate(
            [a32, np.zeros(a32.shape[:-1] + (1,), np.uint32)], -1)
    return a32.view(np.uint64)


def _to_u32(a64: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(a64).view(np.uint32)


@functools.partial(jax.jit, static_argnums=0)
def _jnp_eval(expression: E.Expr, arrays: Dict[str, jnp.ndarray]):
    return E.eval_expr(expression, arrays)
