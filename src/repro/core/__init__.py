"""Ambit core: the paper's bulk bitwise execution engine.

Public API:
  BitVector, BulkBitwiseEngine  - the bbop execution model (Section 5)
  Expr / maj / compile_expr     - bitwise programs -> AAP command streams
  AmbitSubarray / AmbitDevice   - bit-accurate DRAM device model
"""

from .bitvector import BitVector, pack_bits, unpack_bits
from .commands import AAP, AP, B, C, D, OP_TEMPLATES, RowAddr
from .compiler import CompiledProgram, compile_expr
from .engine import (BulkBitwiseEngine, OpStats, compile_cache_clear,
                     compile_cache_info)
from .expr import Expr, ONE, ZERO, eval_expr, maj
from .geometry import DEFAULT_GEOMETRY, DRAMGeometry
from .simulator import AmbitDevice, AmbitError, AmbitSubarray
from .timing import (DEFAULT_TIMING, CommandStats, TABLE3_PAPER, TABLE4_PAPER,
                     TimingParams, ddr3_energy_nj_per_kb, op_energy_nj_per_kb,
                     program_stats)

__all__ = [
    "AAP", "AP", "AmbitDevice", "AmbitError", "AmbitSubarray", "B",
    "BitVector", "BulkBitwiseEngine", "C", "CommandStats", "CompiledProgram",
    "D", "DEFAULT_GEOMETRY", "DEFAULT_TIMING", "DRAMGeometry", "Expr", "ONE",
    "OP_TEMPLATES", "OpStats", "RowAddr", "TABLE3_PAPER", "TABLE4_PAPER",
    "TimingParams", "ZERO", "compile_cache_clear", "compile_cache_info",
    "compile_expr", "ddr3_energy_nj_per_kb", "eval_expr", "maj",
    "op_energy_nj_per_kb", "pack_bits", "program_stats", "unpack_bits",
]
