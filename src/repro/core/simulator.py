"""Bit-accurate functional simulator of an Ambit DRAM device.

Executes raw ACTIVATE/PRECHARGE command streams (and the AAP/AP macros of
Section 4.2) against a modeled subarray with designated rows T0..T3, two
dual-contact-cell rows (DCC0/DCC1), control rows C0/C1, and D-group data
rows. Semantics follow Sections 2-4:

* ACTIVATE from the precharged state connects the addressed wordline(s) to
  the bitlines; charge sharing + sense amplification resolve the row buffer:
    - one d-wordline cell: row buffer = cell (and the cell is restored);
    - one n-wordline (DCC): the capacitor drives bitline-bar, so the row
      buffer resolves to the negated capacitor value (Section 3.2);
    - three cells (TRA): row buffer = bitwise MAJORITY, and *all three*
      cells are overwritten with the result (Section 3.1, issue 3);
    - two cells: only defined when both cells agree (Ambit only issues
      2-wordline addresses as the second ACTIVATE of an AAP); a 2-cell
      activation from precharged state with disagreeing cells is flagged.
* ACTIVATE while the bank is already activated (second ACTIVATE of an AAP)
  overwrites every newly-connected cell with the row-buffer value - through
  the bitline for d-wordlines, negated through bitline-bar for n-wordlines.
* PRECHARGE lowers all wordlines and disables the sense amplifiers.

Batched execution model
-----------------------
The paper's headline claim is *throughput*: every subarray executing an AAP
program operates on its full row buffer in parallel, and many subarrays and
banks run the same program simultaneously (Section 7). `AmbitSubarray`
models that with a leading batch dimension: all row state is held as
``(n_rows, words)`` uint64 arrays, and one command stream executes **once**
over all batch rows. Batch row ``i`` behaves exactly like an independent
subarray executing the same program - TRA majority, DCC negation, 2-cell
agreement checks and restore-on-activate are all elementwise, so batching
is a pure vectorization with no behavioral change (tests/test_batched_sim.py
proves bit- and stats-exactness differentially against the per-row path).
The timing/energy ledger scales per-macro costs by ``n_rows``: the batch
stands in for ``n_rows`` subarrays each spending the energy and (serially
accounted, as the per-row loop did) the latency.

D-group rows are materialized lazily: a row's backing array is only
allocated when first read or written, seeded deterministically per
``(seed, row_index)`` so boot content is independent of access order. This
keeps a 1006-row geometry with a 1024-deep batch from allocating ~0.5 GB of
untouched "undefined" cells.

Rows are stored bit-packed as numpy uint64; all row-wide ops are vectorized.
A timing/energy ledger (timing.py) accumulates per-command costs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import commands as cmd
from .commands import (AAP, AP, Activate, Command, Macro, Precharge, RowAddr,
                       dcc_capacitor, is_n_wordline, wordlines_for)
from .geometry import DEFAULT_GEOMETRY, DRAMGeometry
from .timing import DEFAULT_TIMING, CommandStats, TimingParams


class AmbitError(RuntimeError):
    """Raised when a command stream has undefined analog behaviour."""


def _rand_rows(rng: np.random.Generator, n: int, words: int) -> np.ndarray:
    return rng.integers(0, np.iinfo(np.uint64).max, size=(n, words),
                        dtype=np.uint64)


@dataclasses.dataclass
class _SenseAmpState:
    active: bool = False
    rowbuf: Optional[np.ndarray] = None  # (n_rows, words) uint64 when active
    open_wordlines: List[str] = dataclasses.field(default_factory=list)


class AmbitSubarray:
    """One subarray: D-rows + designated/control/DCC rows + sense amps.

    ``n_rows`` is the batch dimension (number of independent subarray
    instances executing the same command stream in lockstep). All cell
    state is ``(n_rows, words)`` uint64. The scalar API (``write_row`` /
    ``read_row`` with 1-D ``(words,)`` data) remains valid when
    ``n_rows == 1``; batched callers pass/receive ``(n_rows, words)``.
    """

    def __init__(self, geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 words: Optional[int] = None, seed: int = 0,
                 n_rows: int = 1):
        if n_rows < 1:
            raise ValueError("n_rows must be >= 1")
        self.geom = geometry
        self.timing = timing
        self.words = geometry.row_words if words is None else words
        self.n_rows = n_rows
        self._seed = seed
        # Data rows power up with undefined content (modeled as random);
        # materialized lazily per row index so huge geometries stay cheap.
        self._d_rows: Dict[int, np.ndarray] = {}
        rng = np.random.default_rng(seed)
        # Designated rows T0..T3 and DCC capacitors also undefined at boot.
        self.t_rows: Dict[str, np.ndarray] = {
            t: _rand_rows(rng, n_rows, self.words) for t in cmd.T_WORDLINES}
        self.dcc: Dict[str, np.ndarray] = {
            d: _rand_rows(rng, n_rows, self.words)
            for d in cmd.DCC_D_WORDLINES}
        # Control rows are initialized at design time (Section 3.1.4).
        self.c_rows = [np.zeros((n_rows, self.words), np.uint64),
                       np.full((n_rows, self.words),
                               np.iinfo(np.uint64).max, np.uint64)]
        self.amp = _SenseAmpState()
        self.stats = CommandStats()

    # -- D-group storage (lazy, deterministic boot content) ------------------

    def _check_d_index(self, d_index: int) -> None:
        if not 0 <= d_index < self.geom.data_rows:
            raise IndexError(f"D{d_index} outside the D-group "
                             f"(0..{self.geom.data_rows - 1})")

    def _d_row(self, d_index: int) -> np.ndarray:
        self._check_d_index(d_index)
        row = self._d_rows.get(d_index)
        if row is None:
            rng = np.random.default_rng((self._seed, d_index))
            row = _rand_rows(rng, self.n_rows, self.words)
            self._d_rows[d_index] = row
        return row

    # -- software-visible row access (models READ/WRITE via the controller) --

    def _coerce_row(self, data: np.ndarray) -> np.ndarray:
        """Validate/broadcast row data to the (n_rows, words) batch shape."""
        data = np.asarray(data, dtype=np.uint64)
        if data.shape == (self.words,):
            return np.broadcast_to(data, (self.n_rows, self.words)).copy() \
                if self.n_rows > 1 else data.reshape(1, self.words).copy()
        if data.shape == (self.n_rows, self.words):
            return data.copy()
        raise ValueError(
            f"row data must be ({self.words},) or "
            f"({self.n_rows}, {self.words}) uint64, got {data.shape}")

    def write_row(self, d_index: int, data: np.ndarray) -> None:
        if self.amp.active:
            raise AmbitError("WRITE while bank activated is not modeled")
        self._check_d_index(d_index)  # never materialize just to overwrite
        self._d_rows[d_index] = self._coerce_row(data)

    def read_row(self, d_index: int) -> np.ndarray:
        """Row content: (words,) when n_rows == 1, else (n_rows, words)."""
        row = self._d_row(d_index)
        return row[0].copy() if self.n_rows == 1 else row.copy()

    # -- cell plumbing ------------------------------------------------------

    def _cell_value(self, wl: str) -> np.ndarray:
        if wl.startswith("T"):
            return self.t_rows[wl]
        if wl.startswith("DCC"):
            return self.dcc[dcc_capacitor(wl)]
        if wl.startswith("C"):
            return self.c_rows[int(wl[1:])]
        if wl.startswith("D"):
            return self._d_row(int(wl[1:]))
        raise KeyError(wl)

    def _set_cell(self, wl: str, value: np.ndarray) -> None:
        # Cell state is updated by rebinding only (arrays are never mutated
        # in place anywhere in the simulator), so storing `value` without a
        # defensive copy is safe even when several cells alias the same
        # row-buffer array.
        if wl.startswith("T"):
            self.t_rows[wl] = value
        elif wl.startswith("DCC"):
            self.dcc[dcc_capacitor(wl)] = value
        elif wl.startswith("C"):
            # Control rows are pre-initialized constants: restoring the same
            # value (single-cell activate) is fine; overwriting is a bug in
            # the command stream (the controller never targets C rows).
            if not np.array_equal(self.c_rows[int(wl[1:])], value):
                raise AmbitError(f"control row {wl} is read-only")
        elif wl.startswith("D"):
            self._check_d_index(int(wl[1:]))
            self._d_rows[int(wl[1:])] = value
        else:
            raise KeyError(wl)

    # -- command execution --------------------------------------------------

    def execute(self, stream: Sequence[Command]) -> None:
        for c in stream:
            if isinstance(c, Activate):
                self._activate(c.addr)
            elif isinstance(c, Precharge):
                self._precharge()
            else:
                raise TypeError(c)

    def run(self, prog: Sequence[Macro]) -> None:
        """Execute a macro (AAP/AP) program once over all batch rows,
        accounting macro-level timing/energy scaled by ``n_rows`` (the
        batch models ``n_rows`` subarrays executing in lockstep)."""
        for m in prog:
            self.stats.add_macro(m, self.timing, rows=self.n_rows)
            self.execute(m.expand())

    def _activate(self, addr: RowAddr) -> None:
        wls = wordlines_for(addr)
        if not self.amp.active:
            self._activate_from_precharged(wls)
        else:
            self._activate_while_active(wls)

    def _activate_from_precharged(self, wls: Sequence[str]) -> None:
        # Effective bitline contribution of each cell: d-wordline cells drive
        # the bitline with their value; an n-wordline DCC drives bitline-bar,
        # equivalent to driving the bitline with its complement.
        contribs = []
        for wl in wls:
            v = self._cell_value(wl)
            contribs.append(~v if is_n_wordline(wl) else v)
        k = len(contribs)
        if k == 1:
            rowbuf = contribs[0]  # aliasing is safe: updates rebind, never
        elif k == 2:              # mutate (see _set_cell)
            if not np.array_equal(contribs[0], contribs[1]):
                raise AmbitError(
                    "2-wordline ACTIVATE from precharged state with "
                    "disagreeing cells: bitline deviation is ~0 (undefined). "
                    "Ambit only uses B8-B11 as AAP copy destinations.")
            rowbuf = contribs[0]
        elif k == 3:
            a, b, c = contribs
            rowbuf = (a & b) | (b & c) | (c & a)  # TRA majority, Section 3.1.1
        else:
            raise AmbitError(f"{k}-wordline activation not supported")
        # Sense amplification drives connected cells to the resolved value
        # (restores single cells; overwrites all cells of a TRA - issue 3).
        self.amp = _SenseAmpState(True, rowbuf, list(wls))
        self._drive_connected(wls)

    def _activate_while_active(self, wls: Sequence[str]) -> None:
        # Second ACTIVATE of an AAP: the sense amps are stable, so every
        # newly-raised wordline's cell is overwritten with the row buffer
        # (negated for n-wordline connections).
        assert self.amp.rowbuf is not None
        self._drive_connected(wls)
        self.amp.open_wordlines.extend(wls)

    def _drive_connected(self, wls: Sequence[str]) -> None:
        assert self.amp.rowbuf is not None
        for wl in wls:
            value = ~self.amp.rowbuf if is_n_wordline(wl) else self.amp.rowbuf
            self._set_cell(wl, value)

    def _precharge(self) -> None:
        self.amp = _SenseAmpState()

    # -- high-level op helpers (used by tests/engine) ------------------------

    def bbop(self, op: str, dst: int, *srcs: int) -> None:
        """Run a Figure-20 op on D-group rows: dst = op(*srcs)."""
        tmpl = cmd.OP_TEMPLATES[op]
        args = [cmd.D(s) for s in srcs] + [cmd.D(dst)]
        self.run(tmpl(*args))


class AmbitBank:
    """A bank: a set of subarrays sharing I/O. RowClone-FPM works within a
    subarray; inter-subarray/inter-bank copies use RowClone-PSM (TRANSFER,
    Section 2.4) at cache-line granularity over the internal bus."""

    PSM_NS_PER_CACHELINE = 5.0   # ~pipelined tCCD-limited transfer
    PSM_NJ_PER_CACHELINE = 4.39  # derived from DDR3 channel energy ~ internal

    def __init__(self, geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 subarrays: Optional[int] = None,
                 words: Optional[int] = None, seed: int = 0):
        self.geom = geometry
        n_sub = geometry.subarrays_per_bank if subarrays is None else subarrays
        self.subarrays = [AmbitSubarray(geometry, timing, words, seed + i)
                          for i in range(n_sub)]
        self.stats = CommandStats()

    def psm_copy(self, src_sub: int, src_row: int, dst_sub: int,
                 dst_row: int) -> None:
        """RowClone-PSM between subarrays/banks: both rows are activated and
        cache lines are TRANSFERred over the internal bus."""
        data = self.subarrays[src_sub].read_row(src_row)
        self.subarrays[dst_sub].write_row(dst_row, data)
        row_bytes = self.subarrays[src_sub].words * 8
        n_lines = row_bytes // 64
        self.stats.ns += 2 * DEFAULT_TIMING.tRAS + n_lines * \
            self.PSM_NS_PER_CACHELINE + DEFAULT_TIMING.tRP
        self.stats.energy_nj += n_lines * self.PSM_NJ_PER_CACHELINE
        self.stats.activates += 2
        self.stats.precharges += 1

    def total_stats(self) -> CommandStats:
        agg = CommandStats()
        agg.merge(self.stats)
        for s in self.subarrays:
            agg.merge(s.stats)
        return agg


class AmbitDevice:
    """Chip-level view: banks operating in parallel + the bbop ISA (S5.1).

    The driver/allocator abstraction (Section 5.2): `alloc` places bitvector
    pages so corresponding rows of co-operating bitvectors land in the same
    subarray, enabling RowClone-FPM for every staging copy.

    ``bbop`` groups the row slots of one call by destination ``(bank,
    subarray)`` and dispatches each group as a single batched subarray
    execution (the device-model analogue of subarray-level parallelism).
    Calls whose source slots alias destination slots fall back to the
    sequential per-slot path to preserve read-after-write ordering."""

    def __init__(self, geometry: DRAMGeometry = DEFAULT_GEOMETRY,
                 timing: TimingParams = DEFAULT_TIMING,
                 banks: Optional[int] = None, subarrays: Optional[int] = None,
                 words: Optional[int] = None, seed: int = 0,
                 batch_groups: bool = True):
        self.geom = geometry
        self.timing = timing
        n_banks = geometry.banks if banks is None else banks
        self.banks = [AmbitBank(geometry, timing, subarrays, words, seed + 97 * b)
                      for b in range(n_banks)]
        self.words = self.banks[0].subarrays[0].words
        self.row_bytes = self.words * 8
        self.batch_groups = batch_groups
        self._allocator = None  # lazy RowAllocator (pim.allocator)
        # Opt-in span tracing (repro.obs): the runtime swaps in a live
        # Tracer; migrate_row emits RowClone-PSM / inter-bank copy spans.
        from ..obs import NULL_TRACER
        self.tracer = NULL_TRACER
        self.trace_name = "device0"     # track prefix (cluster device idx)
        # Opt-in fault injection (repro.pim.faults): the runtime wires a
        # FaultInjector in; row copies and host accesses then consult it.
        self.fault_injector = None
        self.device_index = 0

    # -- allocator (Section 5.2 driver) --------------------------------------

    @property
    def allocator(self):
        """The device's RowAllocator (created lazily; striped placement
        reproduces the seed bump-cursor order until rows are freed)."""
        if self._allocator is None:
            from ..pim.allocator import RowAllocator  # local: import cycle
            self._allocator = RowAllocator.for_device(self)
        return self._allocator

    def alloc_rows(self, n_rows: int, policy: str = None,
                   near: Sequence[tuple] = None) -> List[tuple]:
        """Allocate row slots (back-compat shim over pim.RowAllocator;
        default striped placement = the seed bump-cursor order).
        Returns [(bank, subarray, row), ...]."""
        return self.allocator.alloc(n_rows, policy=policy, near=near)

    def free_rows(self, slots: Sequence[tuple]) -> None:
        """Release previously allocated row slots for reuse."""
        self.allocator.free(slots)

    # -- bbop ISA (Section 5.1) ----------------------------------------------

    def bbop(self, op: str, dst: Sequence[tuple], *srcs: Sequence[tuple]
             ) -> None:
        """bbop dst, src1[, src2], size - operands are row-slot lists of the
        same length (size = len * row_bytes, a multiple of the row size).

        If corresponding slots are co-located in one subarray, the op runs
        fully in-subarray (RowClone-FPM staging). Otherwise sources are
        first PSM-copied into the destination's subarray (slow path).

        Slots are grouped by destination ``(bank, subarray)`` and each
        group executes its AAP program once, batched over the group's rows
        - unless a source slot aliases a destination slot, in which case
        the call runs slot-by-slot in order (sequential semantics)."""
        slots = [(d, [s[i] for s in srcs]) for i, d in enumerate(dst)]
        if not self.batch_groups or self._has_hazard(slots):
            for d, slot_srcs in slots:
                self._bbop_row(op, d, slot_srcs)
            return
        # fall through: no slot aliases another slot's destination or any
        # staging scratch row, so group execution order cannot matter
        groups: Dict[Tuple[int, int], List[tuple]] = {}
        for d, slot_srcs in slots:
            groups.setdefault((d[0], d[1]), []).append((d, slot_srcs))
        for (db, ds), group in groups.items():
            if len(group) == 1:
                d, slot_srcs = group[0]
                self._bbop_row(op, d, slot_srcs)
            else:
                self._bbop_group(op, db, ds, group)

    def _staging_rows(self, db: int, ds: int, n: int) -> List[int]:
        """Pick ``n`` staging rows in subarray ``(db, ds)``, top-down
        from the end of the D-group, SKIPPING rows the device's
        RowAllocator has live. The naive descending pick clobbered live
        data whenever the allocator's usable region reached the top row
        - an allocator with ``scratch_rows=0`` (the lazy default), or
        optimizer-introduced scratch handles landing in a full subarray
        next to user operands, put real bitvector rows exactly where
        staging writes. Row index never enters the cost model, so the
        skip leaves every ledger byte-identical."""
        alloc = self._allocator      # attribute, not property: never
        rows: List[int] = []         # instantiate one just to ask
        r = self.geom.data_rows - 1
        while len(rows) < n and r >= 0:
            if alloc is None or not alloc.is_live((db, ds, r)):
                rows.append(r)
            r -= 1
        if len(rows) < n:
            # Every row is live (an allocator with scratch_rows=0 can
            # fill the whole D-group): fall back to the legacy top-down
            # pick for the remainder. _has_hazard treats these rows as
            # staging targets, so any within-call alias still forces the
            # sequential path.
            r = self.geom.data_rows - 1
            while len(rows) < n and r >= 0:
                if r not in rows:
                    rows.append(r)
                r -= 1
        if len(rows) < n:
            raise AmbitError(
                f"bbop needs {n} staging rows but bank {db} subarray "
                f"{ds} has only {self.geom.data_rows} data rows")
        return rows

    def _has_hazard(self, slots: List[tuple]) -> bool:
        """True when batched grouping could reorder a read past a write:
        a source slot aliases a destination slot, or a destination/source
        slot aliases a PSM staging row (the allocator-aware top-of-
        D-group pick) that some slot's staging will overwrite."""
        dst_set = {d for d, _ in slots}
        scratch_set = set()
        for (db, ds, _), slot_srcs in slots:
            n_stage = sum(1 for s in slot_srcs if (s[0], s[1]) != (db, ds))
            if n_stage:
                scratch_set.update(
                    (db, ds, r) for r in self._staging_rows(db, ds, n_stage))
        if dst_set & scratch_set:
            return True
        return any(s in dst_set or s in scratch_set
                   for _, slot_srcs in slots for s in slot_srcs)

    def _bbop_group(self, op: str, db: int, ds: int,
                    group: List[tuple]) -> None:
        """One batched dispatch for all slots destined to subarray
        ``(db, ds)``: gather (PSM-staged if needed) source rows, execute the
        op template once over a batch of ``len(group)`` rows, scatter the
        results into the destination rows. Stats are identical to the
        per-slot path (macro costs scale by the batch size; staging costs
        accounted per slot)."""
        sub = self.banks[db].subarrays[ds]
        n = len(group)
        n_srcs = len(group[0][1])
        gathered = [np.empty((n, self.words), np.uint64)
                    for _ in range(n_srcs)]
        for gi, (_, slot_srcs) in enumerate(group):
            # Stage exactly as the sequential path does (the same
            # allocator-aware staging rows per slot), gathering each
            # source's value right after its staging so later slots'
            # staging cannot clobber it.
            n_stage = sum(1 for s in slot_srcs
                          if (s[0], s[1]) != (db, ds))
            stage_rows = iter(self._staging_rows(db, ds, n_stage)
                              if n_stage else ())
            for si, s in enumerate(slot_srcs):
                gathered[si][gi] = self._fetch_src(db, ds, s, stage_rows)
        batch = AmbitSubarray(self.geom, self.timing, words=self.words,
                              n_rows=n)
        for si in range(n_srcs):
            batch.write_row(si, gathered[si])
        batch.bbop(op, n_srcs, *range(n_srcs))
        out = batch.read_row(n_srcs).reshape(n, self.words)
        for gi, (d, _) in enumerate(group):
            sub.write_row(d[2], out[gi])
        sub.stats.merge(batch.stats)

    def _fetch_src(self, db: int, ds: int, src: tuple,
                   stage_rows) -> np.ndarray:
        """Source row content for a slot destined to subarray (db, ds),
        accounting PSM staging cost when the source is not co-located
        (the data still physically lands in the destination subarray's
        next staging row from ``stage_rows``, mirroring the sequential
        path)."""
        sb, ss, sr = src
        bank = self.banks[db]
        if (sb, ss) == (db, ds):
            return bank.subarrays[ds].read_row(sr)
        scratch = next(stage_rows)
        self._stage_psm(db, ds, src, scratch)
        return bank.subarrays[ds].read_row(scratch)

    def migrate_row(self, src: tuple, dst: tuple) -> None:
        """Copy one row between arbitrary slots: intra-bank via
        RowClone-PSM, inter-bank over the channel (same latency/energy
        model, charged to the destination bank). Single cost-model site
        for bbop staging and the pim store's migration planner."""
        inj = self.fault_injector
        if inj is not None:
            inj.check_alive(self.device_index)
        sb, ss, sr = src
        db, ds, dr = dst
        bank = self.banks[db]
        n_lines = self.row_bytes // 64
        if sb == db:
            bank.psm_copy(ss, sr, ds, dr)
            if self.tracer.enabled:
                # mirror psm_copy's charge so the span length IS the cost
                dur = (2 * DEFAULT_TIMING.tRAS
                       + n_lines * AmbitBank.PSM_NS_PER_CACHELINE
                       + DEFAULT_TIMING.tRP)
                self.tracer.tick(
                    (self.trace_name, f"bank{db}", "migrate"),
                    "rowclone_psm", "migrate", dur,
                    args={"src": list(src), "dst": list(dst)})
            self._post_transfer(dst)
            return
        data = self.banks[sb].subarrays[ss].read_row(sr)
        bank.subarrays[ds].write_row(dr, data)
        dur = 2 * DEFAULT_TIMING.tRAS + \
            n_lines * AmbitBank.PSM_NS_PER_CACHELINE
        bank.stats.ns += dur
        bank.stats.energy_nj += n_lines * AmbitBank.PSM_NJ_PER_CACHELINE
        if self.tracer.enabled:
            self.tracer.tick(
                (self.trace_name, f"bank{db}", "migrate"),
                "interbank_copy", "migrate", dur,
                args={"src": list(src), "dst": list(dst)})
        self._post_transfer(dst)

    def _post_transfer(self, dst: tuple) -> None:
        """RowClone fault injection: the copy happened (and was billed);
        the injector may now corrupt the landed row or declare the
        destination stuck (write-verify raises)."""
        inj = self.fault_injector
        if inj is None:
            return
        db, ds, dr = dst
        sub = self.banks[db].subarrays[ds]
        row = sub.read_row(dr)
        out = inj.on_transfer(self.device_index, dst, row)
        if out is not row:
            sub.write_row(dr, out)

    def _stage_psm(self, db: int, ds: int, src: tuple, scratch: int) -> None:
        """Stage a non-co-located source row into scratch row `scratch` of
        subarray (db, ds)."""
        self.migrate_row(src, (db, ds, scratch))

    def _bbop_row(self, op: str, dst: tuple, srcs: List[tuple]) -> None:
        db, ds, dr = dst
        bank = self.banks[db]
        staged = []
        # Staging rows live at the top of the D-group, skipping any the
        # allocator has live (see _staging_rows).
        n_stage = sum(1 for s in srcs if (s[0], s[1]) != (db, ds))
        stage_rows = iter(self._staging_rows(db, ds, n_stage)
                          if n_stage else ())
        for src in srcs:
            if (src[0], src[1]) == (db, ds):
                staged.append(src[2])
            else:  # slow path: stage into the destination subarray
                scratch = next(stage_rows)
                self._stage_psm(db, ds, src, scratch)
                staged.append(scratch)
        bank.subarrays[ds].bbop(op, dr, *staged)

    # -- convenience ----------------------------------------------------------

    def write(self, slots: Sequence[tuple], data: np.ndarray) -> None:
        if self.fault_injector is not None:
            self.fault_injector.check_alive(self.device_index)
        data = np.asarray(data, np.uint64).reshape(len(slots), self.words)
        for (b, s, r), row in zip(slots, data):
            self.banks[b].subarrays[s].write_row(r, row)

    def read(self, slots: Sequence[tuple]) -> np.ndarray:
        if self.fault_injector is not None:
            self.fault_injector.check_alive(self.device_index)
        return np.stack([self.banks[b].subarrays[s].read_row(r)
                         for (b, s, r) in slots])

    def total_stats(self) -> CommandStats:
        agg = CommandStats()
        for b in self.banks:
            agg.merge(b.total_stats())
        return agg
