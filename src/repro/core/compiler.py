"""Compiler: bitwise expression DAG -> AAP/AP command program (Section 4.2).

The naive strategy expands one Figure-20 template per DAG node, staging every
operand into the designated rows with RowClone-FPM copies. The paper notes
("standard compilation techniques... dead-store elimination") that much of
this copy overhead is removable. The optimizing compiler implements:

  * CSE              - the expression DAG is hash-consed at construction.
  * constant folding - in expr.py (`x & 1 -> x`, `maj(a,b,0) -> a & b`, ...).
  * negation fusion  - Not(And) -> nand template, Not(Or) -> nor,
                       Not(Xor) -> xnor, Not(x) at the root via DCC.
  * designated-row state tracking - after a TRA, *all three* activated rows
    hold the result (Section 3.1, issue 3); after AAP(Di,B8), DCC0 holds
    !Di and T0 holds Di, etc. The compiler tracks the symbolic contents of
    T0..T3/DCC0/DCC1 and skips staging AAPs whose target row already holds
    the needed value. Left-deep AND/OR reduction chains drop from 4 AAPs
    per op to ~2 this way (dead stores never emitted).
  * spill minimization - intermediates with a single consumer are consumed
    directly out of the designated rows; only multi-consumer nodes are
    spilled to scratch D-rows.

Outputs a `CompiledProgram` with the macro list, scratch usage, and a
timing/energy cost summary (timing.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from . import commands as cmd
from .commands import AAP, AP, B, C, D, Macro, RowAddr
from .expr import Expr, ZERO, ONE, consumer_counts, topo_order
from .timing import DEFAULT_TIMING, CommandStats, TimingParams, program_stats

# Wordline -> B-group address that activates exactly that wordline.
_WL_ADDR = {"T0": B(0), "T1": B(1), "T2": B(2), "T3": B(3),
            "DCC0": B(4), "DCC0N": B(5), "DCC1": B(6), "DCC1N": B(7)}


@dataclasses.dataclass
class CompiledProgram:
    """An immutable compiled AAP/AP program.

    ``program`` and ``scratch_rows`` are tuples so one CompiledProgram can
    be shared safely by the engine's compile cache across many eval calls
    (the program depends only on the expression shape, the variable-name
    ordering, the optimize flag and the D-group size - never on operand
    data or batch size)."""

    program: Tuple[Macro, ...]
    out_row: RowAddr
    scratch_rows: Tuple[int, ...]
    stats: CommandStats

    @property
    def n_aap(self) -> int:
        return self.stats.aap_count

    @property
    def n_ap(self) -> int:
        return self.stats.ap_count


class _RowState:
    """Symbolic contents of the designated/DCC rows.

    Values are (expr_id, negated) pairs; None = unknown/clobbered.
    """

    def __init__(self):
        self.state: Dict[str, Optional[tuple]] = {
            wl: None for wl in ("T0", "T1", "T2", "T3", "DCC0", "DCC1")}

    def holds(self, wl: str, value: tuple) -> bool:
        return self.state.get(wl) == value

    def set(self, wl: str, value: Optional[tuple]):
        self.state[wl] = value

    def find(self, value: tuple) -> Optional[str]:
        for wl, v in self.state.items():
            if v == value:
                return wl
        return None


class Compiler:
    def __init__(self, var_rows: Dict[str, int], dst_row: int,
                 n_data_rows: int = 1006, optimize: bool = True,
                 timing: TimingParams = DEFAULT_TIMING):
        self.var_rows = dict(var_rows)
        self.dst_row = dst_row
        self.optimize = optimize
        self.timing = timing
        self.prog: List[Macro] = []
        self.rows = _RowState()
        # expr id -> D-row address for spilled/variable values
        self.loc: Dict[int, RowAddr] = {}
        self.scratch: List[int] = []
        self._next_scratch = n_data_rows - 1
        used = set(var_rows.values()) | {dst_row}
        while self._next_scratch in used:
            self._next_scratch -= 1
        self._used = used

    # -- emission helpers ----------------------------------------------------

    def _emit(self, m: Macro):
        self.prog.append(m)

    def _alloc_scratch(self) -> int:
        r = self._next_scratch
        while r in self._used:
            r -= 1
        if r < 0:
            raise RuntimeError("out of scratch rows")
        self._used.add(r)
        self._next_scratch = r - 1
        self.scratch.append(r)
        return r

    def _source_addr(self, value: tuple) -> Optional[RowAddr]:
        """Address whose single ACTIVATE yields `value` in the row buffer."""
        eid, neg = value
        if eid in self.loc and not neg:
            return self.loc[eid]
        wl = self.rows.find(value)
        if wl is not None:
            return _WL_ADDR[wl]
        # A negated value can be read from a DCC capacitor's n-wordline.
        wl = self.rows.find((eid, not neg))
        if wl in ("DCC0", "DCC1"):
            return _WL_ADDR[wl + "N"]
        return None

    def _stage(self, wl: str, value: tuple):
        """Ensure designated row `wl` holds `value`, emitting an AAP if not."""
        if self.optimize and self.rows.holds(wl, value):
            return
        src = self._source_addr(value) if self.optimize else None
        if src is None:
            eid, neg = value
            if neg:
                raise RuntimeError("negated value not materialized")
            src = self.loc[eid]
        dst = _WL_ADDR[wl]
        self._emit(AAP(src, dst))
        self._apply_copy_effects(src, dst)

    def _apply_copy_effects(self, src: RowAddr, dst: RowAddr):
        """Update symbolic row state for AAP(src, dst)."""
        # Value resolved by activating src:
        val = self._value_of_activate(src)
        for wl in cmd.wordlines_for(dst):
            if cmd.is_n_wordline(wl):
                cap = cmd.dcc_capacitor(wl)
                self.rows.set(cap, _negate(val))
            else:
                self.rows.set(wl, val)

    def _value_of_activate(self, addr: RowAddr) -> Optional[tuple]:
        if addr.group == "B":
            wls = cmd.wordlines_for(addr)
            if len(wls) == 1:
                wl = wls[0]
                if cmd.is_n_wordline(wl):
                    return _negate(self.rows.state[cmd.dcc_capacitor(wl)])
                return self.rows.state[wl]
            return None  # TRA handled separately
        if addr.group == "C":
            return (id(ZERO) if addr.index == 0 else id(ONE), False)
        for eid, loc in self.loc.items():
            if loc == addr:
                return (eid, False)
        return None

    def _tra(self, dst: Optional[RowAddr], result: tuple,
             negate_into_dcc: Optional[str] = None):
        """Emit the B12 TRA over T0,T1,T2; result lands in all three rows
        and is optionally copied out to `dst` (AAP) or kept (AP)."""
        if negate_into_dcc is not None:
            self._emit(AAP(B(12), _WL_ADDR[negate_into_dcc + "N"]))
            for wl in ("T0", "T1", "T2"):
                self.rows.set(wl, result)
            self.rows.set(negate_into_dcc, _negate(result))
        elif dst is None:
            self._emit(AP(B(12)))
            for wl in ("T0", "T1", "T2"):
                self.rows.set(wl, result)
        else:
            self._emit(AAP(B(12), dst))
            for wl in ("T0", "T1", "T2"):
                self.rows.set(wl, result)

    # -- op lowering ---------------------------------------------------------

    def compile(self, root: Expr) -> CompiledProgram:
        counts = consumer_counts(root)
        topo = [n for n in topo_order(root) if n.op not in ("var", "lit")]
        for v, r in self.var_rows.items():
            self.loc[id(Expr.var(v))] = D(r)
        self.loc[id(ZERO)] = C(0)
        self.loc[id(ONE)] = C(1)

        if not topo:  # trivial: output is a var/lit -> RowClone copy
            self._emit(AAP(self.loc[id(root)], D(self.dst_row)))
            return self._finish()

        # Negation fusion: a single-consumer and/or/xor feeding a `not` is
        # lowered inside the `not` (nand/nor/xnor templates), never alone.
        self.fused: Dict[int, Expr] = {}  # id(not-node) -> fused child
        if self.optimize:
            for n in topo:
                if n.op == "not":
                    (ch,) = n.args
                    if ch.op in ("and", "or", "xor") and \
                            counts.get(id(ch), 0) == 1:
                        self.fused[id(n)] = ch
        fused_children = {id(ch) for ch in self.fused.values()}
        order = [n for n in topo if id(n) not in fused_children]

        def effective(n: Expr):
            ch = self.fused.get(id(n))
            return ch if ch is not None else n

        # A rows-resident (unspilled) value survives only until the next
        # lowering clobbers the designated rows, so an intermediate may stay
        # unspilled ONLY if (a) its unique consumer is lowered immediately
        # next AND (b) that consumer's staging can reuse it in place
        # (and/or/maj via T-row holds; xor re-loads via an 80 ns B->B AAP
        # which is *slower* than spill+load, so xor consumers force a spill).
        consumer_pos: Dict[int, int] = {}
        for i, n in enumerate(order):
            for a in effective(n).args:
                consumer_pos[id(a)] = i

        for i, n in enumerate(order):
            is_root = n is root
            multi_use = counts.get(id(n), 0) > 1
            consumed_next = consumer_pos.get(id(n)) == i + 1
            next_op = (effective(order[i + 1]).op
                       if i + 1 < len(order) else None)
            keep_in_rows = (self.optimize and not multi_use and consumed_next
                            and next_op in ("and", "or", "maj")
                            and not is_root)
            out_addr = D(self.dst_row) if is_root else (
                None if keep_in_rows else D(self._alloc_scratch()))
            self._lower(n, out_addr)
            if out_addr is not None:
                self.loc[id(n)] = out_addr
        return self._finish()

    def _finish(self) -> CompiledProgram:
        st = program_stats(self.prog, self.timing)
        return CompiledProgram(tuple(self.prog), D(self.dst_row),
                               tuple(self.scratch), st)

    def _val(self, e: Expr) -> tuple:
        return (id(e), False)

    def _lower(self, n: Expr, out: Optional[RowAddr]):
        op = n.op
        res = self._val(n)
        if op == "not":
            (x,) = n.args
            self._lower_not(x, n, out)
            return
        if op in ("and", "or"):
            x, y = n.args
            ctrl = C(0) if op == "and" else C(1)
            self._stage("T0", self._val(x))
            self._stage("T1", self._val(y))
            self._stage_ctrl(ctrl)
            self._tra(out, res)
            return
        if op == "maj":
            x, y, z = n.args
            self._stage("T0", self._val(x))
            self._stage("T1", self._val(y))
            self._stage("T2", self._val(z))
            self._tra(out, res)
            return
        if op == "xor":
            self._lower_xor(n, out, negate=False)
            return
        raise KeyError(op)

    def _stage_ctrl(self, ctrl: RowAddr):
        want = (id(ZERO) if ctrl.index == 0 else id(ONE), False)
        if self.optimize and self.rows.holds("T2", want):
            return
        self._emit(AAP(ctrl, B(2)))
        self.rows.set("T2", want)

    def _lower_not(self, x: Expr, n: Expr, out: Optional[RowAddr]):
        """not x -> fuse with the child op when possible (nand/nor/xnor)."""
        res = self._val(n)
        fused = getattr(self, "fused", {}).get(id(n)) is x
        if fused and x.op in ("and", "or"):
            a, b = x.args
            ctrl = C(0) if x.op == "and" else C(1)
            self._stage("T0", self._val(a))
            self._stage("T1", self._val(b))
            self._stage_ctrl(ctrl)
            # TRA, negating through DCC0 (nand/nor template tail). The DCC0
            # capacitor captures !(a op b) = res; read it back via its
            # d-wordline (B4), exactly as Figure 20b does.
            self._tra(None, self._val(x), negate_into_dcc="DCC0")
            # DCC0 holds the *not-node's* value (same bits as !(x)): record
            # it under the not-node id so later staging can find it.
            self.rows.set("DCC0", res)
            self._copy_out(B(4), res, out)
            return
        if fused and x.op == "xor":
            self._lower_xor(x, out, negate=True, res_override=res)
            return
        # plain NOT via DCC (Fig. 18 / Section 4.2).
        src = self._source_addr(self._val(x))
        if src is None:
            src = self.loc[id(x)]
        self._emit(AAP(src, B(5)))       # DCC0 = !x
        self.rows.set("DCC0", res)       # DCC0 capacitor holds !x == res
        self._copy_out(B(4), res, out)

    def _copy_out(self, src: RowAddr, res: tuple, out: Optional[RowAddr]):
        """Copy a value readable via `src` to `out` (or leave it in rows)."""
        if out is not None:
            self._emit(AAP(src, out))
            self._apply_copy_effects(src, out)

    def _lower_xor(self, n: Expr, out: Optional[RowAddr], negate: bool,
                   res_override: Optional[tuple] = None):
        """Figure 20c (+ xnor variant routing the combine through DCC0N)."""
        x, y = n.args
        res = res_override if res_override is not None else self._val(n)
        # xor is commutative: if y's only residence is the DCC0 capacitor
        # (clobbered by the first copy below), stage it first by swapping.
        if (self.optimize and id(y) not in self.loc
                and self.rows.holds("DCC0", self._val(y))):
            x, y = y, x
        vx, vy = self._val(x), self._val(y)
        # Resolve each source address right before its ACTIVATE: the first
        # copy clobbers T0/DCC0, which may have been y's resident row.
        sx = (self._source_addr(vx) if self.optimize else None) \
            or self.loc[id(x)]
        self._emit(AAP(sx, B(8)))    # DCC0 = !x, T0 = x
        self._apply_copy_effects(sx, B(8))
        sy = (self._source_addr(vy) if self.optimize else None) \
            or self.loc[id(y)]
        self._emit(AAP(sy, B(9)))    # DCC1 = !y, T1 = y
        self._apply_copy_effects(sy, B(9))
        self._emit(AAP(C(0), B(10)))  # T2 = T3 = 0
        self._emit(AP(B(14)))        # T1 = !x & y
        self._emit(AP(B(15)))        # T0 = x & !y
        self._emit(AAP(C(1), B(2)))  # T2 = 1
        # rows now: T0 = x&!y, T1 = !x&y, T2 = 1, T3 = x&!y-ish
        for wl in ("T3", "DCC0", "DCC1"):
            self.rows.set(wl, None)
        if negate:
            self._emit(AAP(B(12), B(5)))   # DCC0 = xnor
            for wl in ("T0", "T1", "T2"):
                self.rows.set(wl, _negate(res))
            self.rows.set("DCC0", res)
            self._copy_out(B(4), res, out)
        else:
            if out is None:
                self._emit(AP(B(12)))
            else:
                self._emit(AAP(B(12), out))
            for wl in ("T0", "T1", "T2"):
                self.rows.set(wl, res)


def _negate(val: Optional[tuple]) -> Optional[tuple]:
    if val is None:
        return None
    return (val[0], not val[1])


def compile_expr(root: Expr, var_rows: Dict[str, int], dst_row: int,
                 n_data_rows: int = 1006, optimize: bool = True,
                 timing: TimingParams = DEFAULT_TIMING) -> CompiledProgram:
    return Compiler(var_rows, dst_row, n_data_rows, optimize,
                    timing).compile(root)
