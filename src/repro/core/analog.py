"""Analog model of triple-row activation (Section 3.1.1 / Section 6).

Implements Equation 1 generalized to varied per-cell capacitances, plus a
Monte-Carlo harness over process variation that reproduces the *trend* of
Table 3 (the paper used transistor-level SPICE; we use the charge-sharing
equation with a sense-amplifier offset term, calibrated so the failure
onset matches the paper's: 0% at +-5%, <1% at +-10%, single-digit % at
+-15%, tens of % at +-25%).

Model:
  delta = (sum_i q_i Cc_i Vdd + Cb Vdd/2) / (sum_i Cc_i + Cb) - Vdd/2
  with q_i in {0, U(1 - v*Q_RESTORE_SCALE, 1)}  (incomplete-restore /
  access-transistor variation scales with process variation v), and TRA
  resolves correctly iff sign(delta - V_off) == sign(ideal majority), where
  V_off ~ U(-v, v) * V_OFF_SCALE * Vdd is the sense-amp offset.

Constants: Cc = 22 fF (Rambus model, Section 6); Cb/Cc = 3.63 (typical for
512-cell bitlines); V_OFF_SCALE and Q_RESTORE_SCALE calibrated numerically
(see benchmarks/table3_variation.py). Calibrated model vs Table 3:
  +-5%: 0.00% vs 0.00%   +-10%: 0.24% vs 0.29%   +-15%: 6.13% vs 6.01%
  +-20%: 12.7% vs 16.4%  +-25%: 17.7% vs 26.2%  (trend reproduced; deep
tail underestimates SPICE, where transistor-level effects compound).
"""

from __future__ import annotations

import dataclasses

import numpy as np

VDD = 1.2  # volts (DDR3)
CC_NOMINAL_FF = 22.0
CB_OVER_CC = 3.63
# Calibrated so Monte-Carlo failure rates track Table 3 (see table3 benchmark).
V_OFF_SCALE = 0.50
Q_RESTORE_SCALE = 1.0


@dataclasses.dataclass(frozen=True)
class AnalogParams:
    vdd: float = VDD
    cc_ff: float = CC_NOMINAL_FF
    cb_over_cc: float = CB_OVER_CC
    v_off_scale: float = V_OFF_SCALE
    q_restore_scale: float = Q_RESTORE_SCALE


def bitline_deviation(charges: np.ndarray, cc: np.ndarray, cb: np.ndarray,
                      vdd: float = VDD) -> np.ndarray:
    """Equation 1, generalized: charges/cc are (..., k) arrays for k cells."""
    num = (charges * cc).sum(-1) * vdd + cb * 0.5 * vdd
    den = cc.sum(-1) + cb
    return num / den - 0.5 * vdd


def ideal_majority(bits: np.ndarray) -> np.ndarray:
    """(..., k) -> (...) boolean majority."""
    return bits.sum(-1) * 2 > bits.shape[-1]


def tra_failure_rate(variation: float, n_trials: int = 100_000,
                     params: AnalogParams = AnalogParams(),
                     seed: int = 0) -> float:
    """Monte-Carlo fraction of TRAs resolving the wrong value (Table 3).

    Each trial samples three fully-refreshed cells with uniformly varied
    capacitances, a varied bitline capacitance, and a sense-amp offset with
    spread proportional to the variation level. Cell contents are sampled
    uniformly from the 8 possible states (failures are dominated by k=1,2
    borderline cases, as in the paper)."""
    rng = np.random.default_rng(seed)
    v = variation
    bits = rng.integers(0, 2, size=(n_trials, 3)).astype(np.float64)
    cc = params.cc_ff * rng.uniform(1 - v, 1 + v, size=(n_trials, 3))
    cb = params.cc_ff * params.cb_over_cc * rng.uniform(1 - v, 1 + v,
                                                        size=n_trials)
    # Incomplete restore / access-transistor strength variation on charged
    # cells: stored charge in [1 - v*q_scale, 1] of full.
    q = bits * rng.uniform(1 - v * params.q_restore_scale, 1.0,
                           size=(n_trials, 3))
    v_off = rng.uniform(-v, v, size=n_trials) * params.v_off_scale * params.vdd
    delta = bitline_deviation(q, cc, cb, params.vdd)
    resolved_one = (delta - v_off) > 0
    expect_one = ideal_majority(bits)
    return float(np.mean(resolved_one != expect_one))


def tra_worst_case_margin(params: AnalogParams = AnalogParams(),
                          resolution: float = 1e-4) -> float:
    """Largest variation v at which TRA still resolves correctly when *every*
    component deviates adversarially (Section 6: paper reports ~+-6%).

    Worst case for k=2 (two charged cells): both charged cells at (1-v)Cc,
    the empty cell at (1+v)Cc, bitline at (1+v)Cb, sense offset at +v*scale.
    """
    lo, hi = 0.0, 0.5
    while hi - lo > resolution:
        v = 0.5 * (lo + hi)
        cc = np.array([(1 - v), (1 - v), (1 + v)]) * params.cc_ff
        charges = np.array([1.0 - v * params.q_restore_scale,
                            1.0 - v * params.q_restore_scale, 0.0])
        cb = np.array(params.cc_ff * params.cb_over_cc * (1 + v))
        delta = bitline_deviation(charges[None], cc[None], cb[None],
                                  params.vdd)[0]
        ok = (delta - v * params.v_off_scale * params.vdd) > 0
        if ok:
            lo = v
        else:
            hi = v
    return lo
