"""DRAM timing-rule checker: a differential oracle for command streams.

The cost model (timing.py) *prices* AAP/AP/PSM macros; nothing until now
checked that the streams the compiler and simulator emit could legally
issue on a DDR3 bank. This module closes that gap: ``schedule_program``
replays a macro program into per-command issue times and ``TimingChecker``
validates the timed stream against a declarative rule table (tRP, tRCD,
tRAS, tRC, tWR, rank-level tFAW, refresh windows, and bank open/close
discipline), reporting structured ``TimingViolation`` records instead of
a pass/fail bit. Inspired by the timing checkers DRAM controller
generators ship for their command schedulers.

Replay semantics
----------------
The checker builds its own *rule-consistent* schedule rather than forcing
the paper's SPICE-derived cost figures onto the command clock:

  * optimized AAP (split row decoder, Section 4.3): ACT @ t, the paired
    ACT @ t + aap_overlap_extra_ns, PRE @ t + tRAS - restoration of both
    rows completes within one shared sense-amplifier cycle, so tRAS is
    honored from the *first* ACTIVATE. Macro occupancy tRAS + tRP.
  * naive AAP (RowClone-FPM): ACT @ t, ACT @ t + tRAS, PRE @ t + 2*tRAS;
    occupancy 2*tRAS + tRP.
  * AP: ACT @ t, PRE @ t + tRAS; occupancy tRAS + tRP.
  * PSM copy (``schedule_psm_copy``): source ACT, destination ACT one
    tRAS later, one column WRITE per cache line every
    ``PSM_NS_PER_CACHELINE``, PRE after the last write - this is the one
    stream exercising tRCD and tWR.

These occupancies are the *rule floor* (50/85 ns), intentionally looser
than the cost model's 49/80 ns SPICE figures - the checker answers "is
this stream legal?", the cost model answers "what does it cost?"; keeping
them independent is what makes the replay a differential oracle.

A second ACTIVATE to an already-open bank is legal only as the paired
ACT of the same macro (``macro_id`` ties commands to the macro that
emitted them); any other ACT-while-open is a missing PRECHARGE. tFAW is
checked at *rank* level - a rolling window over ACTs across all banks -
so cross-bank streams can violate it even when every bank is
individually legal.

Refresh: no command may issue inside a refresh window ([k*tREFI,
k*tREFI + tRFC), timing.py). ``schedule_program(refresh_aware=True)``
defers each macro past windows exactly like a controller holding
commands during REF; scheduling with ``refresh_aware=False`` documents
what the checker catches when nobody does.

Run ``python -m repro.core.timing_checker`` to verify every canonical
program (Figure-20 templates plus compiled expressions, optimized and
naive) - the CI ``timing-oracle`` job does exactly this.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .commands import AAP, AP, D, Macro, OP_ARITY, OP_TEMPLATES, RowAddr
from .simulator import AmbitBank, AmbitError
from .timing import DEFAULT_TIMING, TimingParams, defer_for_refresh

_EPS = 1e-6  # float-comparison slack, well under any real timing margin


# -- the rule table -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimingRule:
    """One named constraint; ``gap`` is the minimum spacing it demands."""

    name: str
    description: str
    gap: Optional[Callable[[TimingParams], float]] = None


RULES: Tuple[TimingRule, ...] = (
    TimingRule("tRP", "PRECHARGE -> next ACTIVATE, same bank",
               lambda p: p.tRP),
    TimingRule("tRCD", "ACTIVATE -> first column access, same bank",
               lambda p: p.tRCD),
    TimingRule("tRAS", "first ACTIVATE -> PRECHARGE, same bank",
               lambda p: p.tRAS),
    TimingRule("tRC", "ACTIVATE -> ACTIVATE of the next macro, same bank",
               lambda p: p.tRAS + p.tRP),
    TimingRule("tWR", "last WRITE -> PRECHARGE, same bank",
               lambda p: p.tWR),
    TimingRule("tFAW", "at most four ACTIVATEs across the rank per "
               "rolling tFAW window", lambda p: p.tFAW),
    TimingRule("refresh", "no command inside a [k*tREFI, k*tREFI+tRFC) "
               "refresh window", lambda p: p.tRFC),
    TimingRule("open-bank", "ACTIVATE while open only as a macro's paired "
               "second ACTIVATE; columns only while open; streams close "
               "every bank", None),
)

RULES_BY_NAME: Dict[str, TimingRule] = {r.name: r for r in RULES}


# -- timed command streams ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimedCommand:
    """One DRAM command on the wall clock. ``macro_id`` identifies the
    macro that emitted it (ties an AAP's paired ACTIVATEs together)."""

    t_ns: float
    kind: str  # "ACT" | "PRE" | "WR"
    bank: int
    macro_id: int
    addr: Optional[RowAddr] = None


@dataclasses.dataclass(frozen=True)
class TimingViolation:
    rule: str
    bank: int
    t_ns: float
    message: str


class TimingViolationError(AmbitError):
    """Raised by ``verify_program`` when a stream breaks the rule table."""

    def __init__(self, violations: Sequence[TimingViolation]):
        self.violations = list(violations)
        head = "; ".join(v.message for v in self.violations[:3])
        more = len(self.violations) - 3
        tail = f" (+{more} more)" if more > 0 else ""
        super().__init__(
            f"{len(self.violations)} timing violation(s): {head}{tail}")


def _is_split(m: AAP) -> bool:
    return ((m.src.group == "B") + (m.dst.group == "B")) == 1


def schedule_program(prog: Sequence[Macro],
                     params: TimingParams = DEFAULT_TIMING,
                     bank: int = 0, start_ns: float = 0.0,
                     refresh_aware: bool = True) -> List[TimedCommand]:
    """Replay a macro program into per-command issue times (semantics in
    the module docstring). With ``refresh_aware`` each macro is deferred
    past refresh windows, as a real controller would hold it."""
    events: List[TimedCommand] = []
    t = start_ns
    for mid, m in enumerate(prog):
        if isinstance(m, AAP):
            if _is_split(m):
                act2, pre = params.aap_overlap_extra_ns, params.tRAS
            else:
                act2, pre = params.tRAS, 2 * params.tRAS
            dur = pre + params.tRP
            if refresh_aware:
                t = defer_for_refresh(t, dur, params)
            events.append(TimedCommand(t, "ACT", bank, mid, m.src))
            events.append(TimedCommand(t + act2, "ACT", bank, mid, m.dst))
            events.append(TimedCommand(t + pre, "PRE", bank, mid))
            t += dur
        elif isinstance(m, AP):
            dur = params.ap_ns
            if refresh_aware:
                t = defer_for_refresh(t, dur, params)
            events.append(TimedCommand(t, "ACT", bank, mid, m.addr))
            events.append(TimedCommand(t + params.tRAS, "PRE", bank, mid))
            t += dur
        else:
            raise TypeError(m)
    return events


def schedule_psm_copy(n_lines: int, params: TimingParams = DEFAULT_TIMING,
                      bank: int = 0, start_ns: float = 0.0,
                      macro_id: int = 0,
                      refresh_aware: bool = True) -> List[TimedCommand]:
    """Replay one RowClone-PSM copy (simulator.AmbitBank.psm_copy): read
    the source row open, open the destination, stream ``n_lines`` column
    writes, precharge. Matches the cost model's
    2*tRAS + n*PSM_NS_PER_CACHELINE + tRP occupancy."""
    per_line = AmbitBank.PSM_NS_PER_CACHELINE
    dur = 2 * params.tRAS + n_lines * per_line + params.tRP
    t = start_ns
    if refresh_aware:
        t = defer_for_refresh(t, dur, params)
    events = [TimedCommand(t, "ACT", bank, macro_id),
              TimedCommand(t + params.tRAS, "ACT", bank, macro_id)]
    first_wr = t + params.tRAS + params.tRCD
    for i in range(n_lines):
        events.append(TimedCommand(first_wr + i * per_line, "WR", bank,
                                   macro_id))
    events.append(TimedCommand(t + dur - params.tRP, "PRE", bank, macro_id))
    return events


# -- the checker --------------------------------------------------------------


@dataclasses.dataclass
class _BankState:
    open_since: Optional[float] = None   # first ACT of the open macro
    open_macro: Optional[int] = None
    acts_in_macro: int = 0
    last_pre: Optional[float] = None
    last_act: Optional[float] = None     # first ACT of the previous macro
    last_wr: Optional[float] = None


class TimingChecker:
    """Validates a timed command stream against ``RULES``.

    ``check`` returns every violation found (empty list = legal stream);
    ``verify_program`` schedules a macro program and raises
    ``TimingViolationError`` if its replay is illegal.
    """

    def __init__(self, params: TimingParams = DEFAULT_TIMING,
                 check_refresh: bool = True):
        self.params = params
        self.check_refresh = check_refresh

    # rule helpers ------------------------------------------------------------

    def _gap(self, rule: str) -> float:
        return RULES_BY_NAME[rule].gap(self.params)

    def _in_refresh_window(self, t: float) -> bool:
        p = self.params
        k = int((t + _EPS) // p.tREFI)
        return k >= 1 and t < k * p.tREFI + p.tRFC - _EPS

    @staticmethod
    def _viol(rule: str, bank: int, t: float, msg: str) -> TimingViolation:
        return TimingViolation(rule, bank, t, f"[{rule}] {msg} @ {t:.1f} ns")

    # the replay --------------------------------------------------------------

    def check(self, events: Sequence[TimedCommand]) -> List[TimingViolation]:
        p = self.params
        out: List[TimingViolation] = []
        banks: Dict[int, _BankState] = {}
        rank_acts: deque = deque(maxlen=4)  # rank-level tFAW window

        for ev in sorted(events, key=lambda e: e.t_ns):
            st = banks.setdefault(ev.bank, _BankState())
            t = ev.t_ns
            if self.check_refresh and self._in_refresh_window(t):
                out.append(self._viol(
                    "refresh", ev.bank, t,
                    f"{ev.kind} issued inside a refresh window "
                    f"(tREFI={p.tREFI:g}, tRFC={p.tRFC:g})"))
            if ev.kind == "ACT":
                if st.open_since is not None:
                    if (ev.macro_id == st.open_macro
                            and st.acts_in_macro == 1):
                        st.acts_in_macro = 2  # the macro's paired ACT
                    else:
                        out.append(self._viol(
                            "open-bank", ev.bank, t,
                            f"ACT to bank {ev.bank} while row open since "
                            f"{st.open_since:.1f} ns (missing PRECHARGE)"))
                else:
                    if st.last_pre is not None and \
                            t - st.last_pre < self._gap("tRP") - _EPS:
                        out.append(self._viol(
                            "tRP", ev.bank, t,
                            f"ACT {t - st.last_pre:.1f} ns after PRECHARGE "
                            f"(tRP={p.tRP:g})"))
                    if st.last_act is not None and \
                            t - st.last_act < self._gap("tRC") - _EPS:
                        out.append(self._viol(
                            "tRC", ev.bank, t,
                            f"ACT {t - st.last_act:.1f} ns after previous "
                            f"ACT (tRC={p.tRAS + p.tRP:g})"))
                    st.open_since = t
                    st.open_macro = ev.macro_id
                    st.acts_in_macro = 1
                    st.last_act = t
                    st.last_wr = None
                if len(rank_acts) == 4 and \
                        t - rank_acts[0] < self._gap("tFAW") - _EPS:
                    out.append(self._viol(
                        "tFAW", ev.bank, t,
                        f"5th ACT across the rank only "
                        f"{t - rank_acts[0]:.1f} ns after the 4th-previous "
                        f"(tFAW={p.tFAW:g})"))
                rank_acts.append(t)
            elif ev.kind == "WR":
                if st.open_since is None:
                    out.append(self._viol(
                        "open-bank", ev.bank, t,
                        f"column WRITE to bank {ev.bank} with no open row"))
                else:
                    if t - st.open_since < self._gap("tRCD") - _EPS:
                        out.append(self._viol(
                            "tRCD", ev.bank, t,
                            f"WRITE {t - st.open_since:.1f} ns after ACT "
                            f"(tRCD={p.tRCD:g})"))
                    st.last_wr = t
            elif ev.kind == "PRE":
                if st.open_since is not None:
                    if t - st.open_since < self._gap("tRAS") - _EPS:
                        out.append(self._viol(
                            "tRAS", ev.bank, t,
                            f"PRECHARGE {t - st.open_since:.1f} ns after "
                            f"ACT (tRAS={p.tRAS:g})"))
                    if st.last_wr is not None and \
                            t - st.last_wr < self._gap("tWR") - _EPS:
                        out.append(self._viol(
                            "tWR", ev.bank, t,
                            f"PRECHARGE {t - st.last_wr:.1f} ns after "
                            f"WRITE (tWR={p.tWR:g})"))
                # PRE to an idle bank is a harmless no-op, as on real DDR.
                st.open_since = None
                st.open_macro = None
                st.acts_in_macro = 0
                st.last_pre = t
                st.last_wr = None
            else:
                raise ValueError(f"unknown command kind {ev.kind!r}")

        for b in sorted(banks):
            st = banks[b]
            if st.open_since is not None:
                out.append(self._viol(
                    "open-bank", b, st.open_since,
                    f"stream ends with bank {b} still activated "
                    "(missing final PRECHARGE)"))
        return out

    def verify_program(self, prog: Sequence[Macro], bank: int = 0,
                       start_ns: float = 0.0,
                       refresh_aware: bool = True) -> List[TimedCommand]:
        """Schedule + check; raises TimingViolationError on any violation,
        returns the legal timed stream otherwise."""
        events = schedule_program(prog, self.params, bank=bank,
                                  start_ns=start_ns,
                                  refresh_aware=refresh_aware)
        violations = self.check(events)
        if violations:
            raise TimingViolationError(violations)
        return events


# -- the CI oracle: canonical programs ---------------------------------------


def _rand_expr(rng, depth: int = 0):
    """Small deterministic expression generator (mirrors the compiler's
    property tests) so the oracle covers optimizer-shaped streams, not
    just the hand-written templates."""
    from . import expr as E
    names = ["a", "b", "c", "d"]
    if depth >= 3 or rng.random() < 0.3:
        e = E.Expr.var(names[int(rng.integers(len(names)))])
        return ~e if rng.random() < 0.3 else e
    k = rng.random()
    if k < 0.25:
        return ~_rand_expr(rng, depth + 1)
    if k < 0.45:
        return E.maj(_rand_expr(rng, depth + 1), _rand_expr(rng, depth + 1),
                     _rand_expr(rng, depth + 1))
    op = ["__and__", "__or__", "__xor__"][int(rng.integers(3))]
    return getattr(_rand_expr(rng, depth + 1), op)(
        _rand_expr(rng, depth + 1))


def canonical_programs(n_random: int = 24) -> List[Tuple[str, Sequence[Macro]]]:
    """The oracle's program set: every Figure-20 template at canonical
    addresses plus deterministic random expressions through the compiler,
    optimized and naive."""
    import numpy as np

    from .compiler import compile_expr

    progs: List[Tuple[str, Sequence[Macro]]] = []
    for op in sorted(OP_TEMPLATES):
        args = [D(i) for i in range(OP_ARITY[op])]
        progs.append((f"fig20:{op}", tuple(OP_TEMPLATES[op](*args))))
    var_rows = {"a": 0, "b": 1, "c": 2, "d": 3}
    for i in range(n_random):
        expr = _rand_expr(np.random.default_rng(3000 + i))
        for optimize in (False, True):
            cp = compile_expr(expr, var_rows, dst_row=4, optimize=optimize)
            tag = "opt" if optimize else "naive"
            progs.append((f"compile[{tag}]:{i}", cp.program))
    return progs


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Verify canonical Ambit command streams against the "
                    "DRAM timing-rule table.")
    ap.add_argument("--n-random", type=int, default=24,
                    help="random compiled expressions per optimize mode")
    ap.add_argument("--psm-lines", type=int, default=128,
                    help="cache lines in the PSM-copy stream (128 = 8KB row)")
    args = ap.parse_args(argv)

    checker = TimingChecker()
    n_cmds = 0
    failed: List[Tuple[str, List[TimingViolation]]] = []
    progs = canonical_programs(args.n_random)
    for name, prog in progs:
        events = schedule_program(prog)
        n_cmds += len(events)
        violations = checker.check(events)
        if violations:
            failed.append((name, violations))
    psm = schedule_psm_copy(args.psm_lines)
    n_cmds += len(psm)
    v = checker.check(psm)
    if v:
        failed.append((f"psm:{args.psm_lines}", v))

    total = len(progs) + 1
    if failed:
        print(f"timing-oracle: {len(failed)}/{total} streams ILLEGAL")
        for name, violations in failed:
            for viol in violations:
                print(f"  {name}: {viol.message}")
        return 1
    print(f"timing-oracle: {total} streams, {n_cmds} commands, "
          f"0 violations against {len(RULES)} rules "
          f"({', '.join(r.name for r in RULES)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
