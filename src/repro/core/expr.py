"""Bitwise expression DAG used by the Ambit compiler and the engine API.

Expressions are hash-consed (CSE falls out of construction) and support
operator overloading:  (a & b) | ~c,  a ^ b,  maj(a, b, c).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

_INTERN: Dict[Tuple, "Expr"] = {}


class Expr:
    """Immutable, interned expression node."""

    op: str
    args: Tuple["Expr", ...]
    name: str  # for Var/Lit

    def __new__(cls, op: str, args: Tuple["Expr", ...] = (), name: str = ""):
        key = (op, tuple(id(a) for a in args), name)
        node = _INTERN.get(key)
        if node is None:
            node = object.__new__(cls)
            node.op = op
            node.args = args
            node.name = name
            _INTERN[key] = node
        return node

    # -- constructors --------------------------------------------------------

    @staticmethod
    def var(name: str) -> "Expr":
        return Expr("var", (), name)

    @staticmethod
    def lit(value: int) -> "Expr":
        return Expr("lit", (), "one" if value else "zero")

    # -- operators -----------------------------------------------------------

    def __and__(self, o: "Expr") -> "Expr":
        return _fold(Expr("and", (self, o)))

    def __or__(self, o: "Expr") -> "Expr":
        return _fold(Expr("or", (self, o)))

    def __xor__(self, o: "Expr") -> "Expr":
        return _fold(Expr("xor", (self, o)))

    def __invert__(self) -> "Expr":
        return _fold(Expr("not", (self,)))

    def __repr__(self):
        if self.op in ("var", "lit"):
            return self.name
        if self.op == "not":
            return f"~{self.args[0]!r}"
        return f"({self.op} " + " ".join(map(repr, self.args)) + ")"


def maj(a: Expr, b: Expr, c: Expr) -> Expr:
    return _fold(Expr("maj", (a, b, c)))


ZERO = Expr.lit(0)
ONE = Expr.lit(1)


def _fold(e: Expr) -> Expr:
    """Constant folding + double-negation elimination + fused-negation
    strength reduction (and->nand etc. happens in the compiler; here we only
    simplify algebraically)."""
    a = e.args
    if e.op == "not":
        (x,) = a
        if x.op == "not":
            return x.args[0]
        if x is ZERO:
            return ONE
        if x is ONE:
            return ZERO
        return e
    if e.op == "and":
        x, y = a
        if x is y:
            return x
        if ZERO in a:
            return ZERO
        if x is ONE:
            return y
        if y is ONE:
            return x
        return e
    if e.op == "or":
        x, y = a
        if x is y:
            return x
        if ONE in a:
            return ONE
        if x is ZERO:
            return y
        if y is ZERO:
            return x
        return e
    if e.op == "xor":
        x, y = a
        if x is y:
            return ZERO
        if x is ZERO:
            return y
        if y is ZERO:
            return x
        if x is ONE:
            return ~y
        if y is ONE:
            return ~x
        return e
    if e.op == "maj":
        x, y, c = a
        if c is ZERO:
            return x & y
        if c is ONE:
            return x | y
        if x is y:
            return x
        return e
    return e


def eval_expr(e: Expr, env: Dict[str, np.ndarray]) -> np.ndarray:
    """Pure-numpy oracle over packed uint64/uint32 arrays."""
    if e.op == "var":
        return env[e.name]
    if e.op == "lit":
        some = next(iter(env.values()))
        zero = some ^ some  # dtype-generic, works for numpy and traced jax
        return ~zero if e.name == "one" else zero
    vals = [eval_expr(x, env) for x in e.args]
    if e.op == "not":
        return ~vals[0]
    if e.op == "and":
        return vals[0] & vals[1]
    if e.op == "or":
        return vals[0] | vals[1]
    if e.op == "xor":
        return vals[0] ^ vals[1]
    if e.op == "maj":
        x, y, z = vals
        return (x & y) | (y & z) | (z & x)
    raise KeyError(e.op)


def topo_order(root: Expr):
    """Post-order DAG traversal (each node once)."""
    seen, out = set(), []

    def visit(n: Expr):
        if id(n) in seen:
            return
        seen.add(id(n))
        for x in n.args:
            visit(x)
        out.append(n)

    visit(root)
    return out


def consumer_counts(root: Expr) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in topo_order(root):
        for x in n.args:
            counts[id(x)] = counts.get(id(x), 0) + 1
    counts.setdefault(id(root), 0)
    counts[id(root)] += 1  # the output itself is consumed
    return counts
