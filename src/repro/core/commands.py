"""Ambit command IR: row addresses, the Table-2 B-group mapping, and the
AAP/AP macro primitives with the Figure-20 operation templates.

Address spaces (Section 4.1):
  * B-group: B0..B15  -> reserved addresses that activate 1, 2 or 3 wordlines
    of the designated rows (T0..T3) and the dual-contact-cell rows.
  * C-group: C0 (all zeros), C1 (all ones).
  * D-group: D0..D<n> data rows.

Wordlines: "T0".."T3" are ordinary cells. Each DCC row has a d-wordline
("DCC0"/"DCC1": capacitor <-> bitline) and an n-wordline ("DCC0N"/"DCC1N":
capacitor <-> bitline-bar), per Section 3.2.

Macro timing/energy is a pure function of each macro's address *groups*
(B/C/D), never of concrete D-row indices - which is what lets the batched
simulator account a whole row batch by scaling per-macro costs
(CommandStats.add_macro(..., rows=n)) and lets the device dispatcher run
one canonical-address template for a group of row slots.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple, Union

# ---------------------------------------------------------------------------
# Wordline names
# ---------------------------------------------------------------------------

T_WORDLINES = ("T0", "T1", "T2", "T3")
DCC_D_WORDLINES = ("DCC0", "DCC1")
DCC_N_WORDLINES = ("DCC0N", "DCC1N")
ALL_B_WORDLINES = T_WORDLINES + DCC_D_WORDLINES + DCC_N_WORDLINES


def is_n_wordline(wl: str) -> bool:
    return wl.endswith("N")


def dcc_capacitor(wl: str) -> str:
    """Capacitor name backing a DCC wordline ("DCC0N" -> "DCC0")."""
    return wl[:-1] if wl.endswith("N") else wl


# ---------------------------------------------------------------------------
# Row addresses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowAddr:
    group: str  # "B" | "C" | "D"
    index: int

    def __post_init__(self):
        if self.group not in ("B", "C", "D"):
            raise ValueError(f"bad group {self.group}")
        if self.group == "B" and not (0 <= self.index < 16):
            raise ValueError("B-group has addresses B0..B15")
        if self.group == "C" and self.index not in (0, 1):
            raise ValueError("C-group has addresses C0, C1")
        if self.index < 0:
            raise ValueError("negative row index")

    def __repr__(self):
        return f"{self.group}{self.index}"


def B(i: int) -> RowAddr:
    return RowAddr("B", i)


def C(i: int) -> RowAddr:
    return RowAddr("C", i)


def D(i: int) -> RowAddr:
    return RowAddr("D", i)


# Table 2: mapping of B-group addresses to activated wordlines.
B_GROUP_WORDLINES: dict[int, Tuple[str, ...]] = {
    0: ("T0",),
    1: ("T1",),
    2: ("T2",),
    3: ("T3",),
    4: ("DCC0",),
    5: ("DCC0N",),
    6: ("DCC1",),
    7: ("DCC1N",),
    8: ("DCC0N", "T0"),
    9: ("DCC1N", "T1"),
    10: ("T2", "T3"),
    11: ("T0", "T3"),
    12: ("T0", "T1", "T2"),
    13: ("T1", "T2", "T3"),
    14: ("DCC0", "T1", "T2"),
    15: ("DCC1", "T0", "T3"),
}


def wordlines_for(addr: RowAddr) -> Tuple[str, ...]:
    """Wordlines raised by an ACTIVATE to `addr` (B-group fan-out per Table 2)."""
    if addr.group == "B":
        return B_GROUP_WORDLINES[addr.index]
    return (repr(addr),)  # C/D rows raise their own single wordline


def num_wordlines(addr: RowAddr) -> int:
    return len(wordlines_for(addr))


# ---------------------------------------------------------------------------
# Commands
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Activate:
    addr: RowAddr

    def __repr__(self):
        return f"ACTIVATE {self.addr!r}"


@dataclasses.dataclass(frozen=True)
class Precharge:
    def __repr__(self):
        return "PRECHARGE"


Command = Union[Activate, Precharge]


@dataclasses.dataclass(frozen=True)
class AAP:
    """ACTIVATE-ACTIVATE-PRECHARGE (Section 4.2).

    Copies the result of activating `src` into the row(s) mapped to `dst`.
    """

    src: RowAddr
    dst: RowAddr

    def expand(self) -> List[Command]:
        return [Activate(self.src), Activate(self.dst), Precharge()]

    def __repr__(self):
        return f"AAP({self.src!r}, {self.dst!r})"


@dataclasses.dataclass(frozen=True)
class AP:
    """ACTIVATE-PRECHARGE (Section 4.2)."""

    addr: RowAddr

    def expand(self) -> List[Command]:
        return [Activate(self.addr), Precharge()]

    def __repr__(self):
        return f"AP({self.addr!r})"


Macro = Union[AAP, AP]


def expand_program(prog: Sequence[Macro]) -> List[Command]:
    out: List[Command] = []
    for m in prog:
        out.extend(m.expand())
    return out


# ---------------------------------------------------------------------------
# Figure 20 operation templates
# ---------------------------------------------------------------------------
# Each template returns the macro program computing dst = op(srcs...).
# Comments mirror Figure 20's annotations.


def seq_not(di: RowAddr, dk: RowAddr) -> List[Macro]:
    return [
        AAP(di, B(5)),   # DCC0 = !Di   (n-wordline capture, Fig. 18)
        AAP(B(4), dk),   # Dk   = DCC0
    ]


def seq_and(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    return [
        AAP(di, B(0)),    # T0 = Di
        AAP(dj, B(1)),    # T1 = Dj
        AAP(C(0), B(2)),  # T2 = 0
        AAP(B(12), dk),   # Dk = MAJ(T0,T1,0) = T0 & T1
    ]


def seq_or(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    return [
        AAP(di, B(0)),    # T0 = Di
        AAP(dj, B(1)),    # T1 = Dj
        AAP(C(1), B(2)),  # T2 = 1
        AAP(B(12), dk),   # Dk = MAJ(T0,T1,1) = T0 | T1
    ]


def seq_nand(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    return [
        AAP(di, B(0)),     # T0 = Di
        AAP(dj, B(1)),     # T1 = Dj
        AAP(C(0), B(2)),   # T2 = 0
        AAP(B(12), B(5)),  # DCC0 = !(T0 & T1)
        AAP(B(4), dk),     # Dk = DCC0
    ]


def seq_nor(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    return [
        AAP(di, B(0)),     # T0 = Di
        AAP(dj, B(1)),     # T1 = Dj
        AAP(C(1), B(2)),   # T2 = 1
        AAP(B(12), B(5)),  # DCC0 = !(T0 | T1)
        AAP(B(4), dk),     # Dk = DCC0
    ]


def seq_xor(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    # Dk = (Di & !Dj) | (!Di & Dj)   (Figure 20c)
    return [
        AAP(di, B(8)),    # DCC0 = !Di, T0 = Di
        AAP(dj, B(9)),    # DCC1 = !Dj, T1 = Dj
        AAP(C(0), B(10)),  # T2 = T3 = 0
        AP(B(14)),        # T1 = DCC0 & T1   (TRA DCC0,T1,T2)
        AP(B(15)),        # T0 = DCC1 & T0   (TRA DCC1,T0,T3)
        AAP(C(1), B(2)),  # T2 = 1
        AAP(B(12), dk),   # Dk = T0 | T1
    ]


def seq_xnor(di: RowAddr, dj: RowAddr, dk: RowAddr) -> List[Macro]:
    """Dk = !(Di xor Dj): the xor skeleton with the final combine routed
    through DCC0's n-wordline (the same negate-on-output trick nand uses).
    By the final step both DCC capacitors have been consumed as xor
    intermediates, so DCC0 is free to capture the negated combine."""
    return [
        AAP(di, B(8)),    # DCC0 = !Di, T0 = Di
        AAP(dj, B(9)),    # DCC1 = !Dj, T1 = Dj
        AAP(C(0), B(10)),  # T2 = T3 = 0
        AP(B(14)),        # T1 = DCC0 & T1 = !Di & Dj
        AP(B(15)),        # T0 = DCC1 & T0 = Di & !Dj
        AAP(C(1), B(2)),  # T2 = 1
        AAP(B(12), B(5)),  # DCC0 = !(T0 | T1) = xnor
        AAP(B(4), dk),    # Dk = DCC0
    ]


def seq_maj3(di: RowAddr, dj: RowAddr, dl: RowAddr, dk: RowAddr) -> List[Macro]:
    """Dk = MAJ(Di, Dj, Dl) - the raw TRA primitive exposed (Section 3.1.1)."""
    return [
        AAP(di, B(0)),   # T0 = Di
        AAP(dj, B(1)),   # T1 = Dj
        AAP(dl, B(2)),   # T2 = Dl
        AAP(B(12), dk),  # Dk = MAJ(T0,T1,T2)
    ]


def seq_copy(di: RowAddr, dk: RowAddr) -> List[Macro]:
    """RowClone-FPM: two back-to-back ACTIVATEs + PRECHARGE (Section 2.4)."""
    return [AAP(di, dk)]


def seq_zero(dk: RowAddr) -> List[Macro]:
    """Bulk initialization to zero via the C0 control row (Section 3.1.4)."""
    return [AAP(C(0), dk)]


def seq_one(dk: RowAddr) -> List[Macro]:
    return [AAP(C(1), dk)]


# Canonical op table used by the compiler and the energy/timing benchmarks.
OP_TEMPLATES = {
    "not": seq_not,
    "and": seq_and,
    "or": seq_or,
    "nand": seq_nand,
    "nor": seq_nor,
    "xor": seq_xor,
    "xnor": seq_xnor,
    "maj3": seq_maj3,
    "copy": seq_copy,
    "zero": seq_zero,
    "one": seq_one,
}

# Total row-address argument count per template (sources + destination).
# Shared by the timing model and the differential tests so per-op argument
# plumbing stays in one place.
OP_ARITY = {
    "not": 2, "and": 3, "or": 3, "nand": 3, "nor": 3, "xor": 3, "xnor": 3,
    "maj3": 4, "copy": 2, "zero": 1, "one": 1,
}
