"""DRAM geometry for the Ambit device model.

Mirrors the organization described in Section 2 of the paper:
chips contain banks; banks contain subarrays; each subarray is a 2-D array of
cells (rows x row_bits) sharing one row of sense amplifiers (the row buffer).

Ambit reserves, per subarray (Section 4.1):
  * B-group: 4 designated rows T0..T3 + 2 dual-contact-cell rows (DCC0, DCC1),
    addressed through 16 reserved addresses B0..B15 (Table 2).
  * C-group: 2 control rows, C0 = all zeros, C1 = all ones.
  * D-group: the remaining rows, exposed to software as data rows.
"""

from __future__ import annotations

import dataclasses

WORD_BITS = 64  # simulator packing width (numpy uint64)


@dataclasses.dataclass(frozen=True)
class DRAMGeometry:
    """Geometry constants for the modeled DDR3-style device (Table 5-like)."""

    row_bytes: int = 8192          # 8 KB row (Table 5: "8 KB row size")
    rows_per_subarray: int = 1024  # typical MAT height (Section 2.2.3)
    subarrays_per_bank: int = 32   # 2Gb chip: 2^15 rows/bank / 1024
    banks: int = 8                 # Ambit config in Fig. 21 uses 8 banks
    dcc_rows: int = 2              # DCC0, DCC1 (Section 4.1)
    designated_rows: int = 4       # T0..T3
    control_rows: int = 2          # C0, C1

    @property
    def row_bits(self) -> int:
        return self.row_bytes * 8

    @property
    def row_words(self) -> int:
        """Packed uint64 words per row (simulator storage unit)."""
        return self.row_bits // WORD_BITS

    @property
    def reserved_rows(self) -> int:
        # Each DCC row costs ~2 regular rows of area (Section 5.6.1), but in
        # terms of *addressable* rows the B+C groups remove 4 + 2 + 2 = 8
        # row addresses; the paper quotes D0..D1005 for 1024-row subarrays,
        # i.e. 18 addresses reserved (16 B-group + 2 C-group).
        return 16 + self.control_rows

    @property
    def data_rows(self) -> int:
        """D-group rows exposed to software (paper: 1006 for 1024 rows)."""
        return self.rows_per_subarray - self.reserved_rows

    @property
    def subarray_data_bytes(self) -> int:
        return self.data_rows * self.row_bytes

    @property
    def bank_data_bytes(self) -> int:
        return self.subarrays_per_bank * self.subarray_data_bytes

    @property
    def chip_data_bytes(self) -> int:
        return self.banks * self.bank_data_bytes


DEFAULT_GEOMETRY = DRAMGeometry()
