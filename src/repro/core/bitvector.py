"""Packed bitvector container used throughout the framework.

Bits are packed little-endian-within-word into uint32 lanes (32x denser than
bool tensors; the TPU analogue of Ambit's 65,536-bit DRAM row operands).
The trailing dimension is padded to a multiple of LANE_WORDS (128) so tiles
are VREG-aligned on TPU, mirroring the paper's requirement that bbop sizes
are multiples of the DRAM row size (Section 5.1/5.3) - residues are padded
with zeros exactly as the paper prescribes ("pad with dummy data").
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32
LANE_WORDS = 128  # pad packed words to a multiple of one VREG lane row

Array = Union[np.ndarray, jax.Array]


def padded_words(n_bits: int) -> int:
    words = (n_bits + WORD - 1) // WORD
    return ((words + LANE_WORDS - 1) // LANE_WORDS) * LANE_WORDS


def pack_bits(bits: Array) -> jnp.ndarray:
    """bool (..., n) -> packed uint32 (..., padded_words(n)). Bit i of word w
    holds element w*32+i (little-endian within word)."""
    bits = jnp.asarray(bits, jnp.uint32)
    n = bits.shape[-1]
    words = padded_words(n)
    pad = words * WORD - n
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (words, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(-1, dtype=jnp.uint32)


def unpack_bits(words: Array, n_bits: Optional[int] = None) -> jnp.ndarray:
    """packed uint32 (..., w) -> bool (..., n_bits or w*32)."""
    words = jnp.asarray(words, jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    if n_bits is not None:
        bits = bits[..., :n_bits]
    return bits.astype(jnp.bool_)


@dataclasses.dataclass
class BitVector:
    """A logical n_bits-long bitvector stored packed. Rows dimension allows
    batches of bitvectors ((rows, words) layout = rows of an Ambit subarray).
    """

    data: jnp.ndarray  # uint32, (..., words)
    n_bits: int

    @staticmethod
    def from_bits(bits: Array) -> "BitVector":
        bits = jnp.asarray(bits)
        return BitVector(pack_bits(bits), bits.shape[-1])

    @staticmethod
    def zeros(n_bits: int, rows: tuple = ()) -> "BitVector":
        return BitVector(
            jnp.zeros(rows + (padded_words(n_bits),), jnp.uint32), n_bits)

    @staticmethod
    def ones(n_bits: int, rows: tuple = ()) -> "BitVector":
        words = padded_words(n_bits)
        data = jnp.full(rows + (words,), 0xFFFFFFFF, jnp.uint32)
        return BitVector(_mask_tail(data, n_bits), n_bits)

    def bits(self) -> jnp.ndarray:
        return unpack_bits(self.data, self.n_bits)

    @property
    def words(self) -> int:
        return self.data.shape[-1]

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.data.shape)) * 4

    def popcount(self) -> jnp.ndarray:
        return jax.lax.population_count(self.data).sum(-1).astype(jnp.int32)

    def __and__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.data & o.data, self.n_bits)

    def __or__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.data | o.data, self.n_bits)

    def __xor__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.data ^ o.data, self.n_bits)

    def __invert__(self) -> "BitVector":
        return BitVector(_mask_tail(~self.data, self.n_bits), self.n_bits)

    def andnot(self, o: "BitVector") -> "BitVector":
        """self & ~other (set difference)."""
        return BitVector(self.data & ~o.data, self.n_bits)


def _mask_tail(data: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Zero the padding bits beyond n_bits (keeps popcounts exact)."""
    words = data.shape[-1]
    full_words = n_bits // WORD
    rem = n_bits % WORD
    idx = jnp.arange(words, dtype=jnp.uint32)
    word_mask = jnp.where(
        idx < full_words, jnp.uint32(0xFFFFFFFF),
        jnp.where(idx == full_words,
                  jnp.uint32((1 << rem) - 1 if rem else 0), jnp.uint32(0)))
    return data & word_mask
