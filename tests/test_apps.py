"""Paper application workloads: bitmap index, BitWeaving scans, bitvector
sets, BitFunnel filtering, masked init - engine results vs plain numpy."""

import numpy as np
import pytest

from repro.core import BitVector, BulkBitwiseEngine

RNG = np.random.default_rng(0)


@pytest.fixture(params=["jnp", "ambit_sim"])
def engine(request):
    return BulkBitwiseEngine(request.param)


def test_bitmap_index_query(engine):
    from repro.apps.bitmap_index import BitmapIndex
    n = 3000 if engine.backend == "ambit_sim" else 100_000
    idx = BitmapIndex(n, engine)
    weeks = {}
    for w in range(3):
        members = RNG.choice(n, n // 3, replace=False)
        weeks[f"w{w}"] = set(members.tolist())
        idx.add(f"w{w}", members)
    male = RNG.choice(n, n // 2, replace=False)
    idx.add("male", male)
    uniq, per_week, stats = idx.weekly_active_query(list(weeks), "male")
    expect_uniq = len(set.intersection(*weeks.values()))
    assert uniq == expect_uniq
    male_set = set(male.tolist())
    for i, w in enumerate(weeks):
        assert per_week[i] == len(weeks[w] & male_set)
    if engine.backend == "ambit_sim":
        assert stats.ns > 0 and stats.energy_nj > 0


def test_bitmap_weekly_query_batches_one_drain():
    """The resident weekly_active_query submits the AND-of-weeks root and
    every per-week AND as ONE multi-root batch: the scheduler ledger
    shows a single drain of weeks+1 queries instead of one eval per week,
    and the answers still match the host path exactly."""
    from repro.apps.bitmap_index import BitmapIndex
    from repro.core import DRAMGeometry
    from repro.pim import AmbitRuntime

    rng = np.random.default_rng(31)
    n_users = 1200
    weeks = [f"w{i}" for i in range(4)]
    host = BitmapIndex(n_users, BulkBitwiseEngine("jnp"))
    rt = AmbitRuntime(DRAMGeometry(rows_per_subarray=32), banks=4,
                      subarrays=2, words=2, scratch_rows=2, seed=13)
    res = BitmapIndex(n_users, runtime=rt)
    for w in weeks + ["male"]:
        members = rng.choice(n_users, n_users // 3, replace=False)
        host.add(w, members)
        res.add(w, members)
    want_u, want_pw, _ = host.weekly_active_query(weeks, "male")
    got_u, got_pw, stats = res.weekly_active_query(weeks, "male")
    assert (got_u, got_pw) == (want_u, want_pw)
    assert rt.scheduler.drains == 1              # one drain, not w evals
    assert rt.last_drain.n_queries == len(weeks) + 1
    assert rt.last_drain.stats.ns <= rt.last_drain.serial_ns + 1e-9
    assert stats.ns > 0 and stats.energy_nj > 0


def test_bitweaving_column_scan():
    from repro.apps.bitweaving_db import BitWeavingColumn
    vals = RNG.integers(0, 2**10, 5000).astype(np.uint32)
    col = BitWeavingColumn.from_values(vals, 10)
    for (c1, c2) in ((0, 1023), (100, 100), (256, 700)):
        assert col.count_between(c1, c2) == col.oracle_count(vals, c1, c2)


def test_bitsets_match_numpy(engine):
    from repro.apps.bitsets import BitSetOps, SortedSetOps
    domain = 2048 if engine.backend == "ambit_sim" else 65536
    bs = BitSetOps(domain, engine)
    arrs = [np.sort(RNG.choice(domain, 200, replace=False))
            for _ in range(4)]
    sets = [bs.make(a) for a in arrs]
    got_u = np.nonzero(np.asarray(bs.union(sets).bits()))[0]
    got_i = np.nonzero(np.asarray(bs.intersection(sets).bits()))[0]
    got_d = np.nonzero(np.asarray(
        bs.difference(sets[0], sets[1:]).bits()))[0]
    assert np.array_equal(got_u, SortedSetOps.union(arrs))
    assert np.array_equal(got_i, SortedSetOps.intersection(arrs))
    assert np.array_equal(got_d, SortedSetOps.difference(arrs[0], arrs[1:]))


def test_bitfunnel_no_false_negatives():
    from repro.apps.bitfunnel import BitFunnelIndex
    docs = {0: ["apple", "banana"], 1: ["banana", "cherry"],
            2: ["apple", "cherry", "date"], 3: ["elderberry"]}
    idx = BitFunnelIndex(n_docs=4, filter_bits=256)
    for d, terms in docs.items():
        idx.add_document(d, terms)
    for query, must in ((["apple"], {0, 2}), (["banana"], {0, 1}),
                        (["apple", "cherry"], {2})):
        got = set(idx.query(query).tolist())
        assert must <= got  # Bloom: supersets allowed, no false negatives


def test_masked_init(engine):
    from repro.apps.masked_init import masked_clear, masked_set
    n = 1000
    x = BitVector.from_bits(RNG.integers(0, 2, n).astype(bool))
    m = BitVector.from_bits(RNG.integers(0, 2, n).astype(bool))
    xs = np.asarray(masked_set(engine, x, m).bits())
    xc = np.asarray(masked_clear(engine, x, m).bits())
    xb = np.asarray(x.bits())
    mb = np.asarray(m.bits())
    assert np.array_equal(xs, xb | mb)
    assert np.array_equal(xc, xb & ~mb)


def test_data_pipeline_bitweaving_filter():
    from repro.data.pipeline import filter_documents, synth_corpus_meta
    meta = synth_corpus_meta(2048, seed=1)
    mask = filter_documents(meta, 64, 200, 1000)
    expect = ((meta.quality >= 64) & (meta.quality <= 200) &
              (meta.length >= 1000))
    assert np.array_equal(mask, expect)


def test_data_pipeline_resume_determinism():
    from repro.data.pipeline import DataConfig, SyntheticLM
    data = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=4))
    a = data.batch_at(7)
    b = data.batch_at(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    # shards partition the batch deterministically
    s0 = data.batch_at(7, shard=0, n_shards=2)
    assert s0["tokens"].shape[0] == 2
