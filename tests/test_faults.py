"""Fault injection + reliability layer: differential recovery suite.

The reliability contract is that faults change WHEN and WHERE work
happens, never WHAT a surviving query computes:

  * the injector is a pure function of ``(seed, structural key)`` - two
    runs with one seed produce byte-identical fault/recovery ledgers,
    independent of ``PYTHONHASHSEED`` (the CI chaos job re-runs this
    shard and diffs the recorded ledgers across hash seeds);
  * stuck-row faults are detected positionally, the scheduler retries
    with re-placement, and the faulty rows are quarantined in the
    allocator - results stay bit-identical to a fault-free run and the
    allocator leaks nothing;
  * TMR-protected queries survive silent corruption (weak cells,
    transient flips) and single-device loss - including loss of planes
    holding *dirty* results, rebuilt from surviving siblings - while
    unprotected queries on a failed device degrade to a host fallback
    through the serving frontend instead of crashing the drain;
  * every retry, scrub, parity check and quarantine is billed work:
    the fault-run ledgers dominate the fault-free ledgers and the
    per-ticket accounting still reconciles with the runtime totals.
"""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import AmbitError, BitVector, Expr
from repro.core.ecc import TMRCodec
from repro.pim import AmbitRuntime
from repro.pim.faults import (FaultConfig, FaultError, FaultInjector,
                              ReliabilityManager)
from repro.serve import QueryFrontend, TenantQuota

X, Y = Expr.var("x"), Expr.var("y")


def _bits(rng, n=512):
    return BitVector.from_bits(jnp.asarray(
        rng.integers(0, 2, n, dtype=np.uint8)))


def _rt(devices=1, injector=None, **kw):
    kw.setdefault("banks", 4)
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    return AmbitRuntime(devices=devices, fault_injector=injector, **kw)


def _mix(rng, k, n_ops):
    """Deterministic little query mix over ``n_ops`` operand names."""
    i, j = int(k % n_ops), int((k + 1 + k // n_ops) % n_ops)
    expr = [X ^ Y, X & Y, X | Y, (X & Y) ^ X][k % 4]
    return expr, i, j


# -- satellite: TMR encode aliasing -------------------------------------------


def test_tmr_encode_replicas_are_independent():
    from repro.core.engine import BulkBitwiseEngine
    rng = np.random.default_rng(0)
    bv = _bits(rng)
    codec = TMRCodec(BulkBitwiseEngine(backend="jnp"))
    reps = codec.encode(bv)
    assert len(reps) == 3
    # three distinct handles over three distinct buffers: scrubbing one
    # plane must never silently rewrite its siblings
    assert len({id(r) for r in reps}) == 3
    assert len({id(r.data) for r in reps}) == 3
    for r in reps:
        assert bool((np.asarray(r.data) == np.asarray(bv.data)).all())
    dec = codec.decode(reps)
    assert bool((np.asarray(dec.data) == np.asarray(bv.data)).all())


# -- determinism --------------------------------------------------------------


def _chaos_session(seed):
    """One seeded faulty session; returns (results, fault ledger)."""
    rng = np.random.default_rng(7)
    vs = [_bits(rng) for _ in range(4)]
    inj = FaultInjector(FaultConfig(seed=seed, stuck_row_rate=0.25,
                                    transient_rate=0.01,
                                    weak_bit_rate=1e-4))
    rt = _rt(injector=inj)
    rt.reliability.max_retries = 16
    hs = [rt.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs[:2]]
    out = []
    for k in range(6):
        expr, i, j = _mix(rng, k, 4)
        r = rt.eval(expr, {"x": hs[i], "y": hs[j]})
        out.append(np.asarray(rt.get(r).data).copy())
        rt.free(r)
    r = rt.xor(hp[0], hp[1])
    out.append(np.asarray(rt.get(r).data).copy())
    return out, inj.ledger()


def test_fault_ledger_is_seed_deterministic(record_ledger):
    out_a, led_a = _chaos_session(3)
    out_b, led_b = _chaos_session(3)
    assert led_a == led_b
    assert led_a                          # the session actually faulted
    for a, b in zip(out_a, out_b):
        assert bool((a == b).all())
    _, led_c = _chaos_session(4)
    assert led_c != led_a                 # the seed is load-bearing
    # recorded for the CI chaos job: byte-diffed across PYTHONHASHSEED
    record_ledger("fault_ledger_seed3", led_a)


def test_injector_sampling_ignores_hash_seed():
    # structural RNG keys only - never hash() - so the sampled fault
    # universe is a pure function of the config seed
    inj = FaultInjector(FaultConfig(seed=9, stuck_row_rate=0.1,
                                    weak_bit_rate=1e-3))
    inj.bind(data_rows=64)
    stuck = [(b, s, r) for b in range(4) for s in range(2)
             for r in range(32) if inj.row_stuck(0, (b, s, r))]
    masks = {slot: inj.weak_mask(0, slot, 2) for slot in stuck}
    inj2 = FaultInjector(FaultConfig(seed=9, stuck_row_rate=0.1,
                                     weak_bit_rate=1e-3))
    inj2.bind(data_rows=64)
    assert stuck == [(b, s, r) for b in range(4) for s in range(2)
                     for r in range(32) if inj2.row_stuck(0, (b, s, r))]
    for slot, m in masks.items():
        m2 = inj2.weak_mask(0, slot, 2)
        assert (m is None) == (m2 is None)
        if m is not None:
            assert bool((m == m2).all())


def test_weak_rate_tracks_analog_calibration():
    from repro.core.analog import tra_failure_rate
    cfg = FaultConfig(seed=5, variation=0.15, analog_trials=4000)
    inj = FaultInjector(cfg)
    expect = float(tra_failure_rate(0.15, n_trials=4000, seed=5))
    assert inj.weak_rate == pytest.approx(expect)
    assert inj.weak_rate > 0.0
    # explicit override wins over the calibrated distribution
    inj2 = FaultInjector(FaultConfig(seed=5, variation=0.15,
                                     weak_bit_rate=1e-6))
    assert inj2.weak_rate == 1e-6


# -- stuck rows: retry + quarantine -------------------------------------------


def test_stuck_rows_retry_to_bit_exact_results():
    rng = np.random.default_rng(1)
    vs = [_bits(rng) for _ in range(6)]
    ref = _rt()
    inj = FaultInjector(FaultConfig(seed=3, stuck_row_rate=0.3))
    rt = _rt(injector=inj)
    rt.reliability.max_retries = 16
    hs = [ref.put(v) for v in vs]
    hf = [rt.put(v) for v in vs]
    for k in range(8):
        expr, i, j = _mix(rng, k, 6)
        a = np.asarray(ref.get(ref.eval(expr, {"x": hs[i], "y": hs[j]})).data)
        b = np.asarray(rt.get(rt.eval(expr, {"x": hf[i], "y": hf[j]})).data)
        assert bool((a == b).all())
    counters = rt.metrics.snapshot()["counters"]
    report = rt.allocator.report()
    assert counters["fault_injected{kind=stuck_row}"] > 0
    assert counters["quarantined_rows"] == report["quarantined"]
    assert counters["ticket_retries{reason=stuck_row}"] > 0
    # quarantined rows never come back: re-placement avoids every one
    for slot in report["quarantined_slots"]:
        assert not rt.allocator.is_live(tuple(slot))


def test_quarantine_does_not_leak_rows():
    rng = np.random.default_rng(2)
    inj = FaultInjector(FaultConfig(seed=3, stuck_row_rate=0.3))
    rt = _rt(injector=inj)
    rt.reliability.max_retries = 16
    hs = [rt.put(_bits(rng)) for _ in range(4)]
    outs = [rt.eval(X ^ Y, {"x": hs[k % 4], "y": hs[(k + 1) % 4]})
            for k in range(6)]
    for h in outs + hs:
        rt.free(h)
    report = rt.allocator.report()
    assert report["live"] == 0            # no leaked rows ...
    assert report["quarantined"] > 0      # ... while retired rows stay out
    # capacity already excludes the quarantine set: with nothing live,
    # every remaining row is free
    assert report["free"] == report["capacity"]


def test_retries_exhausted_surface_a_fault_error():
    rng = np.random.default_rng(3)
    inj = FaultInjector(FaultConfig(seed=3, stuck_row_rate=0.9))
    rt = _rt(injector=inj)
    rt.reliability.max_retries = 1
    a, b = rt.put(_bits(rng)), rt.put(_bits(rng))
    with pytest.raises(FaultError):
        for _ in range(12):               # near-certain double fault
            rt.free(rt.eval(X ^ Y, {"x": a, "y": b}))


# -- TMR protection: silent corruption ----------------------------------------


@pytest.mark.parametrize("devices", [1, 4])
def test_protected_queries_bit_exact_under_silent_faults(devices):
    rng = np.random.default_rng(4)
    vs = [_bits(rng, 2048 if devices > 1 else 512) for _ in range(4)]
    ref = _rt(devices=devices)
    inj = FaultInjector(FaultConfig(seed=7, transient_rate=0.02,
                                    weak_bit_rate=1e-4))
    rt = _rt(devices=devices, injector=inj)
    hs = [ref.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs]
    for k in range(10):
        expr, i, j = _mix(rng, k, 4)
        a = np.asarray(ref.get(ref.eval(expr, {"x": hs[i], "y": hs[j]})).data)
        r = rt.eval(expr, {"x": hp[i], "y": hp[j]})
        assert r.protected and len(r.replicas) == 2
        b = np.asarray(rt.get(r).data)
        assert bool((a == b).all())
        rt.free(r)
    counters = rt.metrics.snapshot()["counters"]
    assert counters["protected_queries"] == 10
    assert counters["parity_checks"] >= 10
    if any("transient" in e or "weak" in e for e in inj.events):
        assert counters.get("scrub_corrections", 0) > 0


def test_scrub_is_billed_work():
    rng = np.random.default_rng(5)
    vs = [_bits(rng) for _ in range(2)]
    clean = _rt(injector=FaultInjector(FaultConfig(seed=7)))
    faulty = _rt(injector=FaultInjector(FaultConfig(seed=7,
                                                    transient_rate=0.05)))
    res = {}
    for tag, rt in (("clean", clean), ("faulty", faulty)):
        hp = [rt.put(v, protect=True) for v in vs]
        for _ in range(6):
            rt.free(rt.eval(X ^ Y, {"x": hp[0], "y": hp[1]}))
        res[tag] = rt.session_stats.aap_count
    # MAJ re-votes are native queries on the ledger, not free fixes
    assert res["faulty"] > res["clean"]


# -- device loss --------------------------------------------------------------


def test_device_loss_protected_recovery_from_host_shadow():
    rng = np.random.default_rng(6)
    vs = [_bits(rng, 2048) for _ in range(4)]
    ref = _rt(devices=4)
    inj = FaultInjector(FaultConfig(seed=11))
    rt = _rt(devices=4, injector=inj)
    hs = [ref.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs]
    inj.fail_device(2)
    for k in range(4):
        expr, i, j = _mix(rng, k, 4)
        a = np.asarray(ref.get(ref.eval(expr, {"x": hs[i], "y": hs[j]})).data)
        b = np.asarray(rt.get(rt.eval(expr, {"x": hp[i], "y": hp[j]})).data)
        assert bool((a == b).all())
    counters = rt.metrics.snapshot()["counters"]
    assert counters["devices_lost"] == 1
    assert counters["fault_evacuated_chunks"] > 0
    assert rt.cluster.dead_devices == {2}


def test_device_loss_dirty_plane_rebuilt_from_siblings():
    rng = np.random.default_rng(6)
    vs = [_bits(rng, 2048) for _ in range(3)]
    ref = _rt(devices=4)
    inj = FaultInjector(FaultConfig(seed=11))
    rt = _rt(devices=4, injector=inj)
    hs = [ref.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs]
    r0 = rt.xor(hp[0], hp[1])             # dirty protected result
    a0 = ref.xor(hs[0], hs[1])
    inj.fail_device(1)                    # claims one plane of r0
    got = np.asarray(
        rt.get(rt.eval(X & Y, {"x": r0, "y": hp[2]})).data)
    expect = np.asarray(
        ref.get(ref.eval(X & Y, {"x": a0, "y": hs[2]})).data)
    assert bool((got == expect).all())
    counters = rt.metrics.snapshot()["counters"]
    assert counters["fault_repaired_chunks"] > 0
    assert any("repair plane" in e for e in inj.events)


def test_result_planes_survive_any_single_device_loss():
    # parity/scrub colocation must not collapse the three planes onto
    # one device: every chunk keeps at least two distinct homes
    rng = np.random.default_rng(6)
    inj = FaultInjector(FaultConfig(seed=11))
    rt = _rt(devices=4, injector=inj)
    hp = [rt.put(_bits(rng, 2048), protect=True) for _ in range(2)]
    r = rt.xor(hp[0], hp[1])
    planes = [r] + list(r.replicas)
    for i in range(r.n_slots):
        homes = {p.slots[i][0] for p in planes}
        assert len(homes) >= 2


def test_scheduled_device_failure_mid_drain():
    rng = np.random.default_rng(8)
    vs = [_bits(rng, 2048) for _ in range(4)]
    ref = _rt(devices=4)
    inj = FaultInjector(FaultConfig(seed=13, fail_device_after=((3, 40),)))
    rt = _rt(devices=4, injector=inj)
    hs = [ref.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs]
    want, tickets = [], []
    for k in range(6):
        expr, i, j = _mix(rng, k, 4)
        want.append(np.asarray(
            ref.get(ref.eval(expr, {"x": hs[i], "y": hs[j]})).data))
        tickets.append(rt.submit(expr, {"x": hp[i], "y": hp[j]}))
    rt.drain()
    for t, w in zip(tickets, want):
        assert t.state == "done", t.error
        assert bool((np.asarray(rt.get(t.result).data) == w).all())
    assert 3 in rt.cluster.dead_devices
    assert rt.metrics.snapshot()["counters"]["devices_lost"] == 1


def test_single_device_loss_is_fatal_for_dirty_unprotected():
    rng = np.random.default_rng(9)
    inj = FaultInjector(FaultConfig(seed=5))
    rt = _rt(injector=inj)
    a, b = rt.put(_bits(rng)), rt.put(_bits(rng))
    r = rt.xor(a, b)                      # dirty, device-only
    inj.fail_device(0)
    with pytest.raises(FaultError):
        rt.get(rt.eval(X ^ Y, {"x": r, "y": a}))


# -- frontend degradation -----------------------------------------------------


def test_frontend_host_fallback_after_device_loss():
    rng = np.random.default_rng(10)
    vs = [_bits(rng) for _ in range(4)]
    inj = FaultInjector(FaultConfig(seed=5))
    rt = _rt(injector=inj)
    hs = [rt.put(v) for v in vs]
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=2)
    inj.fail_device(0)
    fe.submit("a", X ^ Y, {"x": hs[0], "y": hs[1]})
    fe.submit("b", X & Y, {"x": hs[2], "y": hs[3]})
    done = fe.take_completed()
    assert [q.ok for q in done] == [True, True]
    assert all(q.fallback for q in done)
    expect = [np.asarray(vs[0].data ^ vs[1].data),
              np.asarray(vs[2].data & vs[3].data)]
    for q, w in zip(done, expect):
        assert bool((np.asarray(q.result.data) == w).all())
    rep = fe.report()
    assert rep.fallbacks == 2 and rep.errors == 0
    counters = rt.metrics.snapshot()["counters"]
    assert counters["serve_host_fallbacks{tenant=a}"] == 1


def test_frontend_surfaces_errors_instead_of_crashing():
    rng = np.random.default_rng(11)
    inj = FaultInjector(FaultConfig(seed=5))
    rt = _rt(injector=inj)
    a, b = rt.put(_bits(rng)), rt.put(_bits(rng))
    lost = rt.xor(a, b)                   # dirty: unrecoverable on loss
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=2)
    inj.fail_device(0)
    fe.submit("t", X ^ Y, {"x": lost, "y": a})
    fe.submit("t", X | Y, {"x": a, "y": b})
    done = fe.take_completed()            # the drain itself survives
    by_ok = sorted(done, key=lambda q: q.ok)
    assert not by_ok[0].ok and by_ok[0].error
    assert by_ok[1].ok and by_ok[1].fallback
    assert fe.report().errors == 1


def test_frontend_deadline_rejects_stale_backlog():
    rng = np.random.default_rng(12)
    rt = _rt()
    hs = [rt.put(_bits(rng)) for _ in range(2)]
    fe = QueryFrontend(
        rt, window_ns=1e9, max_batch=8,
        quotas={"slow": TenantQuota(max_inflight=1, deadline_ns=100.0)})
    fe.submit("slow", X ^ Y, {"x": hs[0], "y": hs[1]}, arrival_ns=0.0)
    stale = fe.submit("slow", X & Y, {"x": hs[0], "y": hs[1]},
                      arrival_ns=0.0)     # queued behind the quota
    fe.tick(1e6)
    fe.flush()
    done = fe.take_completed()
    assert stale in done
    assert stale.timed_out and not stale.ok and stale.result is None
    rep = fe.report()
    assert rep.timeouts >= 1 and rep.errors >= 1


def test_frontend_marks_late_completions_timed_out():
    rng = np.random.default_rng(13)
    rt = _rt()
    hs = [rt.put(_bits(rng)) for _ in range(2)]
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=8,
                       quotas={"t": TenantQuota(deadline_ns=10.0)})
    q = fe.submit("t", X ^ Y, {"x": hs[0], "y": hs[1]}, arrival_ns=0.0)
    fe.tick(1e6)                          # ages far past the deadline
    fe.flush()
    assert q in fe.take_completed()
    assert q.timed_out and q.ok           # late but correct
    assert q.result is not None
    assert fe.report().timeouts == 1


def test_frontend_optimized_drain_attributes_cache_hits():
    rng = np.random.default_rng(14)
    rt = _rt()
    hs = [rt.put(_bits(rng)) for _ in range(2)]
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=2, optimize=True)
    for _ in range(2):
        fe.submit("tA", X ^ Y, {"x": hs[0], "y": hs[1]})
    first = fe.take_completed()
    for _ in range(2):
        fe.submit("tB", X ^ Y, {"x": hs[0], "y": hs[1]})
    second = fe.take_completed()
    assert all(q.ok for q in first + second)
    a = np.asarray(rt.get(first[0].result).data)
    for q in first + second:
        assert bool((np.asarray(rt.get(q.result).data) == a).all())
    counters = rt.metrics.snapshot()["counters"]
    assert counters["opt_cache_hits{tenant=tB}"] == 2
    assert "opt_cache_hits{tenant=tA}" not in counters


# -- accounting ---------------------------------------------------------------


def test_retry_and_scrub_costs_reconcile_with_ledger():
    rng = np.random.default_rng(15)
    vs = [_bits(rng) for _ in range(4)]
    inj = FaultInjector(FaultConfig(seed=3, stuck_row_rate=0.25,
                                    transient_rate=0.02))
    rt = _rt(injector=inj)
    rt.reliability.max_retries = 16
    hs = [rt.put(v) for v in vs]
    hp = [rt.put(v, protect=True) for v in vs[:2]]
    tickets = [rt.submit(X ^ Y, {"x": hs[k % 4], "y": hs[(k + 1) % 4]})
               for k in range(4)]
    tickets.append(rt.submit(X & Y, {"x": hp[0], "y": hp[1]}))
    rt.drain()
    rep = rt.last_drain
    assert all(t.state == "done" for t in tickets)
    # energy/AAPs are additive: every attempt's work - retries, parity
    # checks, scrub re-votes included - lands on exactly one ticket and
    # the drain total owns all of it
    assert sum(t.stats.aap_count for t in tickets) == rep.stats.aap_count
    assert sum(t.stats.energy_nj for t in tickets) == pytest.approx(
        rep.stats.energy_nj)
    # wall-clock ns is overlapped epoch maxima: serial work dominates it
    assert sum(t.stats.ns for t in tickets) >= rep.stats.ns - 1e-6
    retried = [t for t in tickets if t.retries]
    if retried:                           # backoff stretches wall clock only
        assert all(t.backoff_ns > 0 for t in retried)
        assert rep.end_ns >= max(t.finished_ns for t in tickets)
    counters = rt.metrics.snapshot()["counters"]
    n_inj = sum(1 for e in inj.events
                if e.split()[0] in ("stuck_row", "transient", "weak_cell"))
    got = sum(v for k, v in counters.items()
              if k.startswith("fault_injected{"))
    assert got == n_inj


def test_chaos_env_hook_builds_injector(monkeypatch):
    monkeypatch.setenv("PIM_CHAOS_RATE", "0.2")
    monkeypatch.setenv("PIM_CHAOS_SEED", "17")
    rt = _rt()
    inj = rt.reliability.injector
    assert inj is not None
    assert inj.config.stuck_row_rate == 0.2
    assert inj.config.seed == 17
    monkeypatch.delenv("PIM_CHAOS_RATE")
    rt2 = _rt()
    assert rt2.reliability.injector is None
