"""Sharded multi-device PimCluster: placement policies, the channel cost
model, cross-device colocation, cluster-level LRU spill, and - most
importantly - differential equivalence: sharded evaluation must be
bit-identical to single-device evaluation (and to the jnp reference) for
random expression trees over every placement policy.

Property tests run under hypothesis when installed; without it they fall
back to deterministic seeded sweeps over the same generators (the
test_pim_runtime.py pattern), so collection never fails.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (AmbitError, BitVector, BulkBitwiseEngine,
                        DRAMGeometry, Expr, maj)
from repro.pim import (AFFINITY, AmbitRuntime, ChannelModel,
                       CLUSTER_POLICIES, PACKED, PimCluster, ROUND_ROBIN)

GEOM = DRAMGeometry(rows_per_subarray=32)  # 14 data rows: compact devices
RNG = np.random.default_rng(29)

X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")
CHAIN6 = ((X & Y) | ~Z) ^ ((X | Y) & Z)    # and,or,not,or,and,xor = 6 ops


def _cluster(devices=2, **kw):
    kw.setdefault("banks", 2)
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    kw.setdefault("scratch_rows", 2)
    return PimCluster(devices, GEOM, **kw)


def _bv(n_chunks, rng=RNG):
    return BitVector.from_bits(
        rng.integers(0, 2, n_chunks * 128).astype(bool))


# -- channel cost model -------------------------------------------------------


def test_channel_model_per_hop_costs():
    cm = ChannelModel()
    assert cm.device_to_device_ns(1, 1, 8192) == 0.0
    one = cm.device_to_device_ns(0, 1, 8192)
    two = cm.device_to_device_ns(0, 2, 8192)
    assert one > cm.link_fixed_ns
    assert two > one                      # per-hop: distance costs
    assert two - cm.link_fixed_ns == pytest.approx(
        2 * (one - cm.link_fixed_ns))
    assert cm.host_transfer_ns(8192) > cm.host_fixed_ns
    assert cm.intra_device_ns(8192) > 0


# -- placement policies -------------------------------------------------------


def test_round_robin_stripes_chunks_across_devices():
    cl = _cluster(4)
    rbv = cl.put(_bv(8))
    assert [d for d, _ in rbv.slots] == [0, 1, 2, 3, 0, 1, 2, 3]


def test_packed_fills_devices_in_order():
    cl = _cluster(2, banks=1, subarrays=1, placement=PACKED)  # 12 rows/dev
    a = cl.put(_bv(12))
    b = cl.put(_bv(4))
    assert a.devices == [0]
    assert b.devices == [1]               # device 0 full: spill over


def test_affinity_follows_neighbor_chunks():
    cl = _cluster(4, placement=AFFINITY)
    a = cl.put(_bv(6))
    b = cl.put(_bv(6), near=a.slots)
    assert a.devices == [0] and b.devices == [0]
    assert [s[:1] for s in a.slots] == [s[:1] for s in b.slots]
    # and within the device, chunks are subarray-aligned too
    assert [(d, bs[0], bs[1]) for d, bs in a.slots] == \
        [(d, bs[0], bs[1]) for d, bs in b.slots]


def test_affinity_without_neighbor_picks_least_loaded():
    cl = _cluster(3, placement=AFFINITY)
    a = cl.put(_bv(4))
    b = cl.put(_bv(4))                    # no near: next device
    assert a.devices == [0] and b.devices == [1]


# -- cross-device colocation --------------------------------------------------


def test_colocate_picks_cheapest_direction():
    cl = _cluster(3, placement=AFFINITY)
    a = cl.put(_bv(4))                    # device 0
    b = cl.put(_bv(4), near=a.slots)      # device 0
    c = cl.put(_bv(4))                    # device 1 (least loaded)
    moved = cl.colocate([a, b, c])
    # moving c's 4 rows to device 0 (one migration per chunk) is cheaper
    # than moving a AND b to device 1 (two migrations per chunk)
    assert moved == 4
    assert c.devices == [0]
    assert a.devices == [0] and b.devices == [0]
    assert cl.ledger.inter_device_rows == 4
    assert cl.ledger.inter_device_bytes == 4 * cl.row_bytes
    assert cl.ledger.inter_device_ns > 0


def test_spanning_eval_measures_transfers_and_stays_correct():
    rng = np.random.default_rng(7)
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                      devices=4, scratch_rows=2, seed=3)
    bits = rng.integers(0, 2, (2, 8 * 128)).astype(bool)
    a = rt.store.put(BitVector.from_bits(bits[0]), placement=PACKED)
    b = rt.store.put(BitVector.from_bits(bits[1]), placement=ROUND_ROBIN)
    assert a.devices == [0] and len(b.devices) == 4
    out = rt.and_(a, b)
    led = rt.store.ledger
    # measured, not analytic: bytes == rows actually moved * row size
    assert led.inter_device_rows > 0
    assert led.inter_device_bytes == led.inter_device_rows * cl_row_bytes(rt)
    assert rt.last_stats.channel_bytes == led.inter_device_bytes
    assert rt.last_stats.channel_ns == pytest.approx(led.inter_device_ns)
    assert rt.last_stats.ns >= rt.last_stats.channel_ns
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] & bits[1])


def cl_row_bytes(rt):
    return rt.store.row_bytes


# -- differential equivalence (the acceptance bar) ----------------------------


def test_sharded_6op_chain_matches_single_device():
    """Acceptance: a 6-op chain over >= 4 devices is bit-identical to
    single-device eval, for every placement policy."""
    rng = np.random.default_rng(11)
    bits = rng.integers(0, 2, (3, 2, 6 * 128)).astype(bool)
    env_host = {k: BitVector.from_bits(bits[i])
                for i, k in enumerate("xyz")}
    want = np.asarray(BulkBitwiseEngine("jnp").eval(CHAIN6,
                                                    env_host).bits())
    single = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                          scratch_rows=2, seed=1)
    env = {k: single.put(v) for k, v in env_host.items()}
    got_single = np.asarray(single.get(single.eval(CHAIN6, env)).bits())
    assert np.array_equal(got_single, want)
    for placement in CLUSTER_POLICIES:
        rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                          devices=4, placement=placement,
                          scratch_rows=2, seed=1)
        env = {k: rt.put(v) for k, v in env_host.items()}
        out = rt.eval(CHAIN6, env)
        assert out.dirty
        got = np.asarray(rt.get(out).bits())
        assert np.array_equal(got, got_single), placement


def rand_expr(rng, depth=0):
    if depth > 3 or rng.integers(2):
        return (X, Y, Z)[rng.integers(3)]
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a, b = rand_expr(rng, depth + 1), rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


def check_sharded_matches_single(seed, placement, devices):
    """Sharded eval == single-device eval == jnp, bit for bit. Operands
    are put WITHOUT near-affinity so policies are free to scatter chunks
    (affinity then exercises the cross-device colocation path)."""
    rng = np.random.default_rng(seed)
    expr = rand_expr(rng)
    if expr.op in ("var", "lit"):
        expr = expr ^ Y                   # ensure at least one op
    n_bits = int(rng.integers(1, 900))
    bits = rng.integers(0, 2, (3, n_bits)).astype(bool)
    env_host = {k: BitVector.from_bits(bits[i])
                for i, k in enumerate("xyz")}
    want = np.asarray(BulkBitwiseEngine("jnp").eval(expr, env_host).bits())
    single = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                          scratch_rows=2, seed=seed % 5)
    env = {k: single.put(v) for k, v in env_host.items()}
    got_single = np.asarray(single.get(single.eval(expr, env)).bits())
    assert np.array_equal(got_single, want), (repr(expr), n_bits)

    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                      devices=devices, placement=placement,
                      scratch_rows=2, seed=seed % 5)
    env = {k: rt.put(v) for k, v in env_host.items()}
    out = rt.eval(expr, env)
    got = np.asarray(rt.get(out).bits())
    assert np.array_equal(got, want), (repr(expr), n_bits, placement)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from(list(CLUSTER_POLICIES)),
           st.sampled_from([2, 4]))
    def test_sharded_matches_single_random(seed, placement, devices):
        check_sharded_matches_single(seed, placement, devices)

else:

    @pytest.mark.parametrize("seed", range(7))
    @pytest.mark.parametrize("placement", CLUSTER_POLICIES)
    @pytest.mark.parametrize("devices", [2, 4])
    def test_sharded_matches_single_random(seed, placement, devices):
        check_sharded_matches_single(seed, placement, devices)


# -- cluster-level LRU spill --------------------------------------------------


def test_cluster_put_spills_lru_clean_for_free():
    cl = _cluster(2, banks=1, subarrays=1)   # 12 rows per device
    bv_a = _bv(12)
    host_a = np.asarray(bv_a.bits())
    a = cl.put(bv_a, name="a")               # 6 chunks on each device
    b = cl.put(_bv(8), name="b")
    base = cl.ledger.device_to_host_bytes
    c = cl.put(_bv(12), name="c")            # full: evict a (LRU, clean)
    assert a.spilled and not b.spilled and not c.spilled
    # per-device partial spill: one clean eviction event per full device
    assert (cl.evicted_clean, cl.evicted_dirty) == (2, 0)
    assert cl.ledger.device_to_host_bytes == base   # clean: zero bytes
    assert np.array_equal(np.asarray(cl.get(a).bits()), host_a)
    cl.ensure_resident(a)                    # fault back in
    assert not a.spilled
    assert np.array_equal(np.asarray(cl.get(a).bits()), host_a)


def test_cluster_dirty_spill_charges_readback():
    rng = np.random.default_rng(13)
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2,
                      devices=2, scratch_rows=2, seed=2)
    bits = rng.integers(0, 2, (2, 8 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    out = rt.xor(a, b)                       # dirty, cluster now full
    out_bytes = out.device_bytes
    rt.get(a), rt.get(b)                     # free touches: out is LRU
    base = rt.store.ledger.device_to_host_bytes
    rt.put(_bv(8))                           # evicts out: dirty read-back
    assert out.spilled
    # two per-device dirty eviction events, but each chunk crosses the
    # channel exactly once: total read-back bytes == the vector's bytes
    assert rt.store.evicted_dirty == 2
    assert rt.store.ledger.device_to_host_bytes == base + out_bytes
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] ^ bits[1])


def test_sharded_eval_spills_on_full_device():
    """Cluster analogue of test_planner_protects_in_use_operands: the
    per-device sub-plans' destination rows on a full cluster LRU-spill a
    cold bystander (through the per-device store's cluster fallback) -
    never the in-flight operands."""
    rng = np.random.default_rng(19)
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2,
                      devices=2, scratch_rows=2, seed=2)
    bits = rng.integers(0, 2, (3, 8 * 128)).astype(bool)
    cold = rt.put(BitVector.from_bits(bits[2]))   # oldest: the LRU victim
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    assert sum(al.free_slots for al in rt.store.allocators) == 0
    out = rt.and_(a, b)                  # dst rows force cluster eviction
    assert cold.spilled and not a.spilled and not b.spilled
    assert rt.store.evicted_clean == 2   # one partial event per device
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] & bits[1])
    # and the spilled bystander still reads back exactly, then faults in
    assert np.array_equal(np.asarray(rt.get(cold).bits()), bits[2])


def test_partial_spill_keeps_other_devices_hot():
    """A full device evicts only the victim's chunks resident THERE: the
    chunks on other devices stay hot (non-None slots), the handle is
    neither freed nor fully spilled, reads stay exact and free (clean
    victim), and fault-in re-uploads only the missing chunks."""
    cl = _cluster(2, banks=1, subarrays=1)   # 12 rows per device
    bv_a = _bv(8)
    host_a = np.asarray(bv_a.bits())
    a = cl.put(bv_a, name="a")               # round_robin: 4 chunks/device
    base_up = cl.ledger.host_to_device_bytes
    base_down = cl.ledger.device_to_host_bytes
    cl.put(_bv(20), name="b", placement="packed")  # overflows device 0
    # b needed 12 rows on device 0; a's 4 chunks there were evicted
    assert a.partially_spilled and not a.spilled and not a.freed
    live_devs = {ds[0] for ds in a.slots if ds is not None}
    assert live_devs == {1}
    assert [i for i, ds in enumerate(a.slots) if ds is None] == [0, 2, 4, 6]
    assert cl.ledger.device_to_host_bytes == base_down  # clean: free
    assert np.array_equal(np.asarray(cl.get(a).bits()), host_a)
    # fault-in uploads ONLY the 4 missing chunks
    cl.ensure_resident(a)
    assert not a.partially_spilled
    assert cl.ledger.host_to_device_bytes - base_up == \
        20 * cl.row_bytes + 4 * cl.row_bytes
    assert np.array_equal(np.asarray(cl.get(a).bits()), host_a)


def test_partial_spill_dirty_chunks_stash_and_merge():
    """Dirty partial spill reads back just the evicted device's chunks
    (charged), stashes them, and a later ``get`` merges stash + live
    reads - charging only the still-resident rows - into an exact host
    copy."""
    rng = np.random.default_rng(41)
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2,
                      devices=2, scratch_rows=2, seed=2)
    bits = rng.integers(0, 2, (2, 8 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    out = rt.xor(a, b)                       # dirty, 4 chunks per device
    rt.get(a), rt.get(b)                     # free touches: out is LRU
    base = rt.store.ledger.device_to_host_bytes
    rt.store.spill_device(out, 0)            # evict only device 0's share
    assert out.partially_spilled and not out.spilled
    assert rt.store.evicted_dirty == 1
    assert rt.store.ledger.device_to_host_bytes == base + 4 * rt.store.row_bytes
    got = np.asarray(rt.store.get(out).bits())   # merge stash + device 1
    assert np.array_equal(got, bits[0] ^ bits[1])
    assert rt.store.ledger.device_to_host_bytes == \
        base + 8 * rt.store.row_bytes        # each chunk crossed once
    # fault the missing chunks back in and evaluate on-device again
    rt.store.ensure_resident(out)
    assert not out.partially_spilled
    final = rt.and_(out, a)
    assert np.array_equal(np.asarray(rt.get(final).bits()),
                          (bits[0] ^ bits[1]) & bits[0])


def test_partial_spill_handle_rejected_by_planner_until_fault_in():
    cl = _cluster(2, banks=1, subarrays=1)
    a = cl.put(_bv(8), name="a")
    b = cl.put(_bv(8), name="b", near=a.slots)
    cl.spill_device(a, 0)
    assert a.partially_spilled
    with pytest.raises(AmbitError, match="partially spilled"):
        cl.planner.execute(X & Y, {"x": a, "y": b})
    cl.ensure_resident(a)
    out = cl.planner.execute(X & Y, {"x": a, "y": b})
    assert np.array_equal(
        np.asarray(cl.get(out).bits()),
        np.asarray(cl.get(a).bits()) & np.asarray(cl.get(b).bits()))


def test_cluster_pinned_never_evicted():
    cl = _cluster(2, banks=1, subarrays=1)
    a = cl.put(_bv(12), pin=True, name="a")
    b = cl.put(_bv(8), name="b")
    cl.put(_bv(12), name="c")                # evicts b, not pinned a
    assert b.spilled and not a.spilled
    with pytest.raises(AmbitError, match="pinned or in use"):
        cl.put(_bv(20), name="d")


# -- put/evict/free interleaving property test --------------------------------


def check_cluster_lifecycle(ops_seed):
    """Random put/get/free/spill/eval interleavings: allocator occupancy
    always equals the chunks of unspilled live handles, no slot is owned
    twice, and every handle - resident or spilled - reads back exactly
    the bits that were put (or computed: eval results join the pool, so
    eval under capacity pressure - spill-during-sub-plan - is covered
    too)."""
    and_expr = Expr.var("a") & Expr.var("b")
    rng = np.random.default_rng(ops_seed)
    cl = _cluster(2, banks=1, subarrays=2,
                  placement=list(CLUSTER_POLICIES)[int(rng.integers(3))])
    live = {}        # handle -> expected bits
    for _ in range(40):
        roll = rng.integers(6)
        handles = list(live)
        if roll == 0 and handles:
            victim = handles[int(rng.integers(len(handles)))]
            cl.free(victim)
            del live[victim]
        elif roll == 1 and handles:
            h = handles[int(rng.integers(len(handles)))]
            if h.slots and not h.pinned:
                cl.spill(h)
        elif roll == 2 and handles:
            h = handles[int(rng.integers(len(handles)))]
            cl.ensure_resident(h)
        elif roll == 3 and len(handles) >= 2:
            h1 = handles[int(rng.integers(len(handles)))]
            mates = [h for h in handles
                     if h is not h1 and h.n_slots == h1.n_slots
                     and h.n_bits == h1.n_bits]
            if not mates:
                continue
            h2 = mates[int(rng.integers(len(mates)))]
            try:
                cl.ensure_resident(h1)
                cl.ensure_resident(h2, protect=(h1,))
                out = cl.planner.execute(and_expr, {"a": h1, "b": h2})
            except AmbitError:
                continue     # cluster genuinely full of in-use handles
            live[out] = live[h1] & live[h2]
        else:
            n_chunks = int(rng.integers(1, 7))
            bits = rng.integers(0, 2, n_chunks * 128).astype(bool)
            try:
                h = cl.put(BitVector.from_bits(bits))
            except AmbitError:
                continue         # everything pinned/in-use: fine
            live[h] = bits
        # invariants (None slots = partially spilled chunks: own no rows)
        owned = [ds for h in live for ds in h.slots if ds is not None]
        assert len(owned) == len(set(owned)), "slot owned twice"
        resident_chunks = sum(len(h.live_chunks) for h in live)
        assert sum(a.live for a in cl.allocators) == resident_chunks
        for h, bits in live.items():
            assert np.array_equal(np.asarray(cl.get(h).bits()), bits)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_cluster_lifecycle_random(ops_seed):
        check_cluster_lifecycle(ops_seed)

else:

    @pytest.mark.parametrize("ops_seed", range(15))
    def test_cluster_lifecycle_random(ops_seed):
        check_cluster_lifecycle(ops_seed)


# -- sharded accounting -------------------------------------------------------


def test_sharded_time_is_max_over_devices():
    """Aligned round-robin chunks: devices run their sub-plans in
    parallel, so reported time is the max over devices (plus zero channel
    time), while energy sums."""
    rng = np.random.default_rng(3)
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2,
                      devices=2, scratch_rows=2, seed=4)
    bits = rng.integers(0, 2, (2, 4 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    rt.and_(a, b)
    rep = rt.planner.last_report
    assert len(rep.per_device_ns) == 2
    assert rep.transfer_bytes == 0
    per_dev = list(rep.per_device_ns.values())
    assert rep.stats.ns == pytest.approx(max(per_dev))
    assert sum(per_dev) > rep.stats.ns    # parallelism actually claimed


def test_apps_run_sharded_bit_identical():
    """BitmapIndex over a 3-device cluster returns exactly the host-path
    answers, with zero inter-device traffic (the near= chain keeps
    co-queried bitmaps chunk-aligned)."""
    from repro.apps.bitmap_index import BitmapIndex

    rng = np.random.default_rng(5)
    n_users = 1500
    weeks = [f"w{i}" for i in range(3)]
    host = BitmapIndex(n_users, BulkBitwiseEngine("jnp"))
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                      devices=3, scratch_rows=2, seed=6)
    shard = BitmapIndex(n_users, runtime=rt)
    for w in weeks + ["male"]:
        members = rng.choice(n_users, n_users // 3, replace=False)
        host.add(w, members)
        shard.add(w, members)
    want_u, want_pw, _ = host.weekly_active_query(weeks, "male")
    got_u, got_pw, st = shard.weekly_active_query(weeks, "male")
    assert (got_u, got_pw) == (want_u, want_pw)
    assert rt.store.ledger.inter_device_bytes == 0
    assert st.ns > 0


def test_cluster_ledger_deterministic(record_ledger):
    """Two fresh identical sessions must produce byte-identical ledgers
    (recorded for the CI double-run diff as well)."""
    def session():
        rng = np.random.default_rng(21)
        rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2, devices=3,
                          scratch_rows=2, seed=8)
        bits = rng.integers(0, 2, (3, 6 * 128)).astype(bool)
        a = rt.store.put(BitVector.from_bits(bits[0]), placement=PACKED)
        b = rt.put(BitVector.from_bits(bits[1]))
        c = rt.put(BitVector.from_bits(bits[2]))
        out = rt.eval(CHAIN6, {"x": a, "y": b, "z": c})
        rt.get(out)
        return f"{rt.session_stats!r} | {rt.store.ledger!r}"

    one, two = session(), session()
    assert one == two
    record_ledger("pim_cluster_session", one)
