"""Training substrate: optimizer, microbatching, checkpoint/restore
(+elastic reshard), fault-tolerant supervisor, straggler watchdog,
gradient compression (error-feedback convergence parity)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.models import build_model
from repro.optim.optimizer import OptimizerConfig, schedule
from repro.runtime import (HostFailure, StragglerWatchdog, Supervisor,
                           elastic_mesh_shape)
from repro.train.step import init_state, make_train_step
from repro.data.pipeline import DataConfig, SyntheticLM

KEY = jax.random.PRNGKey(0)


def small_setup(microbatches=1):
    cfg = get_config("qwen2.5-3b").reduced()
    model = build_model(cfg)
    state = init_state(model, KEY)
    opt = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(model, opt, remat=False,
                                   microbatches=microbatches))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4, noise=0.0))

    def batch_at(s):
        return {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}

    return model, state, step, batch_at


def test_loss_decreases():
    _, state, step, batch_at = small_setup()
    losses = []
    for s in range(25):
        state, m = step(state, batch_at(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.min_lr_ratio)


def test_microbatch_accumulation_matches_full_batch():
    _, state, step1, batch_at = small_setup(microbatches=1)
    _, _, step4, _ = small_setup(microbatches=4)
    b = batch_at(0)
    s1, m1 = step1(state, b)
    s4, m4 = step4(state, b)
    # same gradient direction: losses equal, params close
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    w1 = jax.tree.leaves(s1["params"])[0]
    w4 = jax.tree.leaves(s4["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w4), atol=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    _, state, step, batch_at = small_setup()
    state, _ = step(state, batch_at(0))
    ck = Checkpointer(str(tmp_path), keep_n=2)
    ck.save(1, state, blocking=True)
    got_step, tree = ck.restore()
    assert got_step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"w": jnp.ones((2,)) * s}, blocking=True)
    assert ck.steps() == [3, 4]
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_supervisor_recovers_from_injected_failures(tmp_path):
    _, state, step, batch_at = small_setup()
    ck = Checkpointer(str(tmp_path), keep_n=3)
    sup = Supervisor(ck, checkpoint_every=5)
    fail_at = {7, 12}

    def injector(s):
        if s in fail_at:
            fail_at.remove(s)
            raise HostFailure()

    final, hist = sup.run(state, batch_at, step, start_step=0, n_steps=20,
                          failure_injector=injector)
    steps_run = [h["step"] for h in hist if "dt" in h]
    assert max(steps_run) == 19
    restarts = [h for h in hist if "restart" in h]
    assert len(restarts) == 2
    assert ck.latest_step() == 20


def test_straggler_watchdog():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0)
    for s in range(5):
        assert not wd.observe(s, 1.0)
    assert wd.observe(5, 5.0)      # flagged
    assert not wd.observe(6, 1.1)  # baseline not poisoned
    assert wd.flagged == [5]


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(512, 16) == {"data": 32, "model": 16}
    assert elastic_mesh_shape(480, 16) == {"data": 30, "model": 16}
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)


def test_gradient_compression_convergence_parity():
    """EF-int8-compressed 2-shard training ~ full-precision training."""
    from repro.train.compression import (compression_ratio, ef_compress_tree,
                                         init_error_state)

    rng = np.random.default_rng(0)
    wtrue = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
    y = x @ wtrue

    def loss(w, xs, ys):
        return jnp.mean((xs @ w - ys) ** 2)

    g = jax.grad(loss)

    def train(compressed):
        w = jnp.zeros(8)
        err = [init_error_state({"w": w}) for _ in range(2)]
        lr = 0.05
        for step in range(150):
            gs = []
            for shard in range(2):
                sl = slice(shard * 128, (shard + 1) * 128)
                gi = {"w": g(w, x[sl], y[sl])}
                if compressed:
                    q, scale, err[shard] = ef_compress_tree(gi, err[shard])
                    gi = jax.tree.map(
                        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scale)
                gs.append(gi)
            gmean = jax.tree.map(lambda a, b: (a + b) / 2, *gs)
            w = w - lr * gmean["w"]
        return float(loss(w, x, y))

    full = train(False)
    comp = train(True)
    assert comp < 1e-3, comp
    assert abs(comp - full) < 1e-3
    assert compression_ratio({"w": np.zeros((1000,))}) > 3.5
