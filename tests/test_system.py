"""End-to-end behaviour tests: train->checkpoint->resume->serve on a
reduced model, with the Ambit engine in the data path (the full system
loop a deployment would run)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, FilteredSyntheticLM
from repro.models import build_model
from repro.optim.optimizer import OptimizerConfig
from repro.runtime import Supervisor
from repro.serve import Request, ServeEngine
from repro.train.step import init_state, make_train_step


def test_end_to_end_train_checkpoint_resume_serve(tmp_path):
    cfg = get_config("qwen2.5-3b").reduced()
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    opt = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=60)
    step = jax.jit(make_train_step(model, opt, remat=False))

    # data pipeline with the BitWeaving document filter in the loop
    data = FilteredSyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, noise=0.0),
        n_docs=512)

    def batch_at(s):
        b = data.batch_at(s)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    ck = Checkpointer(str(tmp_path), keep_n=2)
    sup = Supervisor(ck, checkpoint_every=5)
    state, hist = sup.run(state, batch_at, step, 0, 12)
    losses = [h["loss"] for h in hist if "loss" in h]
    assert np.isfinite(losses).all()

    # resume from the checkpoint as a fresh process would
    restored_step, tree = ck.restore()
    assert restored_step == 12
    state2, hist2 = sup.run(tree, batch_at, step, restored_step, 16)
    assert [h["step"] for h in hist2 if "dt" in h] == [12, 13, 14, 15]

    # serve with the trained weights
    eng = ServeEngine(model, state2["params"], max_seq=64, batch_slots=2)
    reqs = [Request(prompt=np.array([5, 6, 7], np.int32),
                    max_new_tokens=4)]
    eng.generate(reqs)
    assert len(reqs[0].out) == 4
    assert all(0 <= t < cfg.vocab for t in reqs[0].out)


def test_binary_lm_layer_integration():
    """BitLinear (XNOR-popcount) forward agrees with +-1 dense matmul -
    the Section 8.4.5 ML application wired into a model-like layer."""
    from repro.core.bitvector import pack_bits
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    d_in, d_out, b = 128, 64, 8
    x = rng.normal(size=(b, d_in)).astype(np.float32)
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    # binarize with per-row scales (XNOR-Net style)
    xs = np.abs(x).mean(-1, keepdims=True)
    ws = np.abs(w).mean(-1, keepdims=True)
    xb = (x > 0).astype(np.uint32)
    wb = (w > 0).astype(np.uint32)
    xp = pack_bits(jnp.asarray(xb))[:, :d_in // 32]
    wp = pack_bits(jnp.asarray(wb))[:, :d_in // 32]
    y_packed = np.asarray(ops.binary_matmul(xp, wp, d_in)) * xs * ws.T
    y_dense = ((2 * xb - 1.0) @ (2 * wb - 1.0).T) * xs * ws.T
    np.testing.assert_allclose(y_packed, y_dense, rtol=1e-6)
