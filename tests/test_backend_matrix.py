"""Cross-backend equivalence matrix.

Every engine backend ("jnp", "pallas", "ambit_sim") must compute identical
results for every bbop, for awkward bitvector lengths (non-multiples of 32,
single bits, >1 packed word) and for batched (rows, n_bits) operands. Shift
edge cases (0, +-word boundary, |amount| >= n_bits) are checked against a
pure-numpy reference.
"""

import numpy as np
import pytest

from repro.core import BitVector, BulkBitwiseEngine

BACKENDS = ("jnp", "pallas", "ambit_sim")
N_BITS = (1, 31, 33, 95, 257)  # deliberately not multiples of 32
RNG = np.random.default_rng(17)


def _bv(n_bits, rows=()):
    return BitVector.from_bits(
        RNG.integers(0, 2, rows + (n_bits,)).astype(bool))


def _ref(op, a, b, c):
    return {
        "and": a & b, "or": a | b, "xor": a ^ b,
        "nand": ~(a & b), "nor": ~(a | b), "xnor": ~(a ^ b),
        "maj": (a & b) | (b & c) | (c & a),
        "masked_set": a | b,
        "masked_clear": a & ~b,
    }[op]


def _apply(eng, op, a, b, c):
    if op == "maj":
        return eng.maj(a, b, c)
    if op == "masked_set":
        return eng.masked_set(a, b)
    if op == "masked_clear":
        return eng.masked_clear(a, b)
    return getattr(eng, op if op != "and" and op != "or" else op + "_")(a, b)


OPS = ("and", "or", "xor", "nand", "nor", "xnor", "maj",
       "masked_set", "masked_clear")


@pytest.mark.parametrize("n_bits", N_BITS)
@pytest.mark.parametrize("op", OPS)
def test_backends_agree(op, n_bits):
    a, b, c = _bv(n_bits), _bv(n_bits), _bv(n_bits)
    ref = _ref(op, np.asarray(a.bits()), np.asarray(b.bits()),
               np.asarray(c.bits()))
    for backend in BACKENDS:
        eng = BulkBitwiseEngine(backend)
        got = np.asarray(_apply(eng, op, a, b, c).bits())
        assert np.array_equal(got, ref), (backend, op, n_bits)


@pytest.mark.parametrize("op", ("xor", "maj", "nand"))
def test_backends_agree_batched_rows(op):
    """(rows, n_bits) operands: the ambit_sim batch dimension in action."""
    n_bits = 97
    a, b, c = (_bv(n_bits, rows=(6,)) for _ in range(3))
    ref = _ref(op, np.asarray(a.bits()), np.asarray(b.bits()),
               np.asarray(c.bits()))
    for backend in BACKENDS:
        eng = BulkBitwiseEngine(backend)
        got = np.asarray(_apply(eng, op, a, b, c).bits())
        assert np.array_equal(got, ref), (backend, op)


@pytest.mark.parametrize("n_bits", (1, 31, 33, 95))
@pytest.mark.parametrize("amount_kind", (
    "zero", "pos_small", "neg_small", "pos_word", "neg_word",
    "pos_over", "neg_over"))
@pytest.mark.parametrize("backend", BACKENDS)
def test_shift_edge_cases(backend, amount_kind, n_bits):
    """Shift semantics are backend-independent (word-granular jnp path) but
    must hold for every engine configuration and bit length, including
    amount 0, exactly one packed word (+-32) and |amount| >= n_bits."""
    amount = {
        "zero": 0, "pos_small": 3, "neg_small": -3,
        "pos_word": 32, "neg_word": -32,
        "pos_over": n_bits, "neg_over": -(n_bits + 5),
    }[amount_kind]
    arr = RNG.integers(0, 2, n_bits).astype(bool)
    eng = BulkBitwiseEngine(backend)
    got = np.asarray(eng.shift(BitVector.from_bits(arr), amount).bits())
    want = np.zeros_like(arr)
    if amount >= 0:
        if amount < n_bits:
            want[amount:] = arr[:n_bits - amount]
    else:
        if -amount < n_bits:
            want[:n_bits + amount] = arr[-amount:]
    assert np.array_equal(got, want), (backend, amount, n_bits)


@pytest.mark.parametrize("backend", BACKENDS)
def test_not_and_popcount_agree(backend):
    a = _bv(130)
    eng = BulkBitwiseEngine(backend)
    got = np.asarray(eng.not_(a).bits())
    assert np.array_equal(got, ~np.asarray(a.bits()))
    assert int(eng.popcount(a)) == int(np.asarray(a.bits()).sum())


def test_resident_chain_matches_all_backends():
    """Acceptance bar for the PIM runtime: a query_and_all-style chain of
    6 dependent ANDs over bitvectors spanning >= 256 device rows runs
    fully resident - zero intermediate host read-backs, strictly lower
    host traffic than the non-resident engine path - and the final result
    is bit-identical across jnp/pallas/ambit_sim and the runtime."""
    from repro.core import Expr
    from repro.pim import AmbitRuntime

    n_bits = 256 * 256       # 256 chunks of 256 bits at words=4
    vecs = [_bv(n_bits) for _ in range(7)]

    rt = AmbitRuntime(banks=4, subarrays=4, words=4)
    rs = []
    for i, bv in enumerate(vecs):
        rs.append(rt.put(bv, name=f"w{i}",
                         near=rs[0].slots if rs else None))
    assert rs[0].n_slots >= 256

    acc = rs[0]
    for r in rs[1:]:            # 6 dependent resident ANDs
        acc = rt.and_(acc, r)
    assert rt.host_reads == 0   # intermediates never crossed the channel
    resident_out = np.asarray(rt.get(acc).bits())
    assert rt.host_reads == 1   # ... only the final result did
    resident_bytes = rt.session_stats.bytes_touched

    for backend in BACKENDS:
        eng = BulkBitwiseEngine(backend)
        host_acc, host_bytes = vecs[0], 0
        for bv in vecs[1:]:
            host_acc = eng.and_(host_acc, bv)
            host_bytes += eng.last_stats.bytes_touched
        assert np.array_equal(np.asarray(host_acc.bits()),
                              resident_out), backend
        assert resident_bytes < host_bytes, (backend, resident_bytes,
                                             host_bytes)
