"""Serving layer: ServeEngine termination regressions + QueryFrontend
differential and property suite.

The frontend reorders WHEN queries run (admission quotas, batching
windows, epoch packing) but must never change WHAT they compute or what
the ledgers record:

  * every query served through the frontend is bit-identical to a serial
    ``eval`` of the same expression, and on ``ambit_sim`` the summed
    drain ledgers conserve energy/AAPs exactly against the serial run;
  * the batching window drains for exactly two reasons - it filled
    (``max_batch``) or its oldest admitted query aged past ``window_ns``
    on the simulated clock - and per-query timestamps are monotone
    (arrival <= admission <= finish);
  * per-tenant ``max_inflight`` quotas block admission without blocking
    the queue - an over-quota tenant's backlog never starves other
    tenants - and pinned working sets are budgeted at both the tenant
    (``TenantQuota.pin_bytes``) and store (``pin_budget_bytes``) levels;
  * the accelerator backends keep the popcount reduction device-side:
    the count matches the host computation bit-for-bit while only the
    int32 scalar (4 bytes) crosses the channel.

ServeEngine regressions pin the termination contract: the
prefill-sampled token is EOS-checked like every other token, and padded
slots of a partial batch never keep the decode loop alive.

Property tests run under hypothesis when installed; without it they fall
back to deterministic seeded sweeps over the same generators.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax

from repro.core import AmbitError, BitVector, DRAMGeometry, Expr
from repro.core.engine import OpStats
from repro.pim import AmbitRuntime
from repro.serve import (QueryFrontend, Request, ServeEngine, TenantQuota,
                         run_closed_loop)

GEOM = DRAMGeometry(rows_per_subarray=32)  # compact devices
BACKENDS = ("ambit_sim", "jnp", "pallas")

X, Y = Expr.var("x"), Expr.var("y")
EXPRS = [X & Y, X | Y, X ^ Y, ~X, (X & Y) ^ X, ~(X | Y)]


def _rt(backend="ambit_sim", **kw):
    if backend != "ambit_sim":
        return AmbitRuntime(backend=backend, **kw)
    kw.setdefault("banks", 2)
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    kw.setdefault("seed", 3)
    return AmbitRuntime(GEOM, **kw)


def _operands(rt, rng, n=4, n_bits=120):
    bits = rng.integers(0, 2, (n, n_bits)).astype(bool)
    return bits, [rt.put(BitVector.from_bits(b)) for b in bits]


# -- ServeEngine termination regressions --------------------------------------


class _StubModel:
    """Deterministic LM: next token = (last token + 1) mod V under
    argmax, so generations are predictable without real weights."""

    V = 16

    def prefill(self, params, batch, skv=None):
        last = batch["tokens"][:, -1]
        return jax.nn.one_hot((last + 1) % self.V, self.V), {"t": last}

    def decode_step(self, params, caches, batch):
        last = batch["tokens"][:, 0]
        return jax.nn.one_hot((last + 1) % self.V, self.V), caches


def _engine(batch_slots=2, max_seq=32):
    return ServeEngine(_StubModel(), {}, max_seq=max_seq,
                       batch_slots=batch_slots)


def test_eos_on_prefill_token_regression():
    """The token sampled from the PREFILL logits is EOS-checked too: a
    request whose first generated token is EOS produces no output and
    costs zero decode steps (it used to be appended unconditionally)."""
    eng = _engine()
    reqs = [Request(prompt=np.array([5], np.int32), max_new_tokens=8,
                    eos_id=6)]
    eng.generate(reqs)
    assert reqs[0].out == []
    assert reqs[0].done
    assert eng.decode_steps == 0


def test_eos_mid_stream_stops_decoding():
    eng = _engine()
    reqs = [Request(prompt=np.array([3], np.int32), max_new_tokens=10,
                    eos_id=7)]
    eng.generate(reqs)
    assert reqs[0].out == [4, 5, 6]     # 7 is EOS: checked, not emitted
    assert reqs[0].done
    assert eng.decode_steps == 3


def test_partial_batch_padded_slots_do_not_prolong_decode():
    """One real request in a 4-slot batch: the loop runs exactly the
    decode steps the real request needs - padded slots are born done."""
    eng = _engine(batch_slots=4)
    reqs = [Request(prompt=np.array([1], np.int32), max_new_tokens=3)]
    eng.generate(reqs)
    assert reqs[0].out == [2, 3, 4]
    assert eng.decode_steps == 2        # prefill token + 2 decode tokens


def test_mixed_eos_batch_counts_exact_decode_steps():
    """Batchmates finish at different times; the loop runs only until
    the LAST real request is done."""
    eng = _engine(batch_slots=2)
    reqs = [Request(prompt=np.array([5], np.int32), max_new_tokens=8,
                    eos_id=7),          # 6 then EOS: done after 1 decode
            Request(prompt=np.array([1], np.int32), max_new_tokens=4)]
    eng.generate(reqs)
    assert reqs[0].out == [6]
    assert reqs[1].out == [2, 3, 4, 5]
    assert eng.decode_steps == 3


def test_generate_empty_and_single_token():
    eng = _engine()
    assert eng.generate([]) == []
    reqs = [Request(prompt=np.array([1, 2], np.int32), max_new_tokens=1)]
    eng.generate(reqs)
    assert reqs[0].out == [3] and reqs[0].done
    assert eng.decode_steps == 0


def test_generate_validates_before_running():
    eng = _engine(max_seq=8)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.generate([Request(prompt=np.arange(9, dtype=np.int32))])
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([Request(prompt=np.array([], np.int32))])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate([Request(prompt=np.array([1], np.int32),
                              max_new_tokens=0)])
    assert eng.decode_steps == 0        # no partial generation on bad input


def test_max_seq_bounds_generation():
    eng = _engine(max_seq=4)
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32),
                    max_new_tokens=10)]
    eng.generate(reqs)
    # pos would step past the KV cache: only the prefill token fits
    assert reqs[0].out == [4] and reqs[0].done


# -- frontend differential: served == serial, ledgers conserved ---------------


def check_frontend_matches_serial(seed, backend):
    rng = np.random.default_rng(seed)
    rt_f, rt_s = _rt(backend), _rt(backend)
    bits, hs_f = _operands(rt_f, rng)
    _, hs_s = _operands(rt_s, np.random.default_rng(seed))
    fe = QueryFrontend(rt_f, window_ns=float(rng.integers(1, 6) * 1000),
                       max_batch=int(rng.integers(2, 6)))
    n_q = 12
    picks = [(EXPRS[rng.integers(len(EXPRS))],
              int(rng.integers(4)), int(rng.integers(4)))
             for _ in range(n_q)]

    serial, serial_stats = [], OpStats()
    for expr, i, j in picks:
        out = rt_s.eval(expr, {"x": hs_s[i], "y": hs_s[j]})
        serial_stats += rt_s.last_stats
        serial.append(np.asarray(rt_s.get(out).bits()))
        rt_s.free(out)

    recs = [fe.submit(f"t{k % 3}", expr, {"x": hs_f[i], "y": hs_f[j]})
            for k, (expr, i, j) in enumerate(picks)]
    fe.flush()
    done = fe.take_completed()
    assert sorted(q.seq for q in done) == list(range(n_q))
    for q in done:
        assert q.arrival_ns <= q.admitted_ns <= q.finished_ns
        assert q.latency_ns > 0
    for q, want in zip(sorted(done, key=lambda q: q.seq), serial):
        got = np.asarray(rt_f.get(q.result).bits())
        assert np.array_equal(got, want)
        rt_f.free(q.result)
    rep = fe.report()
    assert rep.completed == n_q
    assert rep.drains == rep.fill_drains + rep.deadline_drains + \
        rep.flush_drains
    assert 0 < rep.p50_ns <= rep.p99_ns <= rep.max_ns
    if backend == "ambit_sim":
        # epoch packing may change WHEN, never what the ledger sums to
        assert rep.stats.energy_nj == pytest.approx(
            serial_stats.energy_nj, rel=1e-12)
        assert rep.stats.aap_count == serial_stats.aap_count
    assert recs[0] in done


@pytest.mark.parametrize("backend", BACKENDS)
def test_frontend_matches_serial(backend):
    check_frontend_matches_serial(11, backend)


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_frontend_matches_serial_random(seed):
        check_frontend_matches_serial(seed, "ambit_sim")

else:

    @pytest.mark.parametrize("seed", range(4))
    def test_frontend_matches_serial_random(seed):
        check_frontend_matches_serial(seed, "ambit_sim")


# -- batching window: fill and deadline drains --------------------------------


def test_window_fills_then_drains():
    rng = np.random.default_rng(0)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=3)
    for k in range(2):
        fe.submit(f"t{k}", X & Y, {"x": hs[0], "y": hs[1]})
    assert not fe.take_completed()      # window below max_batch: holds
    fe.submit("t2", X | Y, {"x": hs[2], "y": hs[3]})
    done = fe.take_completed()          # third admission fills it
    assert len(done) == 3
    rep = fe.report()
    assert rep.fill_drains == 1 and rep.deadline_drains == 0


def test_deadline_drains_partial_window():
    rng = np.random.default_rng(0)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1000.0, max_batch=8)
    q = fe.submit("t0", X & Y, {"x": hs[0], "y": hs[1]}, arrival_ns=0.0)
    fe.tick(999.0)
    assert not fe.take_completed()      # window not yet aged out
    fe.tick(1000.0)
    done = fe.take_completed()
    assert done == [q]
    assert fe.report().deadline_drains == 1
    assert q.finished_ns > 1000.0       # drained at the deadline tick


def test_clock_never_runs_backwards():
    rng = np.random.default_rng(0)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=2)
    fe.submit("a", X & Y, {"x": hs[0], "y": hs[1]}, arrival_ns=5000.0)
    q = fe.submit("b", X | Y, {"x": hs[2], "y": hs[3]}, arrival_ns=10.0)
    assert q.arrival_ns == 10.0         # stale arrival is recorded as-is
    for r in fe.take_completed():
        assert r.admitted_ns >= 5000.0  # but admission uses the clock


# -- quotas: admission control without starvation -----------------------------


def test_quota_blocks_admission_not_the_queue():
    rng = np.random.default_rng(1)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=3,
                       quotas={"greedy": TenantQuota(max_inflight=1)})
    for _ in range(4):
        fe.submit("greedy", X & Y, {"x": hs[0], "y": hs[1]})
    assert fe.inflight("greedy") == 1   # quota admits exactly one
    assert len(fe.backlog) == 3
    # two polite tenants arrive AFTER greedy's backlog - and admit past
    # it, filling the window (no head-of-line starvation)
    fe.submit("p1", X | Y, {"x": hs[2], "y": hs[3]})
    fe.submit("p2", X ^ Y, {"x": hs[1], "y": hs[2]})
    done = fe.take_completed()
    assert {q.tenant for q in done} == {"greedy", "p1", "p2"}
    fe.flush()
    rest = fe.take_completed()
    assert [q.tenant for q in rest] == ["greedy"] * 3
    assert sorted(q.seq for q in rest) == [q.seq for q in rest]  # FIFO


def test_quota_releases_on_completion():
    rng = np.random.default_rng(1)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=2,
                       default_quota=TenantQuota(max_inflight=2))
    for _ in range(6):
        fe.submit("t", X & Y, {"x": hs[0], "y": hs[1]})
    fe.flush()
    assert len(fe.take_completed()) == 6
    assert fe.inflight("t") == 0 and not fe.backlog


# -- pinned working sets: tenant and store budgets ----------------------------


def test_store_pin_budget_enforced():
    rng = np.random.default_rng(2)
    rt = _rt(pin_budget_bytes=1)
    bits = rng.integers(0, 2, 120).astype(bool)
    with pytest.raises(AmbitError, match="pin budget"):
        rt.put(BitVector.from_bits(bits), pin=True)
    rbv = rt.put(BitVector.from_bits(bits))     # unpinned: fine
    with pytest.raises(AmbitError, match="pin budget"):
        rt.pin(rbv)
    assert rt.store.pinned_bytes == 0


def test_pin_budget_refunds_on_unpin_and_free():
    rng = np.random.default_rng(2)
    rt = _rt(pin_budget_bytes=1 << 20)
    _, hs = _operands(rt, rng, n=2)
    rt.pin(hs[0])
    rt.pin(hs[0])                       # idempotent: billed once
    assert rt.store.pinned_bytes == hs[0].device_bytes
    rt.pin(hs[1])
    rt.unpin(hs[0])
    assert rt.store.pinned_bytes == hs[1].device_bytes
    rt.free(hs[1])                      # free refunds the pin bill
    assert rt.store.pinned_bytes == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_tenant_pin_quota(backend):
    rng = np.random.default_rng(3)
    rt = _rt(backend)
    _, hs = _operands(rt, rng, n=3)
    nbytes = hs[0].device_bytes
    fe = QueryFrontend(rt, quotas={
        "a": TenantQuota(pin_bytes=2 * nbytes)})
    assert fe.pin_working_set("a", hs[:2]) == 2 * nbytes
    with pytest.raises(AmbitError, match="pin budget"):
        fe.pin_working_set("a", [hs[2]])
    fe.unpin_working_set("a", [hs[0]])
    assert fe.pin_working_set("a", [hs[2]]) == nbytes
    with pytest.raises(AmbitError, match="pin budget"):
        fe.pin_working_set("zero-quota", [hs[0]])   # default quota: 0 B


def test_tenant_pin_all_or_nothing_on_store_budget():
    """The tenant quota admits the set, the store budget rejects it
    mid-way: nothing stays pinned."""
    rng = np.random.default_rng(3)
    rt = _rt()
    _, hs = _operands(rt, rng, n=2)
    rt.store.pin_budget_bytes = hs[0].device_bytes      # room for one
    fe = QueryFrontend(rt, default_quota=TenantQuota(pin_bytes=1 << 20))
    with pytest.raises(AmbitError, match="pin budget"):
        fe.pin_working_set("a", hs)
    assert rt.store.pinned_bytes == 0
    assert not hs[0].pinned and not hs[1].pinned


# -- device-side popcount -----------------------------------------------------


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_device_popcount_stays_device_side(backend):
    rng = np.random.default_rng(4)
    bits = rng.integers(0, 2, 300).astype(bool)
    rt = _rt(backend)
    rbv = rt.put(BitVector.from_bits(bits))
    reads0 = rt.store.host_reads
    assert rt.popcount(rbv) == int(bits.sum())
    assert rt.last_stats.bytes_touched == 4     # one int32, not the array
    assert rt.store.host_reads == reads0 + 1


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_device_popcount_on_eval_result(backend):
    """Masked-tail contract: expression results are tail-masked on
    device, so the full-array reduction is exact (incl. NOT, whose raw
    complement would set the padding bits)."""
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (2, 77)).astype(bool)
    rt = _rt(backend)
    x, y = (rt.put(BitVector.from_bits(b)) for b in bits)
    out = rt.eval(~(X & Y), {"x": x, "y": y})
    assert rt.popcount(out) == int((~(bits[0] & bits[1])).sum())


def test_ambit_popcount_unchanged():
    rng = np.random.default_rng(6)
    bits = rng.integers(0, 2, (2, 200)).astype(bool)
    rt = _rt()
    x, y = (rt.put(BitVector.from_bits(b)) for b in bits)
    out = rt.eval(X & Y, {"x": x, "y": y})
    assert rt.popcount(out) == int((bits[0] & bits[1]).sum())
    # the DRAM model has no reduction op: the dirty result is read back
    assert rt.last_stats.bytes_touched == out.device_bytes


# -- closed-loop driver -------------------------------------------------------


def test_closed_loop_completes_and_orders_per_tenant():
    rng = np.random.default_rng(7)
    rt = _rt()
    bits, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=2000.0, max_batch=4)
    seen = {}

    def next_query(tenant, k):
        i = (hash(tenant) + k) % 3
        return EXPRS[i], {"x": hs[i], "y": hs[i + 1]}

    def on_complete(q):
        seen.setdefault(q.tenant, []).append(q.seq)
        rt.free(q.result)

    done = run_closed_loop(fe, [f"t{i}" for i in range(5)], next_query,
                           23, on_complete=on_complete)
    assert done == 23
    assert sum(len(v) for v in seen.values()) == 23
    for seqs in seen.values():          # closed loop: per-tenant FIFO
        assert seqs == sorted(seqs)
    rep = fe.report()
    assert rep.completed == 23 and rep.qps > 0 and rep.span_ns > 0


# -- report percentile edge cases + metrics snapshot (ISSUE 7) ----------------


def test_report_on_zero_completions_is_nan_free():
    """p50/p99 over an empty completion set must not raise or emit NaN:
    report() degrades to 0.0 and metrics_snapshot() reports None (JSON
    null), serialisable with allow_nan=False."""
    import json

    fe = QueryFrontend(_rt())
    rep = fe.report()
    assert rep.completed == 0
    assert rep.p50_ns == 0.0 and rep.p99_ns == 0.0
    assert rep.mean_ns == 0.0 and rep.max_ns == 0.0 and rep.qps == 0.0
    snap = fe.metrics_snapshot()
    json.dumps(snap, allow_nan=False)   # must not raise
    assert snap["serving"]["p50_ns"] is None
    assert snap["serving"]["p99_ns"] is None


def test_report_on_single_completion():
    """One completion: every percentile is that query's latency."""
    rng = np.random.default_rng(0)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=1e9, max_batch=8)
    q = fe.submit("t0", X & Y, {"x": hs[0], "y": hs[1]})
    fe.flush()
    rep = fe.report()
    assert rep.completed == 1
    assert rep.p50_ns == rep.p99_ns == rep.mean_ns == rep.max_ns \
        == q.latency_ns > 0
    snap = fe.metrics_snapshot()
    assert snap["serving"]["p50_ns"] == q.latency_ns
    assert snap["serving"]["p99_ns"] == q.latency_ns


def test_frontend_metrics_reconcile_with_report():
    """The registry's serving series are the same numbers report()
    derives - the legacy counters are views over the histogram."""
    rng = np.random.default_rng(1)
    rt = _rt()
    _, hs = _operands(rt, rng)
    fe = QueryFrontend(rt, window_ns=2000.0, max_batch=4)

    def next_query(tenant, k):
        i = (hash(tenant) + k) % 3
        return EXPRS[i], {"x": hs[i], "y": hs[i + 1]}

    run_closed_loop(fe, [f"t{i}" for i in range(4)], next_query, 17,
                    on_complete=lambda q: rt.free(q.result))
    rep = fe.report()
    m = fe.metrics
    assert m is rt.metrics              # shared registry, one namespace
    lat = m.histogram("serve_latency_ns")
    assert lat.count() == rep.completed
    assert m.counter("serve_completed").total() == rep.completed
    assert m.counter("serve_drains").total() == rep.drains
    assert m.counter("serve_admitted").total() == rep.completed
    assert lat.percentile(0.50) == rep.p50_ns
    assert lat.percentile(0.99) == rep.p99_ns


def test_serve_engine_metrics_counters():
    eng = _engine(batch_slots=2)
    reqs = [Request(prompt=np.array([5], np.int32), max_new_tokens=8,
                    eos_id=7),
            Request(prompt=np.array([1], np.int32), max_new_tokens=4)]
    eng.generate(reqs)
    m = eng.metrics
    assert m.counter("serve_prefill_batches").total() == 1
    assert m.counter("serve_decode_steps").total() == eng.decode_steps
    assert m.counter("serve_tokens_sampled").total() == \
        sum(len(r.out) for r in reqs)
    assert m.counter("serve_requests_completed").value(reason="eos") == 1
    assert m.counter("serve_requests_completed").value(
        reason="max_new_tokens") == 1
