import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set XLA_FLAGS / host device count here - smoke tests and
# benchmarks must see the single real CPU device. Multi-device tests spawn
# subprocesses that set the flag themselves (see test_distributed.py).
