import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set XLA_FLAGS / host device count here - smoke tests and
# benchmarks must see the single real CPU device. Multi-device tests spawn
# subprocesses that set the flag themselves (see test_distributed.py).

# -- ledger determinism hook --------------------------------------------------
# pim tests record the ledgers their canonical workloads produce; when
# $PIM_LEDGER_OUT is set the sorted lines are written there at session end.
# CI runs the pim shard twice under PYTHONHASHSEED=0 and diffs the two
# files: any nondeterministic placement/eviction/transfer order shows up
# as a ledger diff even when the bit-level results still agree.

_LEDGER_LINES = []


@pytest.fixture
def record_ledger():
    def _record(name: str, text: str) -> None:
        _LEDGER_LINES.append(f"{name}: {text}")
    return _record


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("PIM_LEDGER_OUT")
    if path:
        with open(path, "w") as fh:
            for line in sorted(_LEDGER_LINES):
                fh.write(line + "\n")
