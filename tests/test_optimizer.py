"""Cost-based multi-query optimizer: property + regression harness.

The optimizer rewrites WHAT executes, so its proofs are first-class:

  * canonicalization properties - idempotent, commutative-sort stable
    (PYTHONHASHSEED-independent: structural keys only), De Morgan /
    double-NOT / xor-polarity / maj-self-duality round-trips are
    semantics-preserving against the numpy oracle, and boolean-equal
    shapes hash-cons to the SAME interned node (identity is the
    equality test);
  * differential execution - ``drain(optimize=True)`` is bit-identical
    to ``drain(optimize=False)`` and to serial eval over random mixes,
    with energy/AAP/ns conservation (optimized <= unoptimized, never
    inflated) across {1,4} ambit devices and the jnp backend;
  * result-cache invalidation regressions - ``out=`` rebind into a
    cached operand, spill->fault-in (the generation must bump), and
    ``free`` of a handle backing a cache entry all make stale entries
    unreachable;
  * corrupted-DAG regressions - dependency cycles are rejected (not
    hung on), and scratch handles never leak (allocator occupancy
    returns to baseline after every optimized drain, success or
    failure);
  * the ``AmbitDevice.bbop`` staging hazard - PSM staging rows now skip
    allocator-live rows (optimizer scratch handles can land at the top
    of a full subarray right where staging used to write), and the
    sequential-fallback path still catches within-call aliasing.

Property tests run under hypothesis when installed; without it they
fall back to deterministic seeded sweeps over the same generators.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import AmbitError, BitVector, DRAMGeometry, Expr, maj
from repro.core import expr as E
from repro.core.simulator import AmbitDevice
from repro.pim import AmbitRuntime
from repro.pim.optimizer import canonicalize, n_ops, struct_key

GEOM = DRAMGeometry(rows_per_subarray=32)  # 14 data rows: compact devices
RNG = np.random.default_rng(11)

X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")
VARS = ("x", "y", "z", "w")


def rand_expr(rng, depth=0):
    if depth > 3 or rng.integers(2):
        return Expr.var(VARS[rng.integers(len(VARS))])
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a, b = rand_expr(rng, depth + 1), rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


def _rt(devices=1, banks=2, **kw):
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    kw.setdefault("seed", 3)
    return AmbitRuntime(GEOM, banks=banks, devices=devices, **kw)


# -- canonicalization properties ----------------------------------------------


def check_canonical_properties(seed):
    rng = np.random.default_rng(seed)
    e = rand_expr(rng)
    c = canonicalize(e)
    # idempotent: the canonical form is its own canonical form
    assert canonicalize(c) is c
    # semantics-preserving against the numpy oracle
    env = {v: rng.integers(0, 2, 64, dtype=np.uint8) for v in VARS}
    assert np.array_equal(E.eval_expr(e, env), E.eval_expr(c, env))
    # NOT never tops and/or in canonical form (De Morgan pushed it down)
    for node in E.topo_order(c):
        if node.op == "not":
            assert node.args[0].op not in ("and", "or", "not")
        if node.op in ("and", "or", "xor"):
            a, b = node.args
            assert struct_key(a) <= struct_key(b)


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_canonicalize_properties(seed):
        check_canonical_properties(seed)

else:

    @pytest.mark.parametrize("seed", range(60))
    def test_canonicalize_properties(seed):
        check_canonical_properties(seed)


def test_canonicalize_hash_cons_identity():
    """Boolean-equal shapes map to the SAME interned node - identity is
    the equality test the CSE keying relies on."""
    pairs = [
        ((X & Y) | Z, Z | (Y & X)),                 # commutativity
        (~(X & Y), ~X | ~Y),                        # De Morgan
        (~(X | Y), ~X & ~Y),
        (~~X, X),                                   # double NOT
        ((~X) ^ Y, ~(X ^ Y)),                       # xor polarity
        (X ^ ~Y, ~(Y ^ X)),
        (maj(~X, ~Y, ~Z), ~maj(X, Y, Z)),           # maj self-duality
        (maj(X, Y, X), X),                          # maj collapse
        ((X & Y) ^ (Y & X), E.ZERO),                # equal operands fold
    ]
    for a, b in pairs:
        assert canonicalize(a) is canonicalize(b), (a, b)
    # and different computations do NOT collide
    assert canonicalize(X & Y) is not canonicalize(X | Y)
    assert canonicalize(X ^ Y) is not canonicalize(~(X ^ Y))


def test_canonicalize_sort_is_structural_not_hash():
    """Commutative-operand order depends only on structure, so two
    processes with different PYTHONHASHSEED produce identical canonical
    forms (the opt-determinism CI job re-checks this cross-process)."""
    perms = [(X & Y) | (Y & Z), (Z & Y) | (Y & X), (Y & X) | (Y & Z)]
    cs = {id(canonicalize(p)) for p in perms}
    assert len(cs) == 1
    # struct_key is a pure function of the tree
    assert struct_key(X & Y) == ("and", "", ("var", "x"), ("var", "y"))


def test_n_ops_counts_device_ops():
    assert n_ops(X) == 0
    assert n_ops(X & Y) == 1
    assert n_ops((X & Y) | ~Z) == 3
    assert n_ops(maj(X, Y, Z)) == 1


# -- differential: optimized == unoptimized == serial -------------------------


def check_optimized_matches_unoptimized(seed, devices, backend="ambit_sim"):
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(1, 500))
    bits = rng.integers(0, 2, (4, n_bits)).astype(bool)
    queries = []
    for _ in range(int(rng.integers(3, 9))):
        e = rand_expr(rng)
        if e.op in ("var", "lit"):
            e = e ^ Y
        picks = rng.integers(0, 4, len(VARS))
        queries.append((e, picks))

    kw = {"backend": backend} if backend != "ambit_sim" else {}
    rt_o = _rt(devices=devices, seed=seed % 5, **kw)
    rt_u = _rt(devices=devices, seed=seed % 5, **kw)
    vo = [rt_o.put(BitVector.from_bits(b)) for b in bits]
    vu = [rt_u.put(BitVector.from_bits(b)) for b in bits]

    to = [rt_o.submit(e, {k: vo[p[i]] for i, k in enumerate(VARS)})
          for e, p in queries]
    tu = [rt_u.submit(e, {k: vu[p[i]] for i, k in enumerate(VARS)})
          for e, p in queries]
    assert rt_o.drain(optimize=True) == to      # submit order preserved
    rt_u.drain()
    for a, b, (e, p) in zip(to, tu, queries):
        got = np.asarray(rt_o.get(a.result).bits())
        env = {k: bits[p[i]] for i, k in enumerate(VARS)}
        want = E.eval_expr(e, env).astype(bool)     # serial numpy oracle
        assert np.array_equal(got, want[:n_bits]), (seed, e)
        assert np.array_equal(got, np.asarray(rt_u.get(b.result).bits()))
    ro, ru = rt_o.last_drain, rt_u.last_drain
    so, su = ro.stats, ru.stats
    # conservation: a rewritten program never does MORE WORK than
    # submitted - AAP count and energy (placement-independent work
    # ledgers) only shrink.  Raw ns is placement-WEIGHTED (an AAP costs
    # 54-80 ns depending on row addresses, and scratch allocations
    # shift every later row placement), so ns reduction is asserted on
    # the placement-controlled TPC-H benchmark instead, not here.
    assert so.aap_count <= su.aap_count
    assert so.energy_nj <= su.energy_nj + 1e-9
    if backend == "ambit_sim":      # accel backends have no bank ledger
        assert ro.busy_ns > 0 and ru.busy_ns > 0
    # opt_* counters reconcile with the drain's OptReport
    rep = rt_o.last_drain.opt
    m = rt_o.store.metrics
    assert m.counter("opt_cse_hits").total() == rep.cse_hits
    assert m.counter("opt_cache_misses").total() == rep.cache_misses


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.sampled_from([1, 4]))
    def test_optimized_matches_unoptimized(seed, devices):
        check_optimized_matches_unoptimized(seed, devices)

else:

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("devices", [1, 4])
    def test_optimized_matches_unoptimized(seed, devices):
        check_optimized_matches_unoptimized(seed, devices)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_optimized_matches_unoptimized_accel(backend, seed):
    check_optimized_matches_unoptimized(seed, 1, backend=backend)


def test_cse_fires_and_shares_one_materialization():
    """Three tickets sharing ``x & y`` materialize it ONCE; consumers
    reference the scratch as a DAG dependency and stay bit-exact."""
    rt = _rt()
    bits = RNG.integers(0, 2, (3, 200)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    exprs = [(X & Y) | Z, (Y & X) ^ Z, ~(X & Y)]
    ts = [rt.submit(e, dict(env)) for e in exprs]
    rt.drain(optimize=True)
    rep = rt.last_drain.opt
    assert rep.cse_materialized == 1
    assert rep.cse_hits == 2
    assert rt.store.metrics.counter("opt_cse_hits").total() == 2
    want = [bits[0] & bits[1] | bits[2], (bits[0] & bits[1]) ^ bits[2],
            ~(bits[0] & bits[1])]
    for t, w in zip(ts, want):
        assert np.array_equal(np.asarray(rt.get(t.result).bits()), w)
        assert t.rewritten_from is not None     # provenance recorded
    # the pre-rewrite expression is the submitted one
    assert ts[0].rewritten_from is exprs[0]


def test_degenerate_fold_ticket_withdraws():
    """A rewrite that would fold a ticket's whole program to a bare var
    or literal (xor of two value-equal subtrees) withdraws that ticket
    from CSE instead of leaving the planner an empty program."""
    rt = _rt()
    bits = RNG.integers(0, 2, (2, 150)).astype(bool)
    a, b = (rt.put(BitVector.from_bits(x)) for x in bits)
    env = {"x": a, "y": b}
    t1 = rt.submit((X & Y) ^ (Y & X), dict(env))    # folds to ZERO
    t2 = rt.submit((X & Y) | Y, dict(env))
    t3 = rt.submit((Y & X) | X, dict(env))
    rt.drain(optimize=True)
    assert np.array_equal(np.asarray(rt.get(t1.result).bits()),
                          np.zeros(150, bool))
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          (bits[0] & bits[1]) | bits[1])
    assert np.array_equal(np.asarray(rt.get(t3.result).bits()),
                          (bits[0] & bits[1]) | bits[0])
    assert t1.expression.op not in ("var", "lit")


# -- result cache -------------------------------------------------------------


def _cache_rt():
    rt = _rt()
    bits = RNG.integers(0, 2, (3, 180)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    return rt, bits, vs


def test_cache_serves_repeat_read_only_query():
    rt, bits, vs = _cache_rt()
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    e = (X & Y) | Z
    t1 = rt.submit(e, dict(env))
    rt.drain(optimize=True)
    base_aap = rt.last_drain.stats.aap_count
    assert base_aap > 0
    t2 = rt.submit(e, dict(env))
    rt.drain(optimize=True)
    assert t2.cache_hit
    assert rt.last_drain.stats.aap_count == 0       # nothing executed
    assert rt.last_drain.opt.cache_hits == 1
    assert rt.store.metrics.counter("opt_cache_hits").total() == 1
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          np.asarray(rt.get(t1.result).bits()))
    # a canonically-equal (not identical) expression also hits
    t3 = rt.submit(Z | (Y & X), dict(env))
    rt.drain(optimize=True)
    assert t3.cache_hit


def test_cache_misses_on_write_between_equal_reads():
    """Adversarial mix: a ticket writes an operand between two
    structurally-equal reads. The second read must MISS (the write
    bumps the operand's virtual generation inside the drain) and
    bit-exactness is preserved."""
    rt, bits, vs = _cache_rt()
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    e = (X & Y) | Z
    t1 = rt.submit(e, dict(env))
    tw = rt.submit(X ^ Y, {"x": vs[0], "y": vs[1]}, out=vs[2])
    t2 = rt.submit(e, dict(env))
    rt.drain(optimize=True)
    assert not t1.cache_hit and not t2.cache_hit
    z_new = bits[0] ^ bits[1]
    assert np.array_equal(np.asarray(rt.get(t1.result).bits()),
                          (bits[0] & bits[1]) | bits[2])
    assert np.array_equal(np.asarray(rt.get(tw.result).bits()), z_new)
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          (bits[0] & bits[1]) | z_new)
    # next drain: the POST-write value is what got cached
    t3 = rt.submit(e, dict(env))
    rt.drain(optimize=True)
    assert t3.cache_hit
    assert np.array_equal(np.asarray(rt.get(t3.result).bits()),
                          (bits[0] & bits[1]) | z_new)


def test_cache_invalidated_by_rebind_into_operand():
    """out= rebind into a cached operand drops the entry and the query
    re-executes against the new contents."""
    rt, bits, vs = _cache_rt()
    env = {"x": vs[0], "y": vs[1]}
    e = X & Y
    rt.submit(e, dict(env))
    rt.drain(optimize=True)
    assert len(rt.scheduler.optimizer.cache) == 1
    rt.submit(X | Y, {"x": vs[0], "y": vs[1]}, out=vs[1])    # rebind y
    rt.drain(optimize=True)
    assert len(rt.scheduler.optimizer.cache) == 0   # pushed invalidation
    t = rt.submit(e, dict(env))
    rt.drain(optimize=True)
    assert not t.cache_hit
    y_new = bits[0] | bits[1]
    assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                          bits[0] & y_new)


def test_cache_invalidated_by_spill_fault_in():
    """Spill->fault-in of a cached operand bumps its generation, so the
    stale key is unreachable and the entry is dropped on fault-in."""
    rt, bits, vs = _cache_rt()
    store = rt.store
    env = {"x": vs[0], "y": vs[1]}
    rt.submit(X & Y, dict(env))
    rt.drain(optimize=True)
    assert len(rt.scheduler.optimizer.cache) == 1
    g0 = store.generation(vs[0])
    store.spill(vs[0])
    store.ensure_resident(vs[0])
    assert store.generation(vs[0]) == g0 + 1        # generation bumped
    assert len(rt.scheduler.optimizer.cache) == 0
    t = rt.submit(X & Y, dict(env))
    rt.drain(optimize=True)
    assert not t.cache_hit
    assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                          bits[0] & bits[1])


def test_cache_entry_released_by_free():
    """Freeing a handle that backs a cache entry works even though the
    cache holds the result: the invalidation hook drops the entry (and
    its hold) before the held-check."""
    rt, bits, vs = _cache_rt()
    t1 = rt.submit(X & Y, {"x": vs[0], "y": vs[1]})
    rt.drain(optimize=True)
    assert len(rt.scheduler.optimizer.cache) == 1
    rt.free(t1.result)          # the cached RESULT handle
    assert len(rt.scheduler.optimizer.cache) == 0
    t2 = rt.submit(X & Y, {"x": vs[0], "y": vs[1]})
    rt.drain(optimize=True)
    assert not t2.cache_hit     # re-executed, fresh result
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          bits[0] & bits[1])
    # freeing an OPERAND of a cached entry also drops it
    assert len(rt.scheduler.optimizer.cache) == 1
    rt.free(vs[0])
    assert len(rt.scheduler.optimizer.cache) == 0


def test_cache_capacity_lru_eviction():
    rt = _rt()
    from repro.pim.optimizer import QueryOptimizer
    rt.scheduler._optimizer = QueryOptimizer(rt.scheduler,
                                             cache_capacity=2)
    bits = RNG.integers(0, 2, (2, 100)).astype(bool)
    a, b = (rt.put(BitVector.from_bits(x)) for x in bits)
    env = {"x": a, "y": b}
    for e in (X & Y, X | Y, X ^ Y):
        rt.submit(e, dict(env))
        rt.drain(optimize=True)
    assert len(rt.scheduler.optimizer.cache) == 2   # oldest evicted
    t = rt.submit(X & Y, dict(env))                 # evicted: re-runs
    rt.drain(optimize=True)
    assert not t.cache_hit


# -- corrupted DAGs and scratch lifecycle -------------------------------------


def test_dependency_cycle_rejected():
    """A corrupted ticket DAG (cycle) raises AmbitError instead of
    hanging or KeyError-ing, and every hold is released."""
    rt = _rt()
    bits = RNG.integers(0, 2, (2, 100)).astype(bool)
    a, b = (rt.put(BitVector.from_bits(x)) for x in bits)
    t1 = rt.submit(X & Y, {"x": a, "y": b})
    t2 = rt.submit(X | Y, {"x": t1, "y": b})
    rt.store.release(a)         # the corruption below orphans x's hold
    t1.env["x"] = t2            # corrupt: t1 now depends on t2
    with pytest.raises(AmbitError, match="cycle"):
        rt.drain(optimize=True)
    assert not rt.store.is_held(a) and not rt.store.is_held(b)
    # the store still works afterwards
    t3 = rt.submit(X ^ Y, {"x": a, "y": b})
    rt.drain(optimize=True)
    assert np.array_equal(np.asarray(rt.get(t3.result).bits()),
                          bits[0] ^ bits[1])


def test_scratch_handles_do_not_leak():
    """Allocator occupancy after an optimized drain equals the
    unoptimized run's: every synthetic scratch result is freed at drain
    end (the CSE rewrite introduces no lasting allocations)."""
    def occupancy(rt):
        return sum(d.allocator.live for d in
                   (getattr(rt.store, "devices", None)
                    or [rt.store.device]))

    results = []
    for optimize in (False, True):
        rt = _rt(devices=2)
        bits = RNG.integers(0, 2, (3, 300)).astype(bool)
        vs = [rt.put(BitVector.from_bits(x)) for x in bits]
        env = {"x": vs[0], "y": vs[1], "z": vs[2]}
        ts = [rt.submit(e, dict(env)) for e in
              [(X & Y) | Z, (X & Y) ^ Z, maj(X & Y, Y, Z),
               ~(X & Y) | (Y ^ Z), (Y ^ Z) & X]]
        rt.drain(optimize=optimize)
        results.append(occupancy(rt))
        for t in ts:        # freeing results+operands returns to zero
            rt.free(t.result)
        for v in vs:
            rt.free(v)
        assert occupancy(rt) == 0
    assert results[0] == results[1]


def test_failed_drain_reaps_scratch():
    """Scratch results are freed on the failure path too."""
    rt = _rt()
    bits = RNG.integers(0, 2, (2, 100)).astype(bool)
    a, b = (rt.put(BitVector.from_bits(x)) for x in bits)
    t1 = rt.submit((X & Y) | X, {"x": a, "y": b})
    t2 = rt.submit((X & Y) | Y, {"x": a, "y": b})
    t3 = rt.submit(X ^ Y, {"x": t2, "y": b})
    t3.env["x"] = t3            # self-cycle: drain fails after rewrite
    before = rt.store.device.allocator.live
    with pytest.raises(AmbitError):
        rt.drain(optimize=True)
    assert rt.store.device.allocator.live == before
    assert not rt.store.is_held(a) and not rt.store.is_held(b)


# -- bbop staging-row hazard (latent since PR 1) ------------------------------


def test_bbop_staging_skips_allocator_live_rows():
    """Regression for the scratch-row hazard: with an allocator whose
    usable region reaches the top of the D-group (scratch_rows=0 - the
    optimizer's scratch handles land wherever rows are free), PSM
    staging used to clobber live rows. The staging picker now skips
    allocator-live rows, so a victim row parked at data_rows-1
    survives a cross-subarray bbop."""
    dev = AmbitDevice(GEOM, banks=1, subarrays=2, words=2, seed=0)
    rows = GEOM.data_rows
    alloc = dev.allocator       # scratch_rows=0: all rows usable
    # fill subarray 0, then free a few mid rows: the TOP row stays live
    # (exactly where optimizer scratch lands in a tight subarray) while
    # free rows remain below it for staging to use instead
    sub0 = alloc.alloc(rows, near=[(0, 0, 0)])
    assert (0, 0, rows - 1) in {tuple(s) for s in sub0}
    rng = np.random.default_rng(7)
    data0 = rng.integers(0, 2**64, (rows, dev.words), dtype=np.uint64)
    dev.write(sub0, data0)
    alloc.free([(0, 0, r) for r in range(8, rows - 1)])
    victim = (0, 0, rows - 1)
    victim_val = dev.read([victim]).copy()
    # a bbop into subarray 0 whose source lives in subarray 1 must stage
    src = alloc.alloc(1, near=[(0, 1, 0)])
    assert src[0][:2] == (0, 1)
    src_val = rng.integers(0, 2**64, (1, dev.words), dtype=np.uint64)
    dev.write(src, src_val)
    dst = [sub0[0]]
    dev.bbop("and", dst, src, [sub0[1]])
    # the live top row was NOT used as a staging scratch
    assert np.array_equal(dev.read([victim]), victim_val)
    # and the op computed the right thing
    assert np.array_equal(dev.read(dst)[0], src_val[0] & data0[1])


def test_bbop_full_subarray_falls_back_sequentially():
    """When every data row is live the picker falls back to the legacy
    top-down staging rows; any within-call alias then forces the
    sequential path (pinned here by checking grouped == sequential on
    an aliasing mix)."""
    grouped = AmbitDevice(GEOM, banks=1, subarrays=2, words=2, seed=0)
    seq = AmbitDevice(GEOM, banks=1, subarrays=2, words=2, seed=0,
                      batch_groups=False)
    rows = GEOM.data_rows
    rng = np.random.default_rng(9)
    outs = []
    for dev in (grouped, seq):
        alloc = dev.allocator
        s0 = alloc.alloc(rows, near=[(0, 0, 0)])    # subarray 0 full
        s1 = alloc.alloc(4, near=[(0, 1, 0)])
        d0 = rng.integers(0, 2**64, (rows, dev.words), dtype=np.uint64)
        d1 = rng.integers(0, 2**64, (4, dev.words), dtype=np.uint64)
        dev.write(s0, d0)
        dev.write(s1, d1)
        # dst includes the top row = the fallback staging row: hazard
        dst = [s0[rows - 1], s0[rows - 2]]
        dev.bbop("or", dst, [s1[0], s1[1]], [s0[0], s0[1]])
        outs.append(dev.read(dst))
        rng = np.random.default_rng(9)      # same data for both devices
    assert np.array_equal(outs[0], outs[1])


def test_staging_rows_prefer_reserved_region():
    """With a scratch reservation (PimStore's default) the picker lands
    in the reserved rows first - identical to the legacy behavior, so
    existing ledgers stay byte-identical."""
    dev = AmbitDevice(GEOM, banks=1, subarrays=1, words=2, seed=0)
    from repro.pim.allocator import RowAllocator
    dev._allocator = RowAllocator.for_device(dev, scratch_rows=4)
    rows = GEOM.data_rows
    assert dev._staging_rows(0, 0, 3) == [rows - 1, rows - 2, rows - 3]


# -- optimizer observability --------------------------------------------------


def test_opt_counters_reconcile_with_ledger_deltas():
    """The opt_* metric counters advance by exactly the OptReport
    integers, and the AAP ledger saving matches recomputing the shared
    subtree per consumer."""
    rt = _rt()
    m = rt.store.metrics
    bits = RNG.integers(0, 2, (3, 200)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    ts = [rt.submit(e, dict(env)) for e in
          [(X & Y) | Z, (X & Y) ^ Z, ~(X & Y)]]
    rt.drain(optimize=True)
    rep = rt.last_drain.opt
    assert m.counter("opt_cse_hits").total() == rep.cse_hits
    assert m.counter("opt_cse_materialized").total() == rep.cse_materialized
    assert m.counter("opt_cache_misses").total() == rep.cache_misses
    assert m.counter("opt_rewrite_ns_saved").total() == pytest.approx(
        rep.ns_saved_est)
    assert rep.ns_saved_est > 0
    del ts


def _canonical_opt_session():
    """A fixed CSE+cache-heavy session; returns its conservation ledger
    and opt_* metric snapshot as one sorted text blob."""
    rt = _rt(devices=2)
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (3, 256)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    exprs = [(X & Y) | Z, (Y & X) ^ Z, ~(X & Y), maj(X & Y, Y, Z),
             (Y ^ Z) & X, ~(Z ^ Y)]
    lines = []
    for round_no in range(2):       # round 2 exercises the result cache
        ts = [rt.submit(e, dict(env)) for e in exprs]
        rt.drain(optimize=True)
        rep, st = rt.last_drain.opt, rt.last_drain.stats
        lines.append(
            f"round{round_no}: aap={st.aap_count} "
            f"energy={st.energy_nj:.3f} busy={rt.last_drain.busy_ns:.1f} "
            f"cse={rep.cse_hits}/{rep.cse_materialized} "
            f"cache={rep.cache_hits}/{rep.cache_misses} "
            f"saved={rep.ns_saved_est:.1f}")
        for t in ts:
            digest = int(np.packbits(
                np.asarray(rt.get(t.result).bits())).sum())
            lines.append(f"round{round_no} t{t.index}: "
                         f"epoch={t.epoch} hit={t.cache_hit} "
                         f"digest={digest}")
    snap = rt.store.metrics.snapshot()["counters"]
    for k in sorted(snap):
        if k.startswith("opt_"):
            lines.append(f"metric {k}={snap[k]:.1f}")
    return "\n".join(lines)


def test_optimizer_session_deterministic(record_ledger):
    """Canonicalization + value numbering + group selection must not
    depend on hash iteration order: two identical sessions produce
    byte-identical conservation ledgers and opt_* snapshots. The
    recorded ledger is byte-diffed across whole CI runs (and across
    PYTHONHASHSEED values) by the opt-determinism job."""
    a = _canonical_opt_session()
    b = _canonical_opt_session()
    assert a == b
    assert "cse=" in a and "metric opt_cse_hits=" in a
    record_ledger("pim_optimizer_session", a)


def test_optimizer_emits_trace_events():
    from repro.obs import Tracer
    rt = _rt()
    tr = Tracer()
    rt.store.tracer = tr
    rt.store.device.tracer = tr
    bits = RNG.integers(0, 2, (2, 100)).astype(bool)
    a, b = (rt.put(BitVector.from_bits(x)) for x in bits)
    env = {"x": a, "y": b}
    rt.submit((X & Y) | X, dict(env))
    rt.submit((X & Y) | Y, dict(env))
    rt.drain(optimize=True)
    cats = {e.cat for e in tr.events}
    assert "opt" in cats
    names = {e.name for e in tr.events if e.cat == "opt"}
    assert any(n.startswith("materialize#") for n in names)
    assert any(n.startswith("rewrite#") for n in names)
