"""Paper-validation tests: Table 3 reliability trend, Table 4 energy
model, Fig. 21 throughput model, timing constants."""

import numpy as np
import pytest

from repro.core import (DEFAULT_TIMING, TABLE3_PAPER, TABLE4_PAPER,
                        ddr3_energy_nj_per_kb, op_energy_nj_per_kb)
from repro.core.analog import (bitline_deviation, ideal_majority,
                               tra_failure_rate, tra_worst_case_margin)


def test_equation1_sign_follows_majority():
    """Eq 1: deviation positive iff k >= 2 of 3 cells charged (ideal)."""
    cc = np.full((1, 3), 22.0)
    cb = np.array([22.0 * 3.63])
    for k in range(4):
        charges = np.array([[1.0] * k + [0.0] * (3 - k)])
        delta = bitline_deviation(charges, cc, cb)[0]
        assert (delta > 0) == (k >= 2), (k, delta)


def test_table3_trend():
    """0 failures at <=5%; <1% at 10%; 3-10% at 15%; growing after."""
    r05 = tra_failure_rate(0.05, n_trials=30_000)
    r10 = tra_failure_rate(0.10, n_trials=30_000)
    r15 = tra_failure_rate(0.15, n_trials=30_000)
    r20 = tra_failure_rate(0.20, n_trials=30_000)
    assert r05 == 0.0
    assert 0.0 < r10 < 0.01 or r10 == 0.0
    assert 0.02 < r15 < 0.12
    assert r20 > r15
    # calibration-point agreement with the paper
    assert abs(r15 - TABLE3_PAPER[0.15]) < 0.04


def test_worst_case_margin_near_paper():
    m = tra_worst_case_margin()
    assert 0.04 < m < 0.12  # paper: ~6%


@pytest.mark.parametrize("op,paper", sorted(TABLE4_PAPER["ambit"].items()))
def test_table4_ambit_energy(op, paper):
    model = op_energy_nj_per_kb(op)
    # xnor needs one extra AAP vs the paper's grouped xor/xnor figure
    tol = 0.15 if op == "xnor" else 0.06
    assert abs(model - paper) / paper < tol, (op, model, paper)


@pytest.mark.parametrize("op", ["not", "and", "xor"])
def test_table4_ddr3_energy(op):
    model = ddr3_energy_nj_per_kb(op)
    paper = TABLE4_PAPER["ddr3"][op]
    assert abs(model - paper) / paper < 0.03


def test_energy_reduction_factors():
    """Paper headline: 25.1x-59.5x energy reduction."""
    for op in ("not", "and", "nand", "xor"):
        red = ddr3_energy_nj_per_kb(op) / op_energy_nj_per_kb(op)
        assert 20 < red < 70, (op, red)


def test_timing_constants_table1():
    assert DEFAULT_TIMING.tRAS == 35.0
    assert DEFAULT_TIMING.tRP == 15.0
    assert DEFAULT_TIMING.aap_naive_ns == 80.0


def test_fig21_throughput_ordering():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.paper_tables import CHANNEL_BW, OP_COST, \
        ambit_throughput
    for op in OP_COST:
        amb = ambit_throughput(op)
        assert amb > CHANNEL_BW["skylake"] / 2          # beats CPU
        assert amb > CHANNEL_BW["hmc"] / 3              # beats HMC/vault
    assert ambit_throughput("not") > ambit_throughput("xor")
