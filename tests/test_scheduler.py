"""Async multi-query scheduler: differential concurrency suite.

The scheduler overlaps independent queries across banks and devices; the
harness proves that overlap never changes WHAT is computed, only how time
is accounted:

  * random mixes of N queries over shared/disjoint operands are
    bit-identical to serial ``eval`` with energy/AAP conservation, and
    drain time <= serial time (equality when every query contends for
    one bank);
  * epoch formation is a deterministic function of submit order - two
    writers of one destination handle never share an epoch, dependency
    tickets execute in earlier epochs than their consumers, and two
    identical sessions produce byte-identical ledgers (the CI
    pim-determinism job re-runs this shard and diffs the recorded
    ledgers across processes / hash seeds);
  * queued-but-not-executed operands are protected from LRU eviction and
    from ``free``, and a spilled operand faulting back in during drain
    is charged to the ticket of the query that needed it.

Property tests run under hypothesis when installed; without it they fall
back to deterministic seeded sweeps over the same generators.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import AmbitError, BitVector, DRAMGeometry, Expr, maj
from repro.pim import AmbitRuntime

GEOM = DRAMGeometry(rows_per_subarray=32)  # 14 data rows: compact devices
RNG = np.random.default_rng(23)

X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")


def rand_expr(rng, depth=0):
    if depth > 2 or rng.integers(2):
        return (X, Y, Z)[rng.integers(3)]
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a, b = rand_expr(rng, depth + 1), rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


def _rt(devices=1, banks=2, **kw):
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    kw.setdefault("seed", 3)
    return AmbitRuntime(GEOM, banks=banks, devices=devices, **kw)


# -- differential concurrency suite -------------------------------------------


def check_async_matches_serial(seed, devices):
    """Random mix of queries over shared/disjoint operands: submit+drain
    must be bit-identical to serial eval of the same queries, with summed
    energy/AAPs conserved exactly and drain time <= serial time."""
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(1, 600))
    n_base = int(rng.integers(3, 6))
    n_queries = int(rng.integers(2, 6))
    bits = rng.integers(0, 2, (n_base, n_bits)).astype(bool)
    queries = []
    for _ in range(n_queries):
        expr = rand_expr(rng)
        if expr.op in ("var", "lit"):
            expr = expr ^ Y
        picks = rng.integers(0, n_base, 3)  # shared AND disjoint operands
        queries.append((expr, picks))

    rt_s = _rt(devices=devices, seed=seed % 5)
    rt_a = _rt(devices=devices, seed=seed % 5)
    vs_s = [rt_s.put(BitVector.from_bits(b)) for b in bits]
    vs_a = [rt_a.put(BitVector.from_bits(b)) for b in bits]

    serial, serial_ns, serial_e, serial_aap = [], 0.0, 0.0, 0
    for expr, picks in queries:
        out = rt_s.eval(expr, {k: vs_s[picks[i]]
                               for i, k in enumerate("xyz")})
        serial_ns += rt_s.last_stats.ns
        serial_e += rt_s.last_stats.energy_nj
        serial_aap += rt_s.last_stats.aap_count
        serial.append(np.asarray(rt_s.get(out).bits()))

    tickets = [rt_a.submit(expr, {k: vs_a[picks[i]]
                                  for i, k in enumerate("xyz")})
               for expr, picks in queries]
    assert rt_a.drain() == tickets          # stable ticket ordering
    drain = rt_a.last_drain
    for t, want in zip(tickets, serial):
        assert t.state == "done" and t.epoch >= 0
        assert np.array_equal(np.asarray(rt_a.get(t.result).bits()), want)
    # conservation: same planner calls in the same order as serial
    assert drain.stats.energy_nj == pytest.approx(serial_e, rel=1e-12)
    assert drain.stats.aap_count == serial_aap
    assert drain.serial_ns == pytest.approx(serial_ns, rel=1e-12)
    assert drain.stats.ns <= serial_ns + 1e-9


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.sampled_from([1, 3]))
    def test_async_matches_serial_random(seed, devices):
        check_async_matches_serial(seed, devices)

else:

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("devices", [1, 3])
    def test_async_matches_serial_random(seed, devices):
        check_async_matches_serial(seed, devices)


def test_single_bank_contention_equals_serial():
    """When every query contends for the one bank there is nothing to
    overlap: each epoch is a singleton and drain time == serial time."""
    rt_s = _rt(banks=1, subarrays=1, scratch_rows=2)
    rt_a = _rt(banks=1, subarrays=1, scratch_rows=2)
    bits = RNG.integers(0, 2, (2, 256)).astype(bool)
    ops_s = [rt_s.put(BitVector.from_bits(b)) for b in bits]
    ops_a = [rt_a.put(BitVector.from_bits(b)) for b in bits]
    exprs = [X & Y, X | Y, X ^ Y]
    serial_ns = 0.0
    for e in exprs:
        rt_s.eval(e, {"x": ops_s[0], "y": ops_s[1]})
        serial_ns += rt_s.last_stats.ns
    tickets = [rt_a.submit(e, {"x": ops_a[0], "y": ops_a[1]})
               for e in exprs]
    rt_a.drain()
    assert [t.epoch for t in tickets] == [0, 1, 2]
    assert rt_a.last_drain.stats.ns == pytest.approx(serial_ns)


def test_disjoint_banks_share_one_epoch():
    """Queries whose operands occupy disjoint banks run in ONE epoch:
    drain time is the max over the queries, not the sum."""
    n_queries = 4
    rt = _rt(banks=n_queries, subarrays=2)
    tickets = []
    for q in range(n_queries):
        bits = RNG.integers(0, 2, (2, 2 * 128)).astype(bool)
        a = rt.put(BitVector.from_bits(bits[0]),
                   near=[(q, s, 0) for s in range(2)])
        b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
        tickets.append((rt.submit(X & Y, {"x": a, "y": b}), bits))
    rt.drain()
    drain = rt.last_drain
    assert [t.epoch for t, _ in tickets] == [0] * n_queries
    assert len(drain.epochs) == 1
    per_query = [t.stats.ns for t, _ in tickets]
    assert drain.stats.ns == pytest.approx(max(per_query))
    assert drain.serial_ns == pytest.approx(sum(per_query))
    for t, bits in tickets:
        assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                              bits[0] & bits[1])


def test_cluster_disjoint_devices_share_one_epoch():
    """Device-level epoch admission: queries pinned to different cluster
    devices overlap even when they use the same bank indices."""
    rt = _rt(devices=3, banks=2)
    tickets = []
    for q in range(3):
        bits = RNG.integers(0, 2, (2, 2 * 128)).astype(bool)
        near = [(q, (i % 2, 0, 0)) for i in range(2)]  # chunk-aligned
        a = rt.put(BitVector.from_bits(bits[0]), near=near)
        b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
        tickets.append((rt.submit(X ^ Y, {"x": a, "y": b}), bits))
    rt.drain()
    assert [t.epoch for t, _ in tickets] == [0, 0, 0]
    for t, bits in tickets:
        assert {d for d, _ in t.result.slots} <= {tickets.index((t, bits))}
        assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                              bits[0] ^ bits[1])


# -- epoch formation properties -----------------------------------------------


def test_same_destination_never_shares_epoch():
    """Two queries writing the same ``out=`` handle are write-write
    conflicts: they never share an epoch, execute in submit order (last
    write wins), and the destination handle keeps its identity."""
    rt = _rt(banks=4)
    bits = RNG.integers(0, 2, (3, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]),
               near=[(0, 0, 0), (0, 1, 0)])
    b = rt.put(BitVector.from_bits(bits[1]),
               near=[(1, 0, 0), (1, 1, 0)])
    o = rt.put(BitVector.from_bits(bits[2]),
               near=[(2, 0, 0), (2, 1, 0)])
    t1 = rt.submit(~X, {"x": a}, out=o)
    t2 = rt.submit(~X, {"x": b}, out=o)
    rt.drain()
    assert t1.epoch != t2.epoch and t1.epoch < t2.epoch
    assert t1.result is o and t2.result is o
    assert np.array_equal(np.asarray(rt.get(o).bits()), ~bits[1])


def test_reader_of_out_handle_orders_before_writer():
    """A query reading a handle that a later query overwrites via out=
    must land in an earlier epoch (no read-write epoch sharing)."""
    rt = _rt(banks=4)
    bits = RNG.integers(0, 2, (2, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]),
               near=[(0, 0, 0), (0, 1, 0)])
    b = rt.put(BitVector.from_bits(bits[1]),
               near=[(1, 0, 0), (1, 1, 0)])
    t_read = rt.submit(~X, {"x": a})
    t_write = rt.submit(~X, {"x": b}, out=a)
    rt.drain()
    assert t_read.epoch < t_write.epoch
    assert np.array_equal(np.asarray(rt.get(t_read.result).bits()),
                          ~bits[0])
    assert np.array_equal(np.asarray(rt.get(a).bits()), ~bits[1])


def test_ticket_dependency_orders_epochs():
    """A query consuming an earlier ticket's result (multi-root DAG in
    one drain) executes in a strictly later epoch."""
    rt = _rt(banks=2)
    bits = RNG.integers(0, 2, (3, 2 * 128)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    t1 = rt.submit(X & Y, {"x": vs[0], "y": vs[1]})
    t2 = rt.submit(X ^ Y, {"x": t1, "y": vs[2]})
    rt.drain()
    assert t1.epoch < t2.epoch
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          (bits[0] & bits[1]) ^ bits[2])


def _canonical_session():
    """Fixed async session used for determinism checks: a mix of
    bank-disjoint, shared-operand, dependent and out= queries."""
    rt = _rt(banks=4, seed=7)
    rng = np.random.default_rng(29)
    bits = rng.integers(0, 2, (5, 2 * 128)).astype(bool)
    vs = []
    for q in range(4):
        vs.append(rt.put(BitVector.from_bits(bits[q]),
                         near=[(q, s, 0) for s in range(2)]))
    o = rt.put(BitVector.from_bits(bits[4]), near=vs[0].slots)
    t = [rt.submit(X & Y, {"x": vs[0], "y": vs[1]}),
         rt.submit(X | Y, {"x": vs[2], "y": vs[3]}),
         rt.submit(~X, {"x": vs[1]}, out=o)]
    t.append(rt.submit(X ^ Y, {"x": t[0], "y": t[1]}))
    rt.drain()
    return rt, t


def _ledger_text(rt, tickets):
    d = rt.last_drain
    epochs = [(e.ns, e.channel_ns, tuple(e.tickets), tuple(e.resources))
              for e in d.epochs]
    return (f"epochs={epochs} stats={d.stats!r} serial={d.serial_ns!r} "
            f"assign={[t.epoch for t in tickets]}")


def test_epoch_formation_deterministic(record_ledger):
    """Submit order is the only tiebreak: two identical sessions produce
    identical epoch schedules and ledgers. The recorded ledger is also
    diffed across two whole CI runs (PYTHONHASHSEED sweep) by the
    pim-determinism job."""
    a = _ledger_text(*_canonical_session())
    b = _ledger_text(*_canonical_session())
    assert a == b
    record_ledger("pim_scheduler_session", a)


def test_per_bank_report_is_conservation_exact():
    """The planner's per-bank ledger deltas decompose the merged report:
    summed energy equals the merged energy, max ns equals the merged ns."""
    rt = _rt(banks=2, colocate=False)
    bits = RNG.integers(0, 2, (2, 4 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    rt.and_(a, b)
    rep = rt.planner.last_report
    assert len(rep.per_bank) == 2
    assert sum(st.energy_nj for st in rep.per_bank.values()) == \
        pytest.approx(rep.stats.energy_nj)
    assert sum(st.aap_count for st in rep.per_bank.values()) == \
        rep.stats.aap_count
    assert max(st.ns for st in rep.per_bank.values()) == \
        pytest.approx(rep.stats.ns)


# -- queueing vs spill/eviction -----------------------------------------------


def _tiny_rt():
    """1 bank x 1 subarray x 12 usable rows."""
    return _rt(banks=1, subarrays=1, scratch_rows=2, seed=5)


def _bv(n_chunks):
    return BitVector.from_bits(
        RNG.integers(0, 2, n_chunks * 128).astype(bool))


def test_queued_operands_are_not_evicted():
    """A queued-but-not-yet-executed operand must survive evictions
    forced by earlier queries in the same drain: the LRU skips held
    handles and picks an unqueued victim instead."""
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (4, 2 * 128)).astype(bool)
    c = rt.put(BitVector.from_bits(bits[0]))     # LRU: would be victim
    d = rt.put(BitVector.from_bits(bits[1]))
    cold = rt.put(_bv(4))                        # the only evictable rows
    a = rt.put(BitVector.from_bits(bits[2]))
    b = rt.put(BitVector.from_bits(bits[3]), near=a.slots)  # 12/12 live
    t1 = rt.submit(X & Y, {"x": a, "y": b})      # dst rows force eviction
    t2 = rt.submit(X ^ Y, {"x": c, "y": d})
    rt.drain()
    assert cold.spilled
    assert not c.spilled and not d.spilled
    assert np.array_equal(np.asarray(rt.get(t1.result).bits()),
                          bits[2] & bits[3])
    assert np.array_equal(np.asarray(rt.get(t2.result).bits()),
                          bits[0] ^ bits[1])


def test_queued_operand_cannot_be_freed_or_spilled():
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (2, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    t = rt.submit(X | Y, {"x": a, "y": b})
    with pytest.raises(AmbitError, match="queued"):
        rt.free(a)
    with pytest.raises(AmbitError, match="queued"):
        rt.store.spill(b)
    rt.drain()
    assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                          bits[0] | bits[1])
    rt.free(a)                                  # released after execution


def test_spilled_operand_fault_in_charged_to_its_ticket():
    """An operand spilled BEFORE submit faults back in during drain; the
    upload bytes land on that query's ticket, not on the drain at large."""
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (2, 4 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]))
    f = rt.put(_bv(4))                           # 12/12: device full
    g = rt.put(_bv(4))                           # evicts the LRU: a
    assert a.spilled and not b.spilled
    t_cheap = rt.submit(~X, {"x": g})            # no fault-in needed
    t_fault = rt.submit(X & Y, {"x": a, "y": b})
    rt.drain()
    assert not a.spilled
    assert t_cheap.stats.bytes_touched == 0
    assert t_fault.stats.bytes_touched >= a.device_bytes
    assert np.array_equal(np.asarray(rt.get(t_fault.result).bits()),
                          bits[0] & bits[1])
    assert not f.freed                           # spilled, still usable


def test_failed_submit_releases_partial_holds():
    """A submit that fails validation mid-way (here: a non-resident
    operand sorting after a valid one) must roll back the holds it
    already took - the valid operand stays freeable."""
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (1, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    with pytest.raises(TypeError, match="not resident"):
        rt.submit(X & Y, {"a": a, "b": BitVector.from_bits(bits[0])})
    rt.free(a)                                   # no hold leaked


def test_failed_epoch_formation_releases_holds():
    """A drain that dies in epoch formation (a consumer of a cancelled
    ticket) must release every queued hold and mark the dropped tickets,
    not leak them in a never-drainable limbo."""
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (3, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    c = rt.put(BitVector.from_bits(bits[2]), near=a.slots)
    t1 = rt.submit(X & Y, {"x": a, "y": b})
    t2 = rt.submit(X ^ Y, {"x": t1, "y": c})
    rt.scheduler.cancel(t1)
    with pytest.raises(AmbitError, match="cancelled"):
        rt.drain()
    assert t2.state in ("failed", "cancelled")
    rt.free(a), rt.free(b), rt.free(c)           # all holds released
    assert rt.drain() == []                      # queue fully drained


def test_cancel_releases_holds():
    rt = _tiny_rt()
    bits = RNG.integers(0, 2, (2, 2 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    t = rt.scheduler.submit(X & Y, {"x": a, "y": b})
    rt.scheduler.cancel(t)
    assert t.state == "cancelled"
    rt.free(a)                                   # holds released
    assert rt.drain() == []


# -- optimized drain: rewrite never changes WHAT is computed ------------------


def check_optimized_drain_matches_serial(seed, devices):
    """drain(optimize=True) over a random mix is bit-identical to serial
    eval AND to drain(optimize=False), with the rewritten program doing
    no more work (AAPs/energy) than the submitted one."""
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(1, 600))
    n_base = int(rng.integers(3, 6))
    bits = rng.integers(0, 2, (n_base, n_bits)).astype(bool)
    queries = []
    for _ in range(int(rng.integers(2, 7))):
        expr = rand_expr(rng)
        if expr.op in ("var", "lit"):
            expr = expr ^ Y
        queries.append((expr, rng.integers(0, n_base, 3)))

    rt_s = _rt(devices=devices, seed=seed % 5)
    rt_o = _rt(devices=devices, seed=seed % 5)
    vs_s = [rt_s.put(BitVector.from_bits(b)) for b in bits]
    vs_o = [rt_o.put(BitVector.from_bits(b)) for b in bits]

    serial, serial_e, serial_aap = [], 0.0, 0
    for expr, picks in queries:
        out = rt_s.eval(expr, {k: vs_s[picks[i]]
                               for i, k in enumerate("xyz")})
        serial_e += rt_s.last_stats.energy_nj
        serial_aap += rt_s.last_stats.aap_count
        serial.append(np.asarray(rt_s.get(out).bits()))

    tickets = [rt_o.submit(expr, {k: vs_o[picks[i]]
                                  for i, k in enumerate("xyz")})
               for expr, picks in queries]
    assert rt_o.drain(optimize=True) == tickets
    for t, want in zip(tickets, serial):
        assert t.state == "done"
        assert np.array_equal(np.asarray(rt_o.get(t.result).bits()), want)
    drain = rt_o.last_drain
    assert drain.opt is not None
    # work conservation: the rewrite only ever REMOVES device ops
    assert drain.stats.aap_count <= serial_aap
    assert drain.stats.energy_nj <= serial_e + 1e-9


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.sampled_from([1, 3]))
    def test_optimized_drain_matches_serial_random(seed, devices):
        check_optimized_drain_matches_serial(seed, devices)

else:

    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("devices", [1, 3])
    def test_optimized_drain_matches_serial_random(seed, devices):
        check_optimized_drain_matches_serial(seed, devices)


def test_optimized_drain_cse_must_fire():
    """A mix built to share a subtree MUST report CSE activity (the
    metric is load-bearing: CI byte-diffs it across hash seeds), while
    staying bit-identical to the unoptimized drain."""
    rt_o, rt_u = _rt(), _rt()
    bits = RNG.integers(0, 2, (3, 256)).astype(bool)
    exprs = [(X & Y) | Z, (Y & X) ^ Z, ~(X & Y), maj(X & Y, Y, Z)]
    results = []
    for rt, opt in ((rt_o, True), (rt_u, False)):
        vs = [rt.put(BitVector.from_bits(b)) for b in bits]
        env = {"x": vs[0], "y": vs[1], "z": vs[2]}
        ts = [rt.submit(e, dict(env)) for e in exprs]
        rt.drain(optimize=opt)
        results.append([np.asarray(rt.get(t.result).bits()) for t in ts])
    for a, b in zip(*results):
        assert np.array_equal(a, b)
    rep = rt_o.last_drain.opt
    assert rep.cse_hits > 0 and rep.cse_materialized >= 1
    assert rt_o.store.metrics.counter("opt_cse_hits").total() == \
        rep.cse_hits
    assert rt_o.last_drain.stats.aap_count < \
        rt_u.last_drain.stats.aap_count


def test_optimized_drain_write_read_interleave_bit_exact():
    """Adversarial mix for the result cache: a write lands between two
    structurally-equal reads in ONE drain. The rewrite must neither
    serve the second read stale nor reorder it before the write."""
    rt = _rt()
    bits = RNG.integers(0, 2, (3, 200)).astype(bool)
    vs = [rt.put(BitVector.from_bits(b)) for b in bits]
    env = {"x": vs[0], "y": vs[1], "z": vs[2]}
    r1 = rt.submit((X | Y) & Z, dict(env))
    w = rt.submit(X ^ Z, {"x": vs[0], "z": vs[2]}, out=vs[1])
    r2 = rt.submit((Y | X) & Z, dict(env))      # equal modulo commute
    rt.drain(optimize=True)
    assert not r1.cache_hit and not r2.cache_hit
    y_new = bits[0] ^ bits[2]
    assert np.array_equal(np.asarray(rt.get(r1.result).bits()),
                          (bits[0] | bits[1]) & bits[2])
    assert np.array_equal(np.asarray(rt.get(r2.result).bits()),
                          (bits[0] | y_new) & bits[2])
    # epoch ordering kept the writer strictly between the readers
    assert r1.epoch <= w.epoch <= r2.epoch
    assert r1.epoch < r2.epoch
