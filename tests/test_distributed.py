"""Multi-device distribution tests. Each test runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps the single real CPU device (see conftest note)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mesh_and_sharded_train_step():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.param import ShardingRules
        from repro.models.sharding_ctx import axis_rules
        from repro.launch.mesh import make_host_mesh, mesh_shape_dict
        from repro.optim.optimizer import OptimizerConfig
        from repro.train.step import init_state, make_train_step
        from repro.models.param import map_tree

        mesh = make_host_mesh(data=2, model=4)
        ms = mesh_shape_dict(mesh)
        cfg = get_config("qwen2.5-3b").reduced()
        model = build_model(cfg)
        rules = ShardingRules()
        pspecs = model.param_specs(rules, ms)
        state = init_state(model, jax.random.PRNGKey(0))
        shard = lambda t: map_tree(lambda s: NamedSharding(mesh, s), t)
        sspec = {"params": shard(pspecs),
                 "opt": {"m": shard(pspecs), "v": shard(pspecs),
                         "step": NamedSharding(mesh, P())}}
        state = jax.device_put(state, sspec)
        step = make_train_step(model, OptimizerConfig(total_steps=5),
                               mesh=mesh, remat=True)
        toks = jnp.zeros((4, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        with mesh, axis_rules(rules, ms):
            state2, m = jax.jit(step)(state, batch)
        assert np.isfinite(float(m["loss"]))
        print("sharded-train-ok", float(m["loss"]))
    """))


def test_moe_shard_map_matches_single_device():
    print(run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("granite-moe-3b-a800m").reduced()
        # High capacity factor: token drops depend on the LOCAL token count
        # (per-shard capacity), so exact parity only holds drop-free.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab)
        ref_logits, ref_aux = model.forward(params, {"tokens": toks})
        mesh = make_host_mesh(data=2, model=4)
        with mesh:
            got_logits, got_aux = jax.jit(
                lambda p, b: model.forward(p, b, mesh=mesh)
            )(params, {"tokens": toks})
        err = float(jnp.max(jnp.abs(got_logits.astype(jnp.float32) -
                                    ref_logits.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
        assert err / scale < 0.1, (err, scale)
        print("moe-ep-parity-ok", err / scale)
    """))


def test_elastic_restore_across_mesh_change():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.param import ShardingRules, map_tree
        from repro.launch.mesh import make_host_mesh, mesh_shape_dict

        cfg = get_config("qwen2.5-3b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        mesh8 = make_host_mesh(data=2, model=4)
        specs8 = model.param_specs(ShardingRules(),
                                   mesh_shape_dict(mesh8))
        sharded = jax.device_put(params, map_tree(
            lambda s: NamedSharding(mesh8, s), specs8))
        ck.save(3, {"params": sharded}, blocking=True)

        # "lose half the hosts": restore onto a 4-device mesh
        mesh4 = make_host_mesh(data=1, model=4)
        specs4 = model.param_specs(ShardingRules(),
                                   mesh_shape_dict(mesh4))
        step, tree = ck.restore(
            mesh=mesh4, spec_tree={"params": specs4})
        assert step == 3
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(tree["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("elastic-restore-ok")
    """))


def test_dryrun_tiny_cell_multi_device():
    """End-to-end dry-run machinery on an 8-device (2,4) mesh with a
    reduced config: lower+compile+analyses must all work."""
    print(run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.param import ShardingRules, map_tree
        from repro.models.sharding_ctx import axis_rules
        from repro.launch.mesh import make_host_mesh, mesh_shape_dict
        from repro.launch.hloparse import (collective_bytes, dot_flops,
                                           traffic_bytes)
        from repro.optim.optimizer import OptimizerConfig
        from repro.train.step import make_train_step

        mesh = make_host_mesh(data=2, model=4)
        ms = mesh_shape_dict(mesh)
        cfg = get_config("gemma3-1b").reduced()
        model = build_model(cfg)
        rules = ShardingRules()
        pspecs = model.param_specs(rules, ms)
        pshapes = model.param_shapes()
        step = make_train_step(model, OptimizerConfig(), mesh=mesh)
        state_shapes = {"params": pshapes,
                        "opt": {"m": pshapes, "v": pshapes,
                                "step": jax.ShapeDtypeStruct((), jnp.int32)}}
        sh = lambda t: map_tree(lambda s: NamedSharding(mesh, s), t)
        state_sh = {"params": sh(pspecs),
                    "opt": {"m": sh(pspecs), "v": sh(pspecs),
                            "step": NamedSharding(mesh, P())}}
        batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
        bsh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
        with mesh, axis_rules(rules, ms):
            compiled = jax.jit(step, in_shardings=(state_sh, bsh)).lower(
                state_shapes, batch).compile()
        hlo = compiled.as_text()
        fl = dot_flops(hlo)
        tb = traffic_bytes(hlo)
        cb, kinds = collective_bytes(hlo)
        assert fl > 0 and tb > 0 and cb > 0, (fl, tb, cb)
        assert compiled.memory_analysis() is not None
        print("tiny-dryrun-ok", fl, tb, cb, sorted(kinds))
    """))


def test_moe_ep2d_matches_single_device():
    """2D expert-parallel serving path (weights stationary, tokens
    gathered) == single-device reference, drop-free."""
    print(run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("qwen3-moe-235b-a22b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0,
                                         pad_to=8))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab)
        ref_logits, _ = model.forward(params, {"tokens": toks})
        mesh = make_host_mesh(data=2, model=4)  # data*model = 8 = pad_to
        with mesh:
            got_logits, _ = jax.jit(
                lambda p, b: model.forward(p, b, mesh=mesh)
            )(params, {"tokens": toks})
        err = float(jnp.max(jnp.abs(got_logits.astype(jnp.float32) -
                                    ref_logits.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
        assert err / scale < 0.1, (err, scale)
        print("moe-ep2d-parity-ok", err / scale)
    """))


def test_pipeline_parallelism_matches_sequential():
    """GPipe pipeline over 4 stages == sequential layer application, and
    gradients flow through the schedule (training-compatible)."""
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import bubble_fraction, pipeline

        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        n_stages, n_micro, mb, d = 4, 6, 2, 8
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.3)
        b = jnp.asarray(rng.normal(size=(n_stages, d)) * 0.1)
        params = {"w": w, "b": b}
        x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

        def stage(p, h):
            return jax.nn.tanh(h @ p["w"] + p["b"])

        got = pipeline(stage, params, x, mesh, axis="pod")
        want = x
        for s in range(n_stages):
            ps = jax.tree.map(lambda a, s=s: a[s], params)
            want = jax.vmap(lambda h: stage(ps, h))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

        # differentiability: grad of a scalar loss wrt stage params
        def loss(p):
            return jnp.sum(pipeline(stage, p, x, mesh, axis="pod") ** 2)
        g = jax.grad(loss)(params)
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(g))
        assert float(jnp.abs(g["w"]).sum()) > 0
        assert abs(bubble_fraction(6, 4) - 3/9) < 1e-9
        print("pipeline-ok")
    """))


def test_launch_train_driver_multi_device():
    """The production train driver end-to-end on a (2,4) mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "qwen2.5-3b", "--reduced", "--steps", "6", "--batch", "4",
         "--seq", "32", "--data-parallel", "2", "--model-parallel", "4",
         "--ckpt-dir", "/tmp/launch_train_test_ckpt"],
        capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "loss" in out.stdout
    print(out.stdout)
