"""Accelerator-resident DeviceStore: differential + lifecycle suite.

The DeviceStore keeps operands on the accelerator across calls the way
PimStore keeps rows in simulated DRAM. The harness proves three things:

  * residency never changes WHAT is computed - random expression trees
    and chains over the resident path are bit-identical to the
    non-resident engine and to the ambit_sim device model, on both
    performance backends;
  * the ledger is honest - resident operands touch zero host bytes, only
    uploads/read-backs/spills/fault-ins are charged, and a drain's bytes
    accounting is identical to serial eval of the same queries;
  * multi-query drains fuse - an epoch of shape-compatible queries is
    ONE stacked kernel launch (call-count probe), with results identical
    to serial evaluation.

Property tests run under hypothesis when installed; without it they fall
back to deterministic seeded sweeps over the same generators.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import AmbitError, BitVector, BulkBitwiseEngine, Expr, maj
from repro.core.engine import OpStats, device_compile_cache_info
from repro.kernels import ops as kops
from repro.pim import AmbitRuntime, DeviceStore

BACKENDS = ("jnp", "pallas")
RNG = np.random.default_rng(47)

X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")


def rand_expr(rng, depth=0):
    if depth > 2 or rng.integers(2):
        return (X, Y, Z)[rng.integers(3)]
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a, b = rand_expr(rng, depth + 1), rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


# -- differential: resident == non-resident == ambit_sim ----------------------


def check_resident_matches_engines(seed, backend):
    """Random exprs + a dependent chain: the DeviceStore path must be
    bit-identical to the non-resident engine (same backend) and to the
    ambit_sim device model, with ZERO host bytes for resident operands."""
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(1, 700))
    rows = () if rng.integers(2) else (int(rng.integers(1, 4)),)
    bits = rng.integers(0, 2, (3,) + rows + (n_bits,)).astype(bool)
    vecs = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xyz")}

    rt = AmbitRuntime(backend=backend)
    hs = {k: rt.put(v) for k, v in vecs.items()}
    host_eng = BulkBitwiseEngine(backend)
    sim_eng = BulkBitwiseEngine("ambit_sim")

    for _ in range(3):
        expr = rand_expr(rng)
        if expr.op in ("var", "lit"):
            expr = expr ^ Y
        out = rt.eval(expr, hs)
        assert rt.last_stats.bytes_touched == 0     # fully resident
        got = np.asarray(rt.get(out).bits())
        want_host = np.asarray(host_eng.eval(expr, vecs).bits())
        want_sim = np.asarray(sim_eng.eval(expr, vecs).bits())
        assert np.array_equal(got, want_host), (backend, expr)
        assert np.array_equal(want_host, want_sim), expr
        rt.free(out)

    # dependent chain: intermediates never cross the channel
    reads0 = rt.store.host_reads
    acc = rt.eval(X ^ Y, {"x": hs["x"], "y": hs["y"]})
    for _ in range(3):
        acc = rt.eval(X & Y, {"x": acc, "y": hs["z"]})
    assert rt.store.host_reads == reads0
    want = np.asarray(vecs["x"].bits()) ^ np.asarray(vecs["y"].bits())
    for _ in range(3):
        want = want & np.asarray(vecs["z"].bits())
    assert np.array_equal(np.asarray(rt.get(acc).bits()), want)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.sampled_from(BACKENDS))
    def test_resident_matches_engines_random(seed, backend):
        check_resident_matches_engines(seed, backend)

else:

    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_resident_matches_engines_random(seed, backend):
        check_resident_matches_engines(seed, backend)


# -- multi-query drain: fused epochs ------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_drain_bit_identical_with_identical_bytes(backend):
    """submit+drain of a query mix == serial eval: same bits, same bytes
    accounting (both charge only fault-ins; here: none)."""
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (4, 500)).astype(bool)
    queries = [(X & Y, (0, 1)), (X ^ Y, (2, 3)),
               (~X, (1, 1)), (maj(X, Y, Z), (0, 2))]

    rt_s = AmbitRuntime(backend=backend)
    rt_a = AmbitRuntime(backend=backend)
    vs_s = [rt_s.put(BitVector.from_bits(b)) for b in bits]
    vs_a = [rt_a.put(BitVector.from_bits(b)) for b in bits]

    def env_for(expr, picks, vs):
        full = {k: vs[picks[i % len(picks)]] for i, k in enumerate("xyz")}
        return {nm: full[nm] for nm in sorted(full)
                if Expr.var(nm) in _vars(expr)}

    serial, serial_bytes = [], 0
    for expr, picks in queries:
        out = rt_s.eval(expr, env_for(expr, picks, vs_s))
        serial_bytes += rt_s.last_stats.bytes_touched
        serial.append(np.asarray(rt_s.get(out).bits()))

    tickets = [rt_a.submit(expr, env_for(expr, picks, vs_a))
               for expr, picks in queries]
    rt_a.drain()
    drain_bytes = rt_a.last_drain.stats.bytes_touched
    assert drain_bytes == serial_bytes == 0
    for t, want in zip(tickets, serial):
        assert t.state == "done"
        assert np.array_equal(np.asarray(rt_a.get(t.result).bits()), want)


def _vars(expr):
    seen = set()

    def walk(e):
        if e.op == "var":
            seen.add(e)
        for a in e.args:
            walk(a)
    walk(expr)
    return seen


def test_pallas_drain_launches_one_kernel_per_epoch():
    """The acceptance probe: shape-compatible same-expression queries
    drain as ONE epoch = ONE stacked pallas dispatch; a different
    expression forces a second epoch = a second dispatch."""
    rng = np.random.default_rng(9)
    rt = AmbitRuntime(backend="pallas")
    bits = rng.integers(0, 2, (4, 2, 300)).astype(bool)
    envs = []
    for q in range(4):
        a = rt.put(BitVector.from_bits(bits[q, 0]))
        b = rt.put(BitVector.from_bits(bits[q, 1]))
        envs.append({"x": a, "y": b})
    kops.fused_dispatch_reset()
    launches0 = rt.planner.kernel_launches
    tickets = [rt.submit(X & Y, env) for env in envs]
    odd = rt.submit(X | Y, envs[0])          # different expr: new epoch
    rt.drain()
    assert len(rt.last_drain.epochs) == 2
    assert [t.epoch for t in tickets] == [0, 0, 0, 0] and odd.epoch == 1
    assert rt.planner.kernel_launches - launches0 == 2
    assert kops.fused_dispatch_count() == 2  # one pallas_call per epoch
    for t, b in zip(tickets, bits):
        assert np.array_equal(np.asarray(rt.get(t.result).bits()),
                              b[0] & b[1])
    assert np.array_equal(np.asarray(rt.get(odd.result).bits()),
                          bits[0, 0] | bits[0, 1])


def test_stacked_kernel_matches_per_query():
    """ops.bitwise_eval_stacked == one bitwise_eval per environment."""
    rng = np.random.default_rng(3)
    expr = (X & Y) | ~X
    envs = [{nm: rng.integers(0, 2**32, (5, 40), dtype=np.uint32)
             for nm in ("x", "y")} for _ in range(3)]
    got = kops.bitwise_eval_stacked(expr, ("x", "y"), envs)
    for g, env in zip(got, envs):
        want = kops.bitwise_eval(expr, env)
        assert np.array_equal(np.asarray(g), np.asarray(want))


def test_drain_dependency_and_out_rebind():
    """Ticket deps execute in earlier epochs; out= rebinds preserve the
    destination handle's identity (device-buffer move, no copy)."""
    rng = np.random.default_rng(11)
    rt = AmbitRuntime(backend="pallas")
    bits = rng.integers(0, 2, (3, 260)).astype(bool)
    a, b, o = (rt.put(BitVector.from_bits(x)) for x in bits)
    t1 = rt.submit(X & Y, {"x": a, "y": b})
    t2 = rt.submit(X ^ Y, {"x": t1, "y": a}, out=o)
    rt.drain()
    assert t1.epoch < t2.epoch
    assert t2.result is o and o.dirty
    want = (bits[0] & bits[1]) ^ bits[0]
    assert np.array_equal(np.asarray(rt.get(o).bits()), want)


# -- lifecycle: capacity budget, spill, pin -----------------------------------


def _nb_bytes(n_bits):
    return BitVector.from_bits(np.zeros(n_bits, bool)).nbytes


@pytest.mark.parametrize("backend", BACKENDS)
def test_capacity_budget_spills_lru_clean_for_free(backend):
    nb = 1024                                # 512 B packed
    rt = AmbitRuntime(backend=backend, capacity_bytes=2 * _nb_bytes(nb))
    bits = RNG.integers(0, 2, (3, nb)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]))
    c = rt.put(BitVector.from_bits(bits[2]))
    assert a.spilled and not b.spilled and not c.spilled
    assert rt.store.evicted_clean == 1 and rt.store.bytes_from_device == 0
    assert np.array_equal(np.asarray(rt.get(a).bits()), bits[0])  # free
    # eval over the spilled operand faults it back in, charged to the call
    out = rt.eval(X ^ Y, {"x": a, "y": c})
    assert rt.last_stats.bytes_touched >= a.device_bytes
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] ^ bits[2])


def test_dirty_spill_reads_back_through_ledger():
    nb = 1024
    rt = AmbitRuntime(backend="jnp", capacity_bytes=3 * _nb_bytes(nb))
    bits = RNG.integers(0, 2, (2, nb)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]))
    out = rt.and_(a, b)                      # dirty result, store full
    rt.get(a), rt.get(b)                     # free touches: out is LRU
    down0 = rt.store.bytes_from_device
    rt.put(BitVector.from_bits(bits[0]))     # evicts out: dirty read-back
    assert out.spilled
    assert rt.store.evicted_dirty == 1
    assert rt.store.bytes_from_device - down0 == out.device_bytes
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] & bits[1])


def test_pinned_never_evicted_and_held_faults_back():
    """Pinned handles are never victims (a full device raises instead);
    a held (queued) operand spills only as a capacity-pressure last
    resort and faults back in at drain, charged to its ticket."""
    nb = 1024
    rt = AmbitRuntime(backend="jnp", capacity_bytes=2 * _nb_bytes(nb))
    bits = RNG.integers(0, 2, (3, nb)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]), pin=True)
    b = rt.put(BitVector.from_bits(bits[1]))
    t = rt.submit(~X, {"x": b})              # b held by the queue
    with pytest.raises(AmbitError, match="queued"):
        rt.free(b)
    rt.put(BitVector.from_bits(bits[2]))     # forces the held spill of b
    assert b.spilled and not a.spilled       # pinned a survived
    rt.drain()
    assert t.stats.bytes_touched >= b.device_bytes  # fault-in charged
    assert np.array_equal(np.asarray(rt.get(t.result).bits()), ~bits[1])
    # with everything pinned, capacity pressure must raise, not evict
    rt2 = AmbitRuntime(backend="jnp", capacity_bytes=_nb_bytes(nb))
    rt2.put(BitVector.from_bits(bits[0]), pin=True)
    with pytest.raises(AmbitError, match="pinned or in use"):
        rt2.put(BitVector.from_bits(bits[1]))


def test_freed_handle_raises():
    rt = AmbitRuntime(backend="jnp")
    a = rt.put(BitVector.from_bits(RNG.integers(0, 2, 64).astype(bool)))
    rt.free(a)
    assert a.freed
    with pytest.raises(AmbitError, match="freed"):
        rt.get(a)
    with pytest.raises(AmbitError, match="freed"):
        rt.eval(~X, {"x": a})


def test_store_rejects_foreign_and_sim_backends():
    with pytest.raises(ValueError, match="PimStore"):
        DeviceStore(backend="ambit_sim")
    rt1 = AmbitRuntime(backend="jnp")
    rt2 = AmbitRuntime(backend="jnp")
    a = rt1.put(BitVector.from_bits(RNG.integers(0, 2, 64).astype(bool)))
    with pytest.raises(AmbitError, match="another store"):
        rt2.get(a)


def test_eval_out_rebind_in_place():
    """eval(out=) rebinds the result into an existing handle: identity
    preserved, zero host traffic, correct bits (the donation path when
    the destination is an operand of the expression)."""
    rng = np.random.default_rng(21)
    for backend in BACKENDS:
        rt = AmbitRuntime(backend=backend)
        bits = rng.integers(0, 2, (2, 300)).astype(bool)
        acc = rt.put(BitVector.from_bits(bits[0]))
        w = rt.put(BitVector.from_bits(bits[1]))
        got = rt.eval(X & Y, {"x": acc, "y": w}, out=acc)
        assert got is acc and acc.dirty
        assert rt.last_stats.bytes_touched == 0
        assert np.array_equal(np.asarray(rt.get(acc).bits()),
                              bits[0] & bits[1])


def test_spilled_handles_hold_no_device_references():
    """Spill must genuinely release the accelerator: the surviving host
    copy is materialized as a numpy array (not a wrapper around the
    device buffer), for clean and dirty victims alike - otherwise the
    capacity budget would not bound device memory."""
    nb = 1024
    rt = AmbitRuntime(backend="jnp", capacity_bytes=2 * _nb_bytes(nb))
    bits = RNG.integers(0, 2, (2, nb)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]))
    rt.store.spill(a)                        # clean victim
    assert a._dev is None and isinstance(a._host.data, np.ndarray)
    assert np.array_equal(np.asarray(rt.get(a).bits()), bits[0])
    out = rt.and_(rt.store.ensure_resident(a), b)   # dirty result
    rt.store.spill(out)
    assert out._dev is None and isinstance(out._host.data, np.ndarray)
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] & bits[1])


def test_donation_restricted_to_store_private_buffers():
    """put() shares the caller's buffer, so it must never be donated to
    XLA (the caller's BitVector would be invalidated); planner results
    are store-created and donation-eligible."""
    rt = AmbitRuntime(backend="jnp")
    bits = RNG.integers(0, 2, (2, 300)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    w = rt.put(BitVector.from_bits(bits[1]))
    assert not a._private
    rt.eval(X & Y, {"x": a, "y": w}, out=a)  # must not donate a's buffer
    assert rt.planner.last_report.donated == 0
    assert a._private                        # now holds a result buffer
    rt.eval(X ^ Y, {"x": a, "y": w}, out=a)  # eligible (CPU skips the
    assert np.array_equal(                   # actual donation, but the
        np.asarray(rt.get(a).bits()),        # plumbing selects the slot)
        (bits[0] & bits[1]) ^ bits[1])


def test_compile_cache_reuses_jitted_callables():
    """Repeated evals of one expression shape hit the jitted-callable
    LRU (the _compile_cached mirror), not a fresh trace per call."""
    rt = AmbitRuntime(backend="jnp")
    bits = RNG.integers(0, 2, (2, 200)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]))
    rt.eval(X & Y, {"x": a, "y": b})
    single0, _ = device_compile_cache_info()
    rt.eval(X & Y, {"x": a, "y": b})
    single1, _ = device_compile_cache_info()
    assert single1.hits == single0.hits + 1
    assert single1.misses == single0.misses


# -- engine ledger regression (stale last_stats) ------------------------------


@pytest.mark.parametrize("backend", ("jnp", "pallas", "ambit_sim"))
def test_engine_entry_points_set_fresh_stats(backend):
    """shift/popcount used to leave the PREVIOUS call's ledger in
    last_stats, so app accumulators silently double-merged the prior op's
    DRAM cost. Every public entry point must now report its own ledger."""
    eng = BulkBitwiseEngine(backend)
    bits = RNG.integers(0, 2, (2, 300)).astype(bool)
    a = BitVector.from_bits(bits[0])
    b = BitVector.from_bits(bits[1])
    eng.and_(a, b)
    and_stats = eng.last_stats
    assert and_stats.bytes_touched > 0
    eng.popcount(a)
    assert eng.last_stats is not and_stats
    assert eng.last_stats.ns == 0 and eng.last_stats.aap_count == 0
    eng.and_(a, b)
    mid = eng.last_stats
    eng.shift(a, 7)
    assert eng.last_stats is not mid
    assert eng.last_stats.aap_count == 0
    eng.shift(a, 0)                          # amount-0 fast path too
    assert eng.last_stats.bytes_touched == 2 * a.nbytes


# -- apps run unmodified on accelerator backends ------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_bitmap_index_weekly_on_device_backend(backend):
    from repro.apps.bitmap_index import BitmapIndex

    rng = np.random.default_rng(31)
    n_users = 1200
    weeks = [f"w{i}" for i in range(4)]
    host = BitmapIndex(n_users, BulkBitwiseEngine("jnp"))
    rt = AmbitRuntime(backend=backend)
    res = BitmapIndex(n_users, runtime=rt)
    for w in weeks + ["male"]:
        members = rng.choice(n_users, n_users // 3, replace=False)
        host.add(w, members)
        res.add(w, members)
    want_u, want_pw, _ = host.weekly_active_query(weeks, "male")
    got_u, got_pw, stats = res.weekly_active_query(weeks, "male")
    assert (got_u, got_pw) == (want_u, want_pw)
    assert rt.scheduler.drains == 1          # one batched drain
    assert rt.last_drain.n_queries == len(weeks) + 1
    assert stats.bytes_touched > 0           # the popcount read-backs


@pytest.mark.parametrize("backend", BACKENDS)
def test_bitfunnel_on_device_backend(backend):
    from repro.apps.bitfunnel import BitFunnelIndex

    docs = {0: ["apple", "banana"], 1: ["banana", "cherry"],
            2: ["apple", "cherry", "date"], 3: ["elderberry"]}
    rt = AmbitRuntime(backend=backend)
    idx = BitFunnelIndex(n_docs=4, filter_bits=256, runtime=rt)
    for d, terms in docs.items():
        idx.add_document(d, terms)
    idx.freeze(pin=True)
    for query, must in ((["apple"], {0, 2}), (["banana"], {0, 1}),
                        (["apple", "cherry"], {2})):
        got = set(idx.query(query).tolist())
        assert must <= got


@pytest.mark.parametrize("backend", BACKENDS)
def test_bitweaving_resident_scan_on_device_backend(backend):
    from repro.apps.bitweaving_db import (BitWeavingColumn,
                                          ambit_scan_resident)

    rng = np.random.default_rng(17)
    vals = rng.integers(0, 2**10, 4000).astype(np.uint32)
    col = BitWeavingColumn.from_values(vals, 10)
    rt = AmbitRuntime(backend=backend)
    for (c1, c2) in ((0, 1023), (100, 100), (256, 700)):
        count, stats, _ = ambit_scan_resident(col, c1, c2, rt)
        assert count == col.oracle_count(vals, c1, c2)
    # planes stayed resident: the second/third scans paid no re-upload
    assert rt.store.host_writes == 10
