"""HLO text analysis: shapes, trip counts, multipliers, dot FLOPs on a
synthetic module with known ground truth."""

from repro.launch.hloparse import HloModule

SYNTH = """
HloModule test

%cond.1 (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %x = f32[4,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %d = f32[4,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,8] all-reduce(%d), replica_groups={}, to_apply=%sum.1
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %ar)
}

%sum.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[4,8]) -> f32[4,8] {
  %x0 = f32[4,8] parameter(0)
  %big = f32[100,200] constant({...})
  %g = f32[4,200] dot(%x0, %big), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[4,8]) tuple(%i0, %x0)
  %w = (s32[], f32[4,8]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[4,8] get-tuple-element(%w), index=1
}
"""


def test_trip_count_and_multipliers():
    m = HloModule(SYNTH)
    assert m.mult["body.1"] == 10
    assert m.mult["main"] == 1


def test_dot_flops_trip_weighted():
    m = HloModule(SYNTH)
    # body dot: 2*4*8*8 = 512 flops x 10 trips; entry dot mis-shaped on
    # purpose? no: 2*4*200*4 contracting lhs dim0(4)... lhs (4,8)
    # contracting {0} -> k=4, out (4,200) -> 2*800*4 = 6400 x1
    assert m.dot_flops() == 512 * 10 + 6400


def test_collective_bytes_trip_weighted():
    m = HloModule(SYNTH)
    total, kinds = m.collective_bytes()
    # all-reduce result+operand = 2 * 4*8*4 bytes, x10 trips
    assert kinds["all-reduce"] == 2 * 128 * 10
    assert total == 2 * 128 * 10


def test_shapes_table():
    m = HloModule(SYNTH)
    assert m.shapes["big"] == ("f32", [100, 200])
    assert m.shapes["d"] == ("f32", [4, 8])
