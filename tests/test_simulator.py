"""Device-model correctness: TRA majority, DCC NOT, RowClone, Figure-20
templates, row-address grouping, and the bbop ISA."""

import itertools

import numpy as np
import pytest

from repro.core import (AmbitDevice, AmbitError, AmbitSubarray, B, C, D,
                        program_stats)
from repro.core.commands import (AAP, AP, Activate, B_GROUP_WORDLINES,
                                 OP_TEMPLATES, Precharge, wordlines_for)

RNG = np.random.default_rng(0)
WORDS = 4


def rand_row():
    return RNG.integers(0, 2**64, WORDS, dtype=np.uint64)


@pytest.fixture
def sub():
    return AmbitSubarray(words=WORDS)


def test_b_group_mapping_matches_table2(sub):
    # Table 2: B12 must raise exactly T0,T1,T2; B8 raises DCC0N,T0; etc.
    assert B_GROUP_WORDLINES[12] == ("T0", "T1", "T2")
    assert B_GROUP_WORDLINES[8] == ("DCC0N", "T0")
    assert B_GROUP_WORDLINES[5] == ("DCC0N",)
    assert wordlines_for(B(14)) == ("DCC0", "T1", "T2")
    assert wordlines_for(D(7)) == ("D7",)


def test_single_activate_restores_cell(sub):
    a = rand_row()
    sub.write_row(0, a)
    sub.execute([Activate(D(0)), Precharge()])
    assert np.array_equal(sub.read_row(0), a)


def test_rowclone_fpm_copy(sub):
    a = rand_row()
    sub.write_row(0, a)
    sub.run([AAP(D(0), D(5))])
    assert np.array_equal(sub.read_row(5), a)
    assert np.array_equal(sub.read_row(0), a)  # source preserved


def test_control_row_init_copy(sub):
    sub.run([AAP(C(0), D(3))])
    assert np.all(sub.read_row(3) == 0)
    sub.run([AAP(C(1), D(3))])
    assert np.all(sub.read_row(3) == np.uint64(0xFFFFFFFFFFFFFFFF))


def test_tra_is_bitwise_majority(sub):
    a, b, c = rand_row(), rand_row(), rand_row()
    sub.write_row(0, a)
    sub.write_row(1, b)
    sub.write_row(2, c)
    sub.bbop("maj3", 6, 0, 1, 2)
    expect = (a & b) | (b & c) | (c & a)
    assert np.array_equal(sub.read_row(6), expect)


def test_tra_overwrites_all_three_cells(sub):
    """Section 3.1.2 issue 3: TRA destroys the source designated rows."""
    a, b = rand_row(), rand_row()
    sub.write_row(0, a)
    sub.write_row(1, b)
    sub.run([AAP(D(0), B(0)), AAP(D(1), B(1)), AAP(C(0), B(2)),
             AP(B(12))])
    expect = a & b
    for wl in ("T0", "T1", "T2"):
        # Row state is batched (n_rows, words); n_rows == 1 here.
        assert np.array_equal(sub.t_rows[wl][0], expect), wl


def test_dcc_not_capture(sub):
    a = rand_row()
    sub.write_row(0, a)
    sub.run([AAP(D(0), B(5))])  # DCC0 = !a via n-wordline
    assert np.array_equal(sub.dcc["DCC0"][0], ~a)
    sub.run([AAP(B(4), D(7))])  # read capacitor back through d-wordline
    assert np.array_equal(sub.read_row(7), ~a)


@pytest.mark.parametrize("op", ["and", "or", "nand", "nor", "xor", "xnor"])
def test_figure20_templates(sub, op):
    a, b = rand_row(), rand_row()
    expect = {"and": a & b, "or": a | b, "nand": ~(a & b),
              "nor": ~(a | b), "xor": a ^ b, "xnor": ~(a ^ b)}[op]
    sub.write_row(0, a)
    sub.write_row(1, b)
    sub.bbop(op, 5, 0, 1)
    assert np.array_equal(sub.read_row(5), expect)
    assert np.array_equal(sub.read_row(0), a)
    assert np.array_equal(sub.read_row(1), b)


def test_figure20_exhaustive_single_bit():
    """All 4 input combinations for every 2-operand template."""
    for op in ("and", "or", "nand", "nor", "xor", "xnor"):
        for bits in itertools.product([0, 1], repeat=2):
            s = AmbitSubarray(words=1)
            full = np.uint64(0xFFFFFFFFFFFFFFFF)
            a = np.array([full if bits[0] else 0], np.uint64)
            b = np.array([full if bits[1] else 0], np.uint64)
            s.write_row(0, a)
            s.write_row(1, b)
            s.bbop(op, 5, 0, 1)
            ref = {"and": a & b, "or": a | b, "nand": ~(a & b),
                   "nor": ~(a | b), "xor": a ^ b, "xnor": ~(a ^ b)}[op]
            assert np.array_equal(s.read_row(5), ref), (op, bits)


def test_paper_aap_counts():
    """Figure 20's op costs: and=4 AAP, nand=5 AAP, xor=5 AAP+2 AP, not=2."""
    counts = {}
    for op, n_args in (("and", 3), ("nand", 3), ("xor", 3), ("not", 2)):
        prog = OP_TEMPLATES[op](*[D(i) for i in range(n_args)])
        st = program_stats(prog)
        counts[op] = (st.aap_count, st.ap_count)
    assert counts["and"] == (4, 0)
    assert counts["nand"] == (5, 0)
    assert counts["xor"] == (5, 2)
    assert counts["not"] == (2, 0)


def test_aap_latency_model():
    """Section 4.3: one-B-address AAPs take 49 ns; B->B and D->D take 80."""
    st = program_stats([AAP(D(0), B(0))])
    assert st.ns == 49.0
    st = program_stats([AAP(B(12), B(5))])  # the nand exception
    assert st.ns == 80.0
    st = program_stats([AAP(D(0), D(1))])   # plain RowClone-FPM
    assert st.ns == 80.0


def test_dual_activation_disagreeing_cells_is_undefined(sub):
    a = rand_row()
    sub.write_row(0, a)
    # Put disagreeing values in T2,T3 then activate B10 from precharged.
    sub.run([AAP(D(0), B(2))])
    sub.run([AAP(C(1), B(3))])
    if not np.array_equal(sub.t_rows["T2"], sub.t_rows["T3"]):
        with pytest.raises(AmbitError):
            sub.execute([Activate(B(10))])


def test_device_bbop_and_allocator():
    dev = AmbitDevice(banks=2, subarrays=2, words=WORDS)
    slots_a = dev.alloc_rows(4)
    slots_b = dev.alloc_rows(4)
    slots_d = dev.alloc_rows(4)
    a = np.stack([rand_row() for _ in range(4)])
    b = np.stack([rand_row() for _ in range(4)])
    dev.write(slots_a, a)
    dev.write(slots_b, b)
    dev.bbop("xor", slots_d, slots_a, slots_b)
    assert np.array_equal(dev.read(slots_d), a ^ b)
    st = dev.total_stats()
    assert st.aap_count > 0 and st.energy_nj > 0


def test_psm_copy_between_subarrays():
    dev = AmbitDevice(banks=1, subarrays=2, words=WORDS)
    a = rand_row()
    dev.banks[0].subarrays[0].write_row(0, a)
    dev.banks[0].psm_copy(0, 0, 1, 3)
    assert np.array_equal(dev.banks[0].subarrays[1].read_row(3), a)
    assert dev.banks[0].stats.ns > 0
