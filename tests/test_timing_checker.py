"""DRAM timing-rule checker: the differential oracle for command streams.

Positive path: every canonical program (Figure-20 templates + compiled
expressions, optimized and naive, plus PSM copies) replays into a timed
stream that is violation-free against the 8-rule DDR table. Negative
path: corrupted streams - dropped PRECHARGEs, cross-bank ACT bursts,
refresh-blind schedules, early PRE/ACT, premature column writes - are
rejected with the *right* rule named, not just "illegal".

The refresh half: ``defer_for_refresh`` / ``refresh_schedule`` model
checks, the per-bank ``refresh_stolen_ns`` ledger reconciling bit-exactly
across OpStats, the metrics registry and the trace export (single device
and cluster), and ``drain(refresh=True)`` stretching the epoch timeline
by exactly the refresh windows it crossed while leaving the conservation
ledger untouched.

Property tests run under hypothesis when installed; without it they fall
back to deterministic seeded sweeps over the same generator.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import BitVector, Expr, compile_expr
from repro.core.commands import AAP, B, D, seq_and
from repro.core.engine import OpStats
from repro.core.timing import (DEFAULT_TIMING, defer_for_refresh,
                               refresh_schedule)
from repro.core.timing_checker import (RULES, RULES_BY_NAME, TimedCommand,
                                       TimingChecker, TimingViolationError,
                                       _rand_expr, canonical_programs,
                                       schedule_program, schedule_psm_copy)
from repro.obs import Tracer
from repro.pim import AmbitRuntime

P = DEFAULT_TIMING
VAR_ROWS = {"a": 0, "b": 1, "c": 2, "d": 3}


def rules_of(violations):
    return sorted({v.rule for v in violations})


# -- the rule table -----------------------------------------------------------


def test_rule_table_is_the_declared_contract():
    assert [r.name for r in RULES] == [
        "tRP", "tRCD", "tRAS", "tRC", "tWR", "tFAW", "refresh", "open-bank"]
    assert RULES_BY_NAME["tRC"].gap(P) == P.tRAS + P.tRP
    assert RULES_BY_NAME["tFAW"].gap(P) == P.tFAW
    assert RULES_BY_NAME["open-bank"].gap is None
    for rule in RULES:
        assert rule.description


# -- positive path: canonical streams are legal -------------------------------


def test_canonical_programs_are_violation_free():
    checker = TimingChecker()
    progs = canonical_programs()
    assert len(progs) > 30          # templates + both optimize modes
    for name, prog in progs:
        violations = checker.check(schedule_program(prog))
        assert violations == [], (name, violations)


def test_psm_copy_stream_is_legal():
    checker = TimingChecker()
    for n_lines in (1, 8, 128):     # one cache line .. a full 8 KB row
        events = schedule_psm_copy(n_lines)
        assert checker.check(events) == []
        assert sum(e.kind == "WR" for e in events) == n_lines


def test_split_vs_naive_aap_occupancy():
    """The replay honors the Section 4.3 distinction: a split-decoder AAP
    (exactly one B-group address) precharges at tRAS and occupies the
    bank for tRAS+tRP = 50 ns; a naive RowClone-FPM AAP needs two full
    activations: 2*tRAS+tRP = 85 ns."""
    split = schedule_program([AAP(D(0), B(0)), AAP(D(1), B(0))])
    assert [e.t_ns for e in split if e.kind == "ACT" and e.macro_id == 1][0] \
        == P.tRAS + P.tRP                              # 50 ns
    assert split[1].t_ns == P.aap_overlap_extra_ns     # paired ACT @ +4
    naive = schedule_program([AAP(D(0), D(1)), AAP(D(2), D(3))])
    assert [e.t_ns for e in naive if e.kind == "ACT" and e.macro_id == 1][0] \
        == 2 * P.tRAS + P.tRP                          # 85 ns
    assert naive[1].t_ns == P.tRAS                     # full restoration
    assert TimingChecker().check(split) == []
    assert TimingChecker().check(naive) == []


def _check_compiled_stream_legal(seed):
    rng = np.random.default_rng(seed)
    expr = _rand_expr(rng)
    checker = TimingChecker()
    for optimize in (False, True):
        cp = compile_expr(expr, VAR_ROWS, 4, optimize=optimize)
        events = checker.verify_program(cp.program)
        assert events and events[0].t_ns == 0.0


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_compiled_streams_legal(seed):
        _check_compiled_stream_legal(seed)
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_compiled_streams_legal(seed):
        _check_compiled_stream_legal(seed)


# -- negative path: corrupted streams name the right rule ---------------------


def test_dropped_precharges_flag_open_bank():
    prog = seq_and(D(0), D(1), D(2))
    legal = schedule_program(prog)
    corrupted = [e for e in legal if e.kind != "PRE"]
    violations = TimingChecker().check(corrupted)
    assert violations and rules_of(violations) == ["open-bank"]
    # both ACTs of every macro after the first re-activate an open bank,
    # and the stream still ends with the bank open
    assert len(violations) == 2 * (len(prog) - 1) + 1
    assert "missing PRECHARGE" in violations[0].message


def test_fifth_act_across_rank_violates_tfaw():
    """tFAW is rank-level and counts a rolling window of four: four ACTs
    in 15 ns are legal, the fifth inside tFAW of the 4th-previous is
    not - even though every bank is individually legal."""
    def burst(n_banks):
        events = []
        for b in range(n_banks):
            t = 5.0 * b
            events.append(TimedCommand(t, "ACT", b, b))
            events.append(TimedCommand(t + P.tRAS, "PRE", b, b))
        return events

    assert TimingChecker().check(burst(4)) == []
    violations = TimingChecker().check(burst(5))
    assert rules_of(violations) == ["tFAW"]
    assert len(violations) == 1
    assert violations[0].t_ns == 20.0
    assert "5th ACT" in violations[0].message


def test_refresh_blind_schedule_is_rejected_aware_is_clean():
    prog = 60 * seq_and(D(0), D(1), D(2))   # ~18 us: crosses 2 windows
    blind = schedule_program(prog, refresh_aware=False)
    violations = TimingChecker().check(blind)
    assert violations and rules_of(violations) == ["refresh"]
    aware = schedule_program(prog, refresh_aware=True)
    assert TimingChecker().check(aware) == []
    # the blind stream is fine on a rank with refresh disabled: the only
    # thing wrong with it is issuing during REF
    assert TimingChecker(check_refresh=False).check(blind) == []


def test_schedule_defers_start_past_refresh_window():
    prog = seq_and(D(0), D(1), D(2))
    events = schedule_program(prog, start_ns=P.tREFI - 5.0)
    assert events[0].t_ns == P.tREFI + P.tRFC      # held through REF
    assert TimingChecker().check(events) == []


def test_early_precharge_and_activate():
    events = [
        TimedCommand(0.0, "ACT", 0, 0),
        TimedCommand(20.0, "PRE", 0, 0),    # 20 < tRAS=35
        TimedCommand(30.0, "ACT", 0, 1),    # 30 < tRC=50, 10 < tRP=15
        TimedCommand(30.0 + P.tRAS, "PRE", 0, 1),
    ]
    violations = TimingChecker().check(events)
    assert rules_of(violations) == ["tRAS", "tRC", "tRP"]
    assert len(violations) == 3


def test_premature_write_and_early_precharge_after_write():
    events = [
        TimedCommand(0.0, "ACT", 0, 0),
        TimedCommand(10.0, "WR", 0, 0),     # 10 < tRCD=15
        TimedCommand(45.0, "WR", 0, 0),
        TimedCommand(50.0, "PRE", 0, 0),    # 5 < tWR=15 after last WR
    ]
    violations = TimingChecker().check(events)
    assert rules_of(violations) == ["tRCD", "tWR"]
    assert len(violations) == 2


def test_write_with_no_open_row():
    violations = TimingChecker().check([TimedCommand(0.0, "WR", 0, 0)])
    assert rules_of(violations) == ["open-bank"]
    assert "no open row" in violations[0].message


def test_stream_ending_with_open_bank():
    violations = TimingChecker().check([TimedCommand(0.0, "ACT", 0, 0)])
    assert rules_of(violations) == ["open-bank"]
    assert "missing final PRECHARGE" in violations[0].message


def test_idle_precharge_is_a_harmless_noop_but_starts_trp():
    ok = [TimedCommand(0.0, "PRE", 0, 0),
          TimedCommand(P.tRP, "ACT", 0, 1),
          TimedCommand(P.tRP + P.tRAS, "PRE", 0, 1)]
    assert TimingChecker().check(ok) == []
    early = [TimedCommand(0.0, "PRE", 0, 0),
             TimedCommand(10.0, "ACT", 0, 1),     # 10 < tRP=15
             TimedCommand(10.0 + P.tRAS, "PRE", 0, 1)]
    assert rules_of(TimingChecker().check(early)) == ["tRP"]


def test_verify_program_raises_structured_error():
    prog = 60 * seq_and(D(0), D(1), D(2))
    with pytest.raises(TimingViolationError) as exc:
        TimingChecker().verify_program(prog, refresh_aware=False)
    err = exc.value
    assert err.violations and all(v.rule == "refresh"
                                  for v in err.violations)
    assert "timing violation(s)" in str(err)
    if len(err.violations) > 3:                 # message truncates
        assert f"+{len(err.violations) - 3} more" in str(err)
    # the same program scheduled refresh-aware verifies clean
    events = TimingChecker().verify_program(prog, refresh_aware=True)
    assert events


# -- the refresh model (timing.py) -------------------------------------------


def test_defer_for_refresh_window_arithmetic():
    # no overlap: untouched
    assert defer_for_refresh(0.0, 50.0) == 0.0
    # a burst that would straddle the first window is pushed past it
    assert defer_for_refresh(P.tREFI - 1.0, 50.0) == P.tREFI + P.tRFC
    # issuing inside the window is equally deferred
    assert defer_for_refresh(P.tREFI + 10.0, 50.0) == P.tREFI + P.tRFC
    # a burst longer than the inter-window gap can never be scheduled
    with pytest.raises(ValueError):
        defer_for_refresh(0.0, P.tREFI - P.tRFC + 1.0)


def test_refresh_schedule_slices_work_across_windows():
    # fully before the first window: no stall
    assert refresh_schedule(0.0, 100.0) == (0.0, 100.0)
    # crossing one window stalls by exactly one tRFC
    start, finish = refresh_schedule(0.0, 10_000.0)
    assert start == 0.0
    assert finish - start - 10_000.0 == pytest.approx(P.tRFC)
    # starting inside a window first waits it out
    start, finish = refresh_schedule(P.tREFI + 1.0, 100.0)
    assert start == P.tREFI + P.tRFC
    assert finish == start + 100.0


def test_steady_state_refresh_overhead():
    assert P.refresh_overhead == pytest.approx(
        P.tRFC / (P.tREFI - P.tRFC))
    assert P.refresh_stolen_ns(1000.0) == pytest.approx(
        1000.0 * P.refresh_overhead)
    assert 0.04 < P.refresh_overhead < 0.05     # ~4.7% at DDR3 8Gb-class


# -- refresh ledger reconciliation (planner / cluster / metrics / trace) ------


def _chain_bits(n, n_bits, seed=0):
    rng = np.random.default_rng(seed)
    return [BitVector.from_bits(rng.integers(0, 2, n_bits).astype(bool))
            for _ in range(n)]


def test_refresh_ledger_reconciles_across_all_surfaces():
    """The planner computes ONE per-call per-bank stolen figure; the
    OpStats ledger, the metric series and the trace spans all accumulate
    that same value in the same order, so equality is ==, not approx."""
    tr = Tracer(enabled=True)
    rt = AmbitRuntime(banks=2, subarrays=2, words=2, tracer=tr)
    n_bits = 4 * rt.store.device.words * 64     # 4 slots: spans banks
    vecs = _chain_bits(4, n_bits)
    acc = rt.put(vecs[0])
    expect_bank = {}
    expect = OpStats()
    for v in vecs[1:]:
        acc = rt.and_(acc, rt.put(v))
        for b, st in sorted(rt.planner.last_report.per_bank.items()):
            expect_bank[b] = (expect_bank.get(b, 0.0)
                              + st.refresh_stolen_ns)
        expect += rt.last_stats
    assert expect.refresh_stolen_ns > 0.0
    # refresh tax never inflates the busy-time ledger itself
    assert expect.ns > 0.0
    assert rt.session_stats.refresh_stolen_ns == expect.refresh_stolen_ns
    series = rt.metrics.counters.get("refresh_stolen_ns").series
    for b, want in sorted(expect_bank.items()):
        if not want:
            continue
        key = (("bank", str(b)), ("device", "0"))
        assert series.get(key) == want, (b, series.get(key), want)
        got = sum(e.dur_ns for e in tr.events
                  if e.cat == "refresh"
                  and e.track == ("device0", f"bank{b}"))
        assert got == want, (b, got, want)


def test_cluster_refresh_metrics_reconcile_per_device_bank():
    rt = AmbitRuntime(banks=2, subarrays=2, words=2, devices=2)
    n_bits = 4 * rt.device.words * 64           # 4 chunks: both devices
    a, b = _chain_bits(2, n_bits, seed=3)
    out = rt.and_(rt.put(a), rt.put(b))
    assert out is not None
    report = rt.planner.last_report
    assert report.stats.refresh_stolen_ns > 0.0
    series = rt.metrics.counters.get("refresh_stolen_ns").series
    devices_seen = set()
    for (d, bank), st in sorted(report.per_bank.items()):
        if not st.refresh_stolen_ns:
            continue
        key = (("bank", str(bank)), ("device", str(d)))
        assert series.get(key) == st.refresh_stolen_ns
        devices_seen.add(d)
    assert devices_seen == {0, 1}               # the tax is shard-local


# -- refresh-aware drain ------------------------------------------------------


def _drained(refresh, queries=4, rows=48):
    rng = np.random.default_rng(11)
    rt = AmbitRuntime(banks=8, subarrays=4, words=128)
    n_bits = rt.store.device.words * 64
    ab = Expr.var("a") & Expr.var("b")
    for _ in range(queries):
        hs = [rt.put(BitVector.from_bits(
            rng.integers(0, 2, (rows, n_bits)).astype(bool)))
            for _ in range(2)]
        rt.submit(ab, {"a": hs[0], "b": hs[1]})
    rt.drain(refresh=refresh)
    return rt.last_drain


def test_drain_refresh_stretches_wall_not_ledger():
    plain = _drained(False)
    aware = _drained(True)
    # the conservation ledger is untouched: refresh is wall-clock only
    assert aware.stats.ns == plain.stats.ns
    assert aware.stats.energy_nj == plain.stats.energy_nj
    assert aware.stats.aap_count == plain.stats.aap_count
    # the wall stretch is exactly the stall, which is whole REF windows
    assert plain.refresh_stall_ns == 0.0
    assert aware.refresh_stall_ns > 0.0
    assert aware.wall_ns - plain.wall_ns == aware.refresh_stall_ns
    assert aware.refresh_stall_ns % P.tRFC == pytest.approx(0.0)
    # per-epoch stalls sum to the drain total and stretch the timeline
    assert sum(e.refresh_ns for e in aware.epochs) == \
        aware.refresh_stall_ns
    for ep, pp in zip(aware.epochs, plain.epochs):
        assert ep.end_ns - ep.start_ns == \
            (pp.end_ns - pp.start_ns) + ep.refresh_ns


def test_drain_refresh_noop_when_work_fits_before_first_window():
    plain = _drained(False, queries=1, rows=4)
    aware = _drained(True, queries=1, rows=4)
    assert aware.wall_ns < P.tREFI              # never reaches a window
    assert aware.refresh_stall_ns == 0.0
    assert aware.wall_ns == plain.wall_ns


def test_drain_refresh_is_deterministic():
    a = _drained(True, queries=2, rows=32)
    b = _drained(True, queries=2, rows=32)
    assert a.wall_ns == b.wall_ns
    assert a.refresh_stall_ns == b.refresh_stall_ns
    assert [e.refresh_ns for e in a.epochs] == \
        [e.refresh_ns for e in b.epochs]
