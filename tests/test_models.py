"""Per-arch smoke tests (reduced configs) + component-level references:
flash attention vs naive softmax, SSD chunked vs sequential recurrence,
MoE sort-dispatch vs dense loop-over-experts, decode-vs-forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.models import build_model
from repro.models.attention import decode_attention, flash_attention
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b, s, key=KEY):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model))
        batch["vision_positions"] = jnp.tile(
            jnp.arange(cfg.vision_tokens)[None], (b, 1))
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(key, (b, cfg.n_frames,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_arch_smoke_forward_and_train_shapes(arch):
    """One forward + one train step on the reduced config: shapes + no NaN."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 24
    batch = make_batch(cfg, b, s)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())

    from repro.optim.optimizer import OptimizerConfig
    from repro.train.step import init_state, make_train_step
    state = init_state(model, KEY)
    batch["labels"] = batch["tokens"]
    step = make_train_step(model, OptimizerConfig(total_steps=10),
                           remat=False)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    w0 = jax.tree.leaves(state["params"])[0]
    w1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(w0), np.asarray(w1))


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_arch_decode_matches_forward(arch):
    """Prefill+decode logits == full-forward logits (bf16 tolerance)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                              cfg.vocab)
    full = make_batch(cfg, b, s + 1)
    full["tokens"] = toks
    pre = dict(full)
    pre["tokens"] = toks[:, :s]
    if cfg.family == "vlm":
        pass  # vision inputs identical for both
    logits_full, _ = model.forward(params, full)
    want = np.asarray(logits_full[:, s], np.float32)
    _, caches = model.prefill(params, pre, skv=s + 4)
    got, _ = model.decode_step(
        params, caches,
        {"tokens": toks[:, s:s + 1], "pos": jnp.full((b,), s, jnp.int32)})
    got = np.asarray(got, np.float32)
    rel = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
    # bf16 compute: prefill+decode accumulates rounding differently than
    # the fused forward; 0.1 max-rel is ~2 bf16 ulps on these logits.
    # (argmax equality is NOT asserted: random-init logits have near-ties
    # that flip under 1-ulp differences.)
    assert rel < 1e-1, rel


def test_flash_attention_matches_naive():
    b, s, h, d = 2, 37, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))

    def naive(q, k, v, window=None):
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
        mask = jnp.tril(jnp.ones((s, s), bool))
        if window is not None:
            pos = jnp.arange(s)
            mask = mask & (pos[None, :] > pos[:, None] - window)
        sc = jnp.where(mask[None, None], sc, -1e30)
        p = jax.nn.softmax(sc, -1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    for window, bkv in ((None, 8), (None, 64), (7, 16)):
        got = flash_attention(q, k, v, causal=True,
                              window=None if window is None else
                              jnp.asarray(window), block_kv=bkv)
        want = naive(q, k, v, window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=2e-2)


def test_flash_attention_gqa_and_cross():
    b, sq, skv, hq, hkv, d = 2, 9, 21, 8, 2, 16
    q = jax.random.normal(KEY, (b, sq, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, hkv, d))
    got = flash_attention(q, k, v, causal=False, block_kv=8)
    kr = jnp.repeat(k, hq // hkv, 2)
    vr = jnp.repeat(v, hq // hkv, 2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-2)


def test_decode_attention_matches_flash_last_row():
    b, s, h, d = 2, 12, 4, 8
    q1 = jax.random.normal(KEY, (b, 1, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    pos = jnp.full((b,), s - 1, jnp.int32)
    got = decode_attention(q1, k, v, pos)
    sc = jnp.einsum("bhd,bkhd->bhk", q1[:, 0], k) / np.sqrt(d)
    want = jnp.einsum("bhk,bkhd->bhd", jax.nn.softmax(sc, -1), v)[:, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-2)


def test_ssd_chunked_matches_sequential():
    """Chunked dual form == naive recurrent scan."""
    b, s, h, p, n = 2, 29, 3, 4, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    da = -dt * jnp.asarray(rng.uniform(0.1, 1.0, (1, 1, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)

    def sequential():
        state = np.zeros((b, h, p, n))
        ys = []
        for t in range(s):
            decay = np.exp(np.asarray(da[:, t]))  # (b,h)
            state = state * decay[:, :, None, None] + np.einsum(
                "bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                np.asarray(bm[:, t, 0]), np.asarray(x[:, t]))
            ys.append(np.einsum("bn,bhpn->bhp", np.asarray(cm[:, t, 0]),
                                state))
        return np.stack(ys, 1), state

    want_y, want_state = sequential()
    for chunk in (4, 8, 32):
        got_y, got_state = ssd_chunked(x, dt, da, bm, cm, chunk)
        np.testing.assert_allclose(np.asarray(got_y), want_y, atol=2e-3,
                                   rtol=2e-2)
        np.testing.assert_allclose(np.asarray(got_state), want_state,
                                   atol=2e-3, rtol=2e-2)


def test_ssd_initial_state_continuation():
    """prefill(x[:k]) state + chunked(x[k:]) == chunked(x) outputs."""
    b, s, h, p, n = 1, 24, 2, 4, 4
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    da = -dt * 0.5
    bm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    y_all, st_all = ssd_chunked(x, dt, da, bm, cm, 8)
    k = 16
    _, st1 = ssd_chunked(x[:, :k], dt[:, :k], da[:, :k], bm[:, :k],
                         cm[:, :k], 8)
    y2, st2 = ssd_chunked(x[:, k:], dt[:, k:], da[:, k:], bm[:, k:],
                          cm[:, k:], 8, initial_state=st1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, k:]),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_all),
                               atol=2e-3, rtol=2e-2)


def test_moe_sort_dispatch_matches_dense_loop():
    """Sort+scatter expert execution == explicit per-expert dense loop
    (no capacity drops at high capacity_factor)."""
    from repro.configs.base import ArchConfig, MoEConfig
    from repro.models.moe import _capacity, _moe_local, padded_experts

    d, ffe, e, k, t = 16, 8, 8, 2, 64
    moe = MoEConfig(n_experts=e, top_k=k, d_ff_expert=ffe,
                    capacity_factor=8.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, e)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(e, d, ffe)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(e, d, ffe)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(e, ffe, d)) * 0.1, jnp.float32)
    cap = _capacity(t, moe)
    out, aux = _moe_local(x, router, w1, w3, w2, moe=moe, e_pad=e,
                          n_local=e, e_lo=0, act="silu", capacity=cap)

    logits = np.asarray(x @ router)
    topv, topi = jax.lax.top_k(jnp.asarray(logits), k)
    gates = np.asarray(jax.nn.softmax(topv, -1))
    want = np.zeros((t, d), np.float32)
    for ti in range(t):
        for j in range(k):
            ex = int(topi[ti, j])
            h = np.asarray(jax.nn.silu(x[ti] @ w1[ex])) * \
                np.asarray(x[ti] @ w3[ex])
            want[ti] += gates[ti, j] * np.asarray(h @ w2[ex])
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-3, rtol=1e-2)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    from repro.configs.base import MoEConfig
    from repro.models.moe import _moe_local

    d, ffe, e, k, t = 8, 4, 4, 1, 32
    moe = MoEConfig(n_experts=e, top_k=k, d_ff_expert=ffe,
                    capacity_factor=0.25)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    router = jnp.zeros((d, e), jnp.float32)  # all tokens -> expert 0 ties
    w = jnp.asarray(rng.normal(size=(e, d, ffe)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(e, ffe, d)) * 0.1, jnp.float32)
    out, _ = _moe_local(x, router, w, w, w2, moe=moe, e_pad=e, n_local=e,
                        e_lo=0, act="silu", capacity=2)
    # beyond-capacity tokens produce zero output rows
    zero_rows = int((np.abs(np.asarray(out)).sum(-1) < 1e-9).sum())
    assert zero_rows > 0


def test_expert_bitmask_stats():
    from repro.models.moe import expert_bitmask_stats
    idx = jnp.asarray([[0, 1], [1, 2], [1, 3]], jnp.int32)
    masks, loads = expert_bitmask_stats(idx, 4)
    assert list(np.asarray(loads)) == [1, 3, 1, 1]


def test_gemma3_layer_pattern():
    from repro.models.transformer import layer_windows
    cfg = get_config("gemma3-1b")
    w = np.asarray(layer_windows(cfg, 8192))
    assert (w[np.arange(26) % 6 == 5] == 8193).all()   # global layers
    assert (w[np.arange(26) % 6 != 5] == 1024).all()   # sliding layers
