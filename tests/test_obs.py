"""Observability layer: simulated-clock tracing + metrics registry.

The contracts under test (ISSUE 7):

  * **Determinism** - the same workload traced twice produces
    byte-identical Chrome/Perfetto JSON (CI diffs trace files);
  * **Zero overhead off** - with tracing disabled the tracer records
    nothing AND every legacy ledger (OpStats, store byte counters,
    ChannelLedger) is bit-identical to the traced run: tracing may only
    observe, never perturb;
  * **Reconciliation** - MetricsRegistry series are incremented at the
    same call sites as the legacy ledgers, so their totals match
    bit-exactly (store io bytes vs bytes_to/from_device, cluster channel
    ns vs ChannelLedger.host_ns), with tracing on and off;
  * **Sum reconcile** - the scheduler's epoch spans tile the drain's
    [start_ns, end_ns) exactly: consecutive, gapless, durations summing
    to the drain wall time;
  * **Exporter validity** - chrome_trace output is structurally valid
    trace-event JSON (pids/tids consistent with metadata, ts/dur
    microseconds with exact ns in args) and serialises with
    ``allow_nan=False``.
"""

import json

import numpy as np
import pytest

from repro.core import BitVector, DRAMGeometry, Expr
from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer, chrome_trace,
                       utilization_report, write_chrome_trace)
from repro.pim import AmbitRuntime

GEOM = DRAMGeometry(rows_per_subarray=32)

X, Y = Expr.var("x"), Expr.var("y")


def _rt(tracer=None, **kw):
    kw.setdefault("banks", 2)
    kw.setdefault("subarrays", 2)
    kw.setdefault("words", 2)
    kw.setdefault("seed", 3)
    return AmbitRuntime(GEOM, tracer=tracer, **kw)


def _drain_workload(rt, n_queries=6, n_bits=120, seed=0):
    """Submit a small mixed batch and drain it on the simulated clock."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (4, n_bits)).astype(bool)
    hs = [rt.put(BitVector.from_bits(b), name=f"v{i}")
          for i, b in enumerate(bits)]
    exprs = [X & Y, X | Y, X ^ Y]
    for k in range(n_queries):
        e = exprs[k % len(exprs)]
        env = {"x": hs[k % 4], "y": hs[(k + 1) % 4]}
        rt.submit(e, env, now_ns=float(100 * k))
    rt.drain(now_ns=1_000.0)
    return rt.last_drain


# -- tracer primitives ---------------------------------------------------------


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    tr.span(("a",), "s", "c", 0.0, 5.0)
    tr.instant(("a",), "i", "c")
    tr.tick(("a",), "t", "c", 3.0)
    tr.async_begin(("a",), "q", "c", 1, 0.0)
    tr.async_end(("a",), "q", "c", 1, 2.0)
    tr.advance(("a",), 10.0)
    assert len(tr) == 0
    assert tr.cursor(("a",)) == 0.0
    assert NULL_TRACER.enabled is False


def test_tick_advances_per_track_cursor():
    tr = Tracer()
    tr.tick(("bank", "0"), "op", "c", 10.0)
    tr.tick(("bank", "0"), "op", "c", 5.0)
    tr.tick(("bank", "1"), "op", "c", 7.0)
    assert tr.cursor(("bank", "0")) == 15.0
    assert tr.cursor(("bank", "1")) == 7.0
    s0 = [s for s in tr.spans() if s.track == ("bank", "0")]
    assert [(s.ts_ns, s.dur_ns) for s in s0] == [(0.0, 10.0), (10.0, 5.0)]


def test_instant_sequence_position_is_per_track():
    tr = Tracer()
    tr.instant(("a",), "x", "c")
    tr.instant(("a",), "y", "c")
    tr.instant(("b",), "z", "c")
    ts = [e.ts_ns for e in tr.events]
    assert ts[0] < ts[1]            # call order on track a
    assert tr.events[2].track == ("b",)


# -- metrics primitives --------------------------------------------------------


def test_counter_labels_canonical_order():
    m = MetricsRegistry()
    m.counter("c").inc(1, a="1", b="2")
    m.counter("c").inc(2, b="2", a="1")      # kwarg order must not matter
    assert m.counter("c").value(a="1", b="2") == 3
    assert m.counter("c").total() == 3


def test_histogram_percentile_edge_cases():
    h = MetricsRegistry().histogram("h")
    assert h.percentile(0.50) is None        # empty: None, never NaN
    assert h.percentile(0.99) is None
    h.observe(42.0)
    assert h.percentile(0.50) == 42.0        # single sample is every pct
    assert h.percentile(0.99) == 42.0
    h.observe(10.0)
    h.observe(20.0)
    assert h.percentile(0.50) == 20.0        # nearest-rank over [10,20,42]


def test_snapshot_is_json_safe_with_empty_histograms():
    m = MetricsRegistry()
    m.counter("c").inc(1, k="v")
    m.gauge("g").set(2.5)
    m.histogram("h")                         # registered, never observed
    snap = m.snapshot()
    json.dumps(snap, allow_nan=False)        # must not raise
    assert snap["counters"]["c{k=v}"] == 1


# -- reconciliation: registry vs legacy ledgers --------------------------------


@pytest.mark.parametrize("traced", [False, True])
def test_store_io_metrics_match_legacy_counters(traced):
    rt = _rt(tracer=Tracer() if traced else None)
    _drain_workload(rt)
    rt.get(rt.put(BitVector.from_bits(
        np.ones(64, bool)), name="rb"))      # force a read_back
    io = rt.metrics.counter("store_io_bytes")
    to_dev = sum(v for k, v in io.series.items()
                 if ("direction", "to_device") in k)
    from_dev = sum(v for k, v in io.series.items()
                   if ("direction", "from_device") in k)
    assert to_dev == rt.store.bytes_to_device
    assert from_dev == rt.store.bytes_from_device
    ops = rt.metrics.counter("store_io_ops")
    assert sum(v for k, v in ops.series.items()
               if ("direction", "to_device") in k) == rt.store.host_writes
    assert sum(v for k, v in ops.series.items()
               if ("direction", "from_device") in k) == rt.store.host_reads


@pytest.mark.parametrize("traced", [False, True])
def test_cluster_channel_metrics_match_ledger(traced):
    rt = _rt(tracer=Tracer() if traced else None, devices=2)
    _drain_workload(rt)
    led = rt.store.ledger
    io = rt.metrics.counter("store_io_bytes")
    to_dev = sum(v for k, v in io.series.items()
                 if ("direction", "to_device") in k)
    from_dev = sum(v for k, v in io.series.items()
                   if ("direction", "from_device") in k)
    assert to_dev == led.host_to_device_bytes
    assert from_dev == led.device_to_host_bytes
    assert rt.metrics.counter("host_channel_ns").total() == led.host_ns


def test_runtime_stats_metrics_match_opstats():
    rt = _rt()
    _drain_workload(rt)
    st = rt.session_stats
    m = rt.metrics
    assert m.counter("runtime_ns").total() == st.ns
    assert m.counter("runtime_energy_nj").total() == st.energy_nj
    assert m.counter("runtime_aaps").total() == st.aap_count


def test_tracing_does_not_perturb_ledgers():
    """Bit-identical OpStats + store counters with tracing on vs off -
    the zero-overhead-when-disabled AND observe-only-when-enabled
    contract in one assertion."""
    plain, traced = _rt(), _rt(tracer=Tracer())
    rep_p = _drain_workload(plain)
    rep_t = _drain_workload(traced)
    assert plain.tracer is NULL_TRACER and len(plain.tracer) == 0
    assert len(traced.tracer) > 0
    for f in ("ns", "energy_nj", "aap_count", "bytes_touched"):
        assert getattr(rep_p.stats, f) == getattr(rep_t.stats, f)
    assert plain.store.bytes_to_device == traced.store.bytes_to_device
    assert plain.store.bytes_from_device == traced.store.bytes_from_device
    assert plain.metrics.snapshot() == traced.metrics.snapshot()


# -- epoch spans reconcile with the drain timeline -----------------------------


def test_epoch_spans_tile_drain_wall():
    rt = _rt(tracer=Tracer())
    rep = _drain_workload(rt)
    spans = [e for e in rt.tracer.spans(cat="epoch")]
    assert len(spans) == len(rep.epochs)
    assert spans[0].ts_ns == rep.start_ns
    clock = rep.start_ns
    for s, erep in zip(spans, rep.epochs):
        assert s.ts_ns == clock                 # gapless, consecutive
        assert s.dur_ns == erep.end_ns - erep.start_ns
        clock = s.ts_ns + s.dur_ns
    assert clock == rep.end_ns                  # durations sum to wall
    assert sum(s.dur_ns for s in spans) == rep.wall_ns


def test_ticket_lifecycle_and_defer_reasons_traced():
    rt = _rt(tracer=Tracer())
    # two queries on the same operands: write conflict or bank overlap
    # forces at least one deferral with a recorded reason
    rng = np.random.default_rng(0)
    h = rt.put(BitVector.from_bits(rng.integers(0, 2, 64).astype(bool)),
               name="h")
    t0 = rt.submit(X & Y, {"x": h, "y": h}, now_ns=0.0)
    t1 = rt.submit(X | Y, {"x": h, "y": h}, now_ns=0.0)
    rt.drain(now_ns=0.0)
    begins = [e for e in rt.tracer.events if e.kind == "b"]
    ends = [e for e in rt.tracer.events if e.kind == "e"]
    assert len(begins) == 2 and len(ends) == 2
    assert t1.epoch > t0.epoch
    assert t1.deferred                          # why it waited
    assert rt.metrics.counter("sched_deferrals").total() >= 1


# -- exporters -----------------------------------------------------------------


def test_chrome_trace_structure_and_determinism(tmp_path):
    def run():
        rt = _rt(tracer=Tracer())
        _drain_workload(rt)
        return rt.tracer

    tr1, tr2 = run(), run()
    doc = chrome_trace(tr1)
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    body = [e for e in events if e["ph"] != "M"]
    assert body, "trace must contain non-metadata events"
    named = {(e["pid"], e["tid"]) for e in meta if e["name"] == "thread_name"}
    for e in body:
        assert (e["pid"], e["tid"]) in named
        assert e["args"]["ns"] == pytest.approx(e["ts"] * 1000.0)
        if e["ph"] == "X":
            assert "dur_ns" in e["args"]

    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    write_chrome_trace(tr1, str(p1))
    write_chrome_trace(tr2, str(p2))
    assert p1.read_bytes() == p2.read_bytes()   # byte-identical traces
    json.loads(p1.read_text())                  # and valid JSON


def test_utilization_report_sections():
    rt = _rt(tracer=Tracer())
    rep = _drain_workload(rt)
    txt = utilization_report(tracer=rt.tracer, registry=rt.metrics,
                             drain=rep, max_batch=4)
    assert "== drain ==" in txt
    assert "packing_efficiency=" in txt
    assert "== per-bank busy ==" in txt
    assert "== bytes by cause ==" in txt
    assert "== trace ==" in txt


def test_trace_report_cli_roundtrip(tmp_path):
    import pathlib
    import subprocess
    import sys
    repo = pathlib.Path(__file__).resolve().parents[1]
    rt = _rt(tracer=Tracer())
    _drain_workload(rt)
    p = tmp_path / "t.json"
    write_chrome_trace(rt.tracer, str(p))
    out = subprocess.run(
        [sys.executable, str(repo / "tools" / "trace_report.py"),
         str(p), "--json"],
        capture_output=True, text=True, check=True)
    summary = json.loads(out.stdout)
    assert summary["epochs"]["count"] == len(rt.last_drain.epochs)
    assert summary["epochs"]["wall_ns"] == rt.last_drain.wall_ns
