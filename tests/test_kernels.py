"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU) + randomized engine-invariant tests.

Engine-invariant property tests run under hypothesis when installed
(requirements-dev.txt pins it); otherwise they fall back to deterministic
seeded sweeps so collection never fails and coverage is preserved.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallbacks below keep coverage
    HAVE_HYPOTHESIS = False

from repro.core import BitVector, BulkBitwiseEngine
from repro.core import expr as E
from repro.core.bitvector import pack_bits, unpack_bits
from repro.kernels import ops, ref

RNG = np.random.default_rng(3)


def rand_u32(shape):
    return jnp.asarray(RNG.integers(0, 2**32, shape, dtype=np.uint32))


X, Y, Z = E.Expr.var("x"), E.Expr.var("y"), E.Expr.var("z")
EXPRS = [X & Y, X ^ Y, ~X, ((X & Y) | ~Z) ^ (X | Y), E.maj(X, Y, Z)]


@pytest.mark.parametrize("shape", [(1, 7), (3, 130), (16, 512), (129,),
                                   (2, 3, 40)])
@pytest.mark.parametrize("expr", EXPRS, ids=[repr(e)[:30] for e in EXPRS])
def test_fused_bitwise_kernel(shape, expr):
    env = {k: rand_u32(shape) for k in "xyz"}
    got = ops.bitwise_eval(expr, env)
    assert got.dtype == jnp.uint32
    assert np.array_equal(np.asarray(got), np.asarray(ref.bitwise_eval(
        expr, env)))


@pytest.mark.parametrize("shape", [(1, 1), (4, 100), (33, 257), (257, 8)])
def test_popcount_kernel(shape):
    a = rand_u32(shape)
    got = ops.popcount(a)
    assert np.array_equal(np.asarray(got), np.asarray(ref.popcount(a)))


@pytest.mark.parametrize("b,n", [(1, 32), (4, 64), (8, 320), (12, 1024),
                                 (16, 4096), (32, 96)])
def test_bitweaving_kernel(b, n):
    vals = RNG.integers(0, 2**b, n).astype(np.uint32)
    planes = ref.bitslice(jnp.asarray(vals), b)
    lo, hi = sorted(RNG.integers(0, 2**b, 2).tolist())
    got = ops.bitweaving_scan(planes, lo, hi)
    expect = ref.bitweaving_scan(planes, lo, hi)
    assert np.array_equal(np.asarray(got), np.asarray(expect))
    mask = np.asarray(unpack_bits(got, n))
    assert np.array_equal(mask, (vals >= lo) & (vals <= hi))


@pytest.mark.parametrize("m,n,k", [(1, 1, 32), (5, 9, 64), (16, 16, 128),
                                   (40, 70, 1000), (8, 128, 4096)])
def test_binary_matmul_kernel(m, n, k):
    kw = (k + 31) // 32
    abits = RNG.integers(0, 2, (m, k)).astype(np.uint32)
    bbits = RNG.integers(0, 2, (n, k)).astype(np.uint32)
    ap = pack_bits(jnp.asarray(abits))[:, :kw]
    bp = pack_bits(jnp.asarray(bbits))[:, :kw]
    expect = (2 * abits.astype(np.int32) - 1) @ \
        (2 * bbits.astype(np.int32) - 1).T
    assert np.array_equal(np.asarray(ops.binary_matmul(ap, bp, k)), expect)
    assert np.array_equal(np.asarray(ops.binary_matmul_mxu(ap, bp, k)),
                          expect)


# -- engine invariants (randomized) -------------------------------------------
# Shared check bodies; hypothesis drives them when installed, deterministic
# seeded sweeps otherwise.


def check_engine_demorgan(a_bits, b_bits, backend):
    n = min(len(a_bits), len(b_bits))
    a = BitVector.from_bits(np.array(a_bits[:n], bool))
    b = BitVector.from_bits(np.array(b_bits[:n], bool))
    eng = BulkBitwiseEngine(backend)
    lhs = eng.nand(a, b).bits()
    rhs = eng.or_(~a, ~b).bits()
    assert np.array_equal(np.asarray(lhs), np.asarray(rhs))


def check_engine_xor_involution(a_bits):
    a = BitVector.from_bits(np.array(a_bits, bool))
    eng = BulkBitwiseEngine("jnp")
    twice = eng.xor(eng.xor(a, a), a).bits()
    assert np.array_equal(np.asarray(twice), np.array(a_bits, bool))


def check_engine_popcount_inclusion_exclusion(a_bits, b_bits):
    n = min(len(a_bits), len(b_bits))
    a = BitVector.from_bits(np.array(a_bits[:n], bool))
    b = BitVector.from_bits(np.array(b_bits[:n], bool))
    eng = BulkBitwiseEngine("jnp")
    pc = lambda v: int(eng.popcount(v))
    assert pc(eng.or_(a, b)) == pc(a) + pc(b) - pc(eng.and_(a, b))


def check_pack_unpack_roundtrip(bits):
    arr = np.array(bits, bool)
    bv = BitVector.from_bits(arr)
    assert np.array_equal(np.asarray(bv.bits()), arr)
    assert int(bv.popcount()) == int(arr.sum())


def _seeded_bits(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 201))
    return rng.integers(0, 2, n).astype(bool).tolist()


if HAVE_HYPOTHESIS:

    bit_arrays = st.integers(1, 200).flatmap(
        lambda n: st.lists(st.booleans(), min_size=n, max_size=n))

    @settings(max_examples=30, deadline=None)
    @given(bit_arrays, bit_arrays, st.sampled_from(["jnp", "pallas"]))
    def test_engine_demorgan(a_bits, b_bits, backend):
        check_engine_demorgan(a_bits, b_bits, backend)

    @settings(max_examples=30, deadline=None)
    @given(bit_arrays)
    def test_engine_xor_involution(a_bits):
        check_engine_xor_involution(a_bits)

    @settings(max_examples=30, deadline=None)
    @given(bit_arrays, bit_arrays)
    def test_engine_popcount_inclusion_exclusion(a_bits, b_bits):
        check_engine_popcount_inclusion_exclusion(a_bits, b_bits)

    @settings(max_examples=20, deadline=None)
    @given(bit_arrays)
    def test_pack_unpack_roundtrip(bits):
        check_pack_unpack_roundtrip(bits)

else:

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("seed", range(8))
    def test_engine_demorgan(seed, backend):
        check_engine_demorgan(_seeded_bits(3 * seed),
                              _seeded_bits(3 * seed + 1), backend)

    @pytest.mark.parametrize("seed", range(15))
    def test_engine_xor_involution(seed):
        check_engine_xor_involution(_seeded_bits(100 + seed))

    @pytest.mark.parametrize("seed", range(15))
    def test_engine_popcount_inclusion_exclusion(seed):
        check_engine_popcount_inclusion_exclusion(
            _seeded_bits(200 + 2 * seed), _seeded_bits(201 + 2 * seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_pack_unpack_roundtrip(seed):
        check_pack_unpack_roundtrip(_seeded_bits(300 + seed))


def test_engine_backends_agree_on_majority():
    a, b, c = (BitVector.from_bits(RNG.integers(0, 2, 500).astype(bool))
               for _ in range(3))
    outs = []
    for backend in ("jnp", "pallas", "ambit_sim"):
        eng = BulkBitwiseEngine(backend)
        outs.append(np.asarray(eng.maj(a, b, c).bits()))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])


@pytest.mark.parametrize("amount", [-70, -64, -33, -32, -31, -1, 0, 1, 31,
                                    32, 33, 64, 70])
@pytest.mark.parametrize("n_bits", [1, 63, 64, 200])
def test_engine_shift_matches_numpy(n_bits, amount):
    """Section 9.1 future-op: logical shift over packed words."""
    rng = np.random.default_rng(n_bits * 1000 + amount)
    arr = rng.integers(0, 2, n_bits).astype(bool)
    eng = BulkBitwiseEngine("jnp")
    got = np.asarray(eng.shift(BitVector.from_bits(arr), amount).bits())
    want = np.zeros_like(arr)
    n = len(arr)
    if amount >= 0:
        if amount < n:
            want[amount:] = arr[:n - amount]
    else:
        if -amount < n:
            want[:n + amount] = arr[-amount:]
    assert np.array_equal(got, want), (amount, n)


def test_tmr_ecc_homomorphism_and_scrub():
    """Section 5.5: TMR is homomorphic over bitwise ops; majority decode
    corrects single-replica flips (and is itself one TRA)."""
    from repro.core.ecc import TMRCodec
    rng = np.random.default_rng(0)
    a = BitVector.from_bits(rng.integers(0, 2, 300).astype(bool))
    b = BitVector.from_bits(rng.integers(0, 2, 300).astype(bool))
    eng = BulkBitwiseEngine("jnp")
    codec = TMRCodec(eng)
    ea, eb = codec.encode(a), codec.encode(b)
    # op on encoded replicas == encode(op on plaintext)
    enc_res = codec.apply("xor", ea, eb)
    plain = eng.xor(a, b)
    assert np.array_equal(np.asarray(codec.decode(enc_res).bits()),
                          np.asarray(plain.bits()))
    # flip bits in ONE replica; scrub recovers
    corrupted = enc_res[0].data.at[0].set(enc_res[0].data[0] ^ 0xFF)
    enc_res[0] = BitVector(corrupted, enc_res[0].n_bits)
    clean, n_fixed = codec.scrub(enc_res)
    assert n_fixed == 8
    assert np.array_equal(np.asarray(codec.decode(clean).bits()),
                          np.asarray(plain.bits()))
