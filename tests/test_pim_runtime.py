"""PIM runtime subsystem: RowAllocator invariants, PimStore lifecycle /
dirty tracking / migration, QueryPlanner differential equivalence against
op-by-op engine evaluation, and AmbitRuntime session accounting.

Property tests run under hypothesis when installed (requirements-dev.txt
pins it); without it they fall back to deterministic seeded sweeps over
the same generators, so collection never fails and coverage is preserved.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (AmbitError, BitVector, BulkBitwiseEngine,
                        DRAMGeometry, Expr, maj)
from repro.core.engine import OpStats
from repro.core.simulator import AmbitDevice
from repro.pim import (AmbitRuntime, COLOCATED, PimStore, RowAllocator,
                       STRIPED)

GEOM = DRAMGeometry(rows_per_subarray=32)  # 14 data rows: compact devices
RNG = np.random.default_rng(11)


# -- RowAllocator invariants --------------------------------------------------


def test_striped_matches_seed_bump_cursor_order():
    """Until something is freed, striped allocation must reproduce the seed
    bump cursor exactly (banks fastest, then subarrays, then rows)."""
    alloc = RowAllocator(banks=3, subarrays=2, data_rows=4)
    got = alloc.alloc(3 * 2 * 4)
    want = [(i % 3, (i // 3) % 2, i // 6) for i in range(3 * 2 * 4)]
    assert got == want
    with pytest.raises(AmbitError, match="full"):
        alloc.alloc(1)


def test_colocated_fills_subarray_first():
    alloc = RowAllocator(banks=2, subarrays=2, data_rows=4,
                         policy=COLOCATED)
    assert alloc.alloc(5) == [(0, 0, 0), (0, 0, 1), (0, 0, 2), (0, 0, 3),
                              (0, 1, 0)]


def test_freed_slots_are_reused_lowest_first():
    alloc = RowAllocator(banks=1, subarrays=1, data_rows=8)
    slots = alloc.alloc(6)
    alloc.free([slots[4], slots[1]])
    assert alloc.alloc(3) == [(0, 0, 1), (0, 0, 4), (0, 0, 6)]


def test_double_free_and_foreign_free_raise():
    alloc = RowAllocator(banks=1, subarrays=1, data_rows=4)
    (slot,) = alloc.alloc(1)
    alloc.free([slot])
    with pytest.raises(AmbitError, match="non-live"):
        alloc.free([slot])
    with pytest.raises(AmbitError, match="non-live"):
        alloc.free([(0, 0, 3)])


def test_failed_alloc_rolls_back():
    alloc = RowAllocator(banks=1, subarrays=2, data_rows=2)
    alloc.alloc(3)
    with pytest.raises(AmbitError, match="full"):
        alloc.alloc(2)          # only 1 slot left
    assert alloc.free_slots == 1  # the partial grab was rolled back
    assert alloc.alloc(1) == [(0, 1, 1)]


def test_scratch_reservation_shrinks_capacity():
    alloc = RowAllocator(banks=1, subarrays=1, data_rows=8, scratch_rows=3)
    assert alloc.capacity == 5
    rows = {r for (_, _, r) in alloc.alloc(5)}
    assert rows == {0, 1, 2, 3, 4}  # top 3 rows never handed out
    with pytest.raises(AmbitError, match="full"):
        alloc.alloc(1)


def test_near_affinity_prefers_neighbor_subarray():
    alloc = RowAllocator(banks=2, subarrays=2, data_rows=8)
    a = alloc.alloc(4)                      # one slot in each subarray
    got = alloc.alloc(2, near=[a[3]])       # affinity to (1, 1)
    assert [(b, s) for (b, s, _) in got] == [(1, 1), (1, 1)]


def test_occupancy_tracking():
    alloc = RowAllocator(banks=2, subarrays=1, data_rows=4)
    slots = alloc.alloc(5)
    assert alloc.occupancy(0, 0) == 3 and alloc.occupancy(1, 0) == 2
    alloc.free(slots[:2])
    assert alloc.occupancy(0, 0) + alloc.occupancy(1, 0) == 3
    assert alloc.live == 3


def check_allocator_invariants(ops_seed):
    """Random alloc/free interleavings: no live slot is ever handed out
    twice, frees return capacity, and exhaustion raises AmbitError."""
    rng = np.random.default_rng(ops_seed)
    alloc = RowAllocator(banks=2, subarrays=2, data_rows=6,
                         scratch_rows=1)
    live = set()
    for _ in range(200):
        if live and rng.integers(3) == 0:
            victims = list(live)[:int(rng.integers(1, len(live) + 1))]
            alloc.free(victims)
            live -= set(victims)
        else:
            n = int(rng.integers(1, 5))
            policy = (STRIPED, COLOCATED)[int(rng.integers(2))]
            try:
                got = alloc.alloc(n, policy=policy)
            except AmbitError:
                assert alloc.free_slots < n
                continue
            for slot in got:
                assert slot not in live, "double allocation"
                assert slot[2] < alloc.usable_rows
                live.add(slot)
        assert alloc.live == len(live)
        assert alloc.free_slots == alloc.capacity - len(live)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_allocator_invariants_random(ops_seed):
        check_allocator_invariants(ops_seed)

else:

    @pytest.mark.parametrize("ops_seed", range(25))
    def test_allocator_invariants_random(ops_seed):
        check_allocator_invariants(ops_seed)


# -- PimStore lifecycle -------------------------------------------------------


def _store(**kw):
    dev = AmbitDevice(GEOM, banks=2, subarrays=2, words=2, seed=3)
    return PimStore(dev, scratch_rows=kw.pop("scratch_rows", 2), **kw)


@pytest.mark.parametrize("n_bits", [1, 127, 128, 129, 700])
def test_put_get_roundtrip(n_bits):
    store = _store()
    bits = RNG.integers(0, 2, n_bits).astype(bool)
    rbv = store.put(BitVector.from_bits(bits))
    got = np.asarray(store.get(rbv).bits())
    assert np.array_equal(got, bits)


def test_put_get_roundtrip_batched_rows():
    store = _store()
    bits = RNG.integers(0, 2, (3, 200)).astype(bool)
    rbv = store.put(BitVector.from_bits(bits))
    assert rbv.shape == (3,)
    assert np.array_equal(np.asarray(store.get(rbv).bits()), bits)


def test_get_clean_is_free_dirty_costs():
    store = _store()
    bits = RNG.integers(0, 2, 300).astype(bool)
    rbv = store.put(BitVector.from_bits(bits))
    assert not rbv.dirty
    base_reads = store.host_reads
    store.get(rbv)                       # clean: cached host copy
    assert store.host_reads == base_reads
    rbv.dirty = True                     # simulate a device-side write
    rbv._host = None
    store.get(rbv)
    assert store.host_reads == base_reads + 1
    assert not rbv.dirty                 # read-back cleaned it


def test_free_releases_rows_and_blocks_use():
    store = _store()
    rbv = store.put(BitVector.from_bits(RNG.integers(0, 2, 64).astype(bool)))
    live_before = store.allocator.live
    store.free(rbv)
    assert store.allocator.live == live_before - rbv.chunks == 0
    with pytest.raises(AmbitError, match="freed"):
        store.get(rbv)
    with pytest.raises(AmbitError, match="freed"):
        store.free(rbv)


def test_colocate_migrates_spanning_operands():
    store = _store()
    n_bits = 128  # one device row at words=2
    a = store.put(BitVector.from_bits(RNG.integers(0, 2, n_bits).astype(bool)))
    b = store.put(BitVector.from_bits(RNG.integers(0, 2, n_bits).astype(bool)))
    assert a.slots[0][:2] != b.slots[0][:2]  # striped: different subarrays
    host_b = np.asarray(store.get(b).bits())
    ns_before = store.device.total_stats().ns
    moved = store.colocate([a, b])
    assert moved == 1
    assert a.slots[0][:2] == b.slots[0][:2]
    assert store.device.total_stats().ns > ns_before  # PSM cost charged
    b.dirty, b._host = True, None       # force a device read
    assert np.array_equal(np.asarray(store.get(b).bits()), host_b)


def test_put_near_aligns_chunks():
    store = _store()
    bits = RNG.integers(0, 2, (2, 600)).astype(bool)
    a = store.put(BitVector.from_bits(bits[0]))
    b = store.put(BitVector.from_bits(bits[1]), near=a.slots)
    assert [s[:2] for s in a.slots] == [s[:2] for s in b.slots]
    assert store.colocate([a, b]) == 0


# -- QueryPlanner differential equivalence ------------------------------------


X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")


def rand_expr(rng, depth=0):
    if depth > 3 or rng.integers(2):
        return (X, Y, Z)[rng.integers(3)]
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a, b = rand_expr(rng, depth + 1), rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


def check_planner_matches_engine(seed, policy):
    """Planner output over resident operands is bit-identical to op-free
    engine evaluation of the same expression on the host."""
    rng = np.random.default_rng(seed)
    expr = rand_expr(rng)
    if expr.op in ("var", "lit"):
        expr = expr ^ Y            # ensure at least one op
    n_bits = int(rng.integers(1, 700))
    bits = rng.integers(0, 2, (3, n_bits)).astype(bool)
    env_host = {k: BitVector.from_bits(bits[i])
                for i, k in enumerate("xyz")}
    want = np.asarray(BulkBitwiseEngine("ambit_sim").eval(
        expr, env_host).bits())
    jnp_got = np.asarray(BulkBitwiseEngine("jnp").eval(
        expr, env_host).bits())

    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                      policy=policy, seed=seed % 7)
    env = {k: rt.put(v) for k, v in env_host.items()}
    out = rt.eval(expr, env)
    assert out.dirty
    got = np.asarray(rt.get(out).bits())
    assert np.array_equal(got, want), (repr(expr), n_bits, policy)
    assert np.array_equal(got, jnp_got)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from([STRIPED, COLOCATED]))
    def test_planner_matches_engine_random(seed, policy):
        check_planner_matches_engine(seed, policy)

else:

    @pytest.mark.parametrize("seed", range(10))
    @pytest.mark.parametrize("policy", [STRIPED, COLOCATED])
    def test_planner_matches_engine_random(seed, policy):
        check_planner_matches_engine(seed, policy)


def test_planner_rejects_misaligned_operands():
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2)
    a = rt.put(BitVector.from_bits(RNG.integers(0, 2, 64).astype(bool)))
    b = rt.put(BitVector.from_bits(RNG.integers(0, 2, 600).astype(bool)))
    with pytest.raises(ValueError, match="row-aligned"):
        rt.eval(X & Y, {"x": a, "y": b})


def test_runtime_rejects_host_operands():
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2)
    a = rt.put(BitVector.from_bits(RNG.integers(0, 2, 64).astype(bool)))
    with pytest.raises(TypeError, match="resident"):
        rt.eval(X & Y, {"x": a, "y": BitVector.zeros(64)})


def test_planner_reports_bank_parallel_time():
    """Independent row groups on different banks overlap: reported time is
    the max over banks, energy the sum (Fig. 21 accounting)."""
    rt = AmbitRuntime(GEOM, banks=2, subarrays=1, words=2, colocate=False)
    n_bits = 4 * 128            # 4 chunks striped over 2 banks
    bits = RNG.integers(0, 2, (2, n_bits)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    rt.and_(a, b)
    rep = rt.planner.last_report
    assert rep.groups == 2 and len(rep.per_bank_ns) == 2
    per_bank = list(rep.per_bank_ns.values())
    assert rep.stats.ns == pytest.approx(max(per_bank))
    assert sum(per_bank) > rep.stats.ns  # parallelism actually claimed


def test_runtime_session_accounting():
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2)
    bits = RNG.integers(0, 2, (2, 500)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    upload = rt.session_stats.bytes_touched
    assert upload == a.device_bytes + b.device_bytes
    out = rt.xor(a, b)
    assert rt.session_stats.bytes_touched == upload  # eval: no host bytes
    assert rt.session_stats.ns > 0
    got = np.asarray(rt.get(out).bits())
    assert np.array_equal(got, bits[0] ^ bits[1])
    assert rt.session_stats.bytes_touched == upload + out.device_bytes
    rt.get(out)                  # clean: no extra traffic
    assert rt.session_stats.bytes_touched == upload + out.device_bytes


def test_opstats_merge_accumulates_all_fields():
    a = OpStats(ns=1.0, energy_nj=2.0, aap_count=3, bytes_touched=4)
    a += OpStats(ns=10.0, energy_nj=20.0, aap_count=30, bytes_touched=40)
    assert (a.ns, a.energy_nj, a.aap_count, a.bytes_touched) == \
        (11.0, 22.0, 33, 44)


# -- LRU spill / eviction -----------------------------------------------------


def _tiny_store(seed=5):
    """1 bank x 1 subarray x 12 usable rows (14 data rows - 2 scratch)."""
    dev = AmbitDevice(GEOM, banks=1, subarrays=1, words=2, seed=seed)
    return PimStore(dev, scratch_rows=2)


def _bv(n_chunks):
    return BitVector.from_bits(
        RNG.integers(0, 2, n_chunks * 128).astype(bool))


def test_full_device_spills_lru_clean_for_free():
    store = _tiny_store()
    bv_a = _bv(6)
    host_a = np.asarray(bv_a.bits())
    a = store.put(bv_a, name="a")
    b = store.put(_bv(5), name="b")
    base_reads, base_bytes = store.host_reads, store.bytes_from_device
    store.put(_bv(6), name="c")          # needs 6, only 1 free: evict LRU
    assert a.spilled and not a.freed
    assert not b.spilled
    assert (store.evicted_clean, store.evicted_dirty) == (1, 0)
    # clean spill: zero ledger bytes, and the handle still reads for free
    assert store.host_reads == base_reads
    assert store.bytes_from_device == base_bytes
    assert np.array_equal(np.asarray(store.get(a).bits()), host_a)
    assert store.host_reads == base_reads


def test_get_refreshes_lru_recency():
    store = _tiny_store()
    a = store.put(_bv(6), name="a")
    b = store.put(_bv(5), name="b")
    store.get(a)                         # a is now most-recently-used
    store.put(_bv(5), name="c")
    assert b.spilled and not a.spilled


def test_dirty_eviction_charges_readback():
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2, scratch_rows=2)
    bits = RNG.integers(0, 2, (2, 4 * 128)).astype(bool)
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    out = rt.xor(a, b)                   # dirty, device now full (12/12)
    out_bytes = out.device_bytes
    rt.get(a), rt.get(b)                 # free touches: out becomes LRU
    base_bytes = rt.store.bytes_from_device
    d = rt.put(_bv(4))                   # must evict `out` - dirty
    assert out.spilled
    assert rt.store.evicted_dirty == 1
    assert rt.store.bytes_from_device == base_bytes + out_bytes
    # the spill read-back was charged to the put that forced it
    assert rt.last_stats.bytes_touched == d.device_bytes + out_bytes
    # and the evicted result is still correct, served from the host copy
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] ^ bits[1])


def test_pinned_is_never_evicted():
    store = _tiny_store()
    a = store.put(_bv(6), pin=True, name="a")
    b = store.put(_bv(5), name="b")
    store.put(_bv(6), name="c")          # evicts b, NOT the pinned a
    assert b.spilled and not a.spilled
    with pytest.raises(AmbitError, match="pinned or in use"):
        store.put(_bv(12), name="d")     # a alone cannot be evicted
    with pytest.raises(AmbitError, match="pinned"):
        store.spill(a)


def test_planner_protects_in_use_operands():
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2, scratch_rows=2)
    bits = RNG.integers(0, 2, (3, 4 * 128)).astype(bool)
    cold = rt.put(BitVector.from_bits(bits[2]))   # oldest: the LRU victim
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    out = rt.and_(a, b)                  # dst rows force an eviction
    assert cold.spilled and not a.spilled and not b.spilled
    assert np.array_equal(np.asarray(rt.get(out).bits()),
                          bits[0] & bits[1])


def test_spilled_operand_faults_back_in_on_eval():
    rt = AmbitRuntime(GEOM, banks=1, subarrays=1, words=2, scratch_rows=2)
    bits = RNG.integers(0, 2, (3, 4 * 128)).astype(bool)
    cold = rt.put(BitVector.from_bits(bits[2]))
    a = rt.put(BitVector.from_bits(bits[0]))
    b = rt.put(BitVector.from_bits(bits[1]), near=a.slots)
    out = rt.and_(a, b)                  # spills `cold`
    assert cold.spilled
    rt.free(out)
    rt.free(b)
    res = rt.xor(cold, a)                # fault-in charged to this call
    assert not cold.spilled
    assert rt.last_stats.bytes_touched >= cold.device_bytes
    assert np.array_equal(np.asarray(rt.get(res).bits()),
                          bits[2] ^ bits[0])


def test_session_ledger_deterministic(record_ledger):
    """Canonical eviction-heavy session; the recorded ledger is diffed
    across two CI runs to catch nondeterministic placement."""
    rt = AmbitRuntime(GEOM, banks=2, subarrays=2, words=2,
                      scratch_rows=2, seed=9)
    rng = np.random.default_rng(17)
    bits = rng.integers(0, 2, (6, 6 * 128)).astype(bool)
    vecs = [rt.put(BitVector.from_bits(b)) for b in bits]
    acc = rt.and_(vecs[0], vecs[1])
    acc = rt.xor(acc, vecs[2])           # device now full (48/48)
    acc = rt.or_(acc, vecs[3])           # dst rows force LRU evictions
    rt.get(acc)
    assert rt.store.evicted_clean + rt.store.evicted_dirty > 0
    record_ledger("pim_runtime_session",
                  f"{rt.session_stats!r} evicted="
                  f"{rt.store.evicted_clean}+{rt.store.evicted_dirty}")


def test_device_alloc_rows_shim_free_and_reuse():
    """The back-compat shim supports free/realloc (the seed cursor could
    only run out)."""
    dev = AmbitDevice(GEOM, banks=2, subarrays=2, words=2)
    slots = dev.alloc_rows(6)
    dev.free_rows(slots[:3])
    again = dev.alloc_rows(3)
    assert sorted(again) == sorted(slots[:3])
