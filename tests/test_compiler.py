"""Compiler: expression DAG -> AAP programs. Bit-exactness against the
numpy oracle on the device simulator + optimization quality (AAP counts
never regress) + randomized property tests.

The property tests run under hypothesis when it is installed
(requirements-dev.txt pins it); without it they fall back to deterministic
seeded sweeps over the same generator, so collection never fails and
coverage is preserved.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallbacks below keep coverage
    HAVE_HYPOTHESIS = False

from repro.core import (AmbitSubarray, Expr, ONE, ZERO, compile_expr,
                        eval_expr, maj)

WORDS = 4
RNG = np.random.default_rng(7)
VARS = {"x": 0, "y": 1, "z": 2}


def run_on_sim(expr, env, optimize):
    comp = compile_expr(expr, VARS, 3, optimize=optimize)
    sub = AmbitSubarray(words=WORDS)
    for name, row in VARS.items():
        sub.write_row(row, env[name])
    sub.run(comp.program)
    return sub.read_row(3), comp


def rand_env(rng=None):
    rng = RNG if rng is None else rng
    return {k: rng.integers(0, 2**64, WORDS, dtype=np.uint64)
            for k in VARS}


X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")

CASES = [
    X & Y, X | Y, X ^ Y, ~X, ~(X & Y), ~(X | Y), ~(X ^ Y),
    (X & Y) & Z, (X | Y) | Z, (X ^ Y) ^ Z,
    maj(X, Y, Z), ~maj(X, Y, Z),
    (X & Y) | ((X & Y) ^ Z),              # CSE
    ~((X | Y) & (Y ^ Z)),                 # fusion + mixed
    ((X & Y) | (~Z & X)) ^ (Y | ~X),      # deep DAG
    (X & ONE) | (Y & ZERO),               # constant folding
    ~~X & Y,                              # double negation
]


@pytest.mark.parametrize("expr", CASES, ids=[repr(e)[:40] for e in CASES])
@pytest.mark.parametrize("optimize", [False, True])
def test_compile_matches_oracle(expr, optimize):
    env = rand_env()
    got, _ = run_on_sim(expr, env, optimize)
    assert np.array_equal(got, eval_expr(expr, env))


@pytest.mark.parametrize("expr", CASES, ids=[repr(e)[:40] for e in CASES])
def test_optimizer_never_regresses(expr):
    n = compile_expr(expr, VARS, 3, optimize=False)
    o = compile_expr(expr, VARS, 3, optimize=True)
    assert o.stats.ns <= n.stats.ns


def test_chain_and_reuses_designated_rows():
    """Left-deep AND chains drop staging copies via TRA row reuse."""
    n = compile_expr((X & Y) & Z, VARS, 3, optimize=False)
    o = compile_expr((X & Y) & Z, VARS, 3, optimize=True)
    assert n.n_aap == 8
    assert o.n_aap < n.n_aap


def test_nand_fusion_matches_paper_count():
    o = compile_expr(~(X & Y), VARS, 3, optimize=True)
    assert (o.n_aap, o.n_ap) == (5, 0)  # Figure 20b


# -- randomized property tests ------------------------------------------------
# One shared generator: hypothesis drives it via st.data() when installed;
# the deterministic fallback drives it from seeded numpy Generators.


def rand_expr(rng: np.random.Generator, depth: int = 0) -> Expr:
    if depth > 3 or rng.integers(2):
        return (X, Y, Z)[rng.integers(3)]
    op = ("and", "or", "xor", "not", "maj")[rng.integers(5)]
    if op == "not":
        return ~rand_expr(rng, depth + 1)
    if op == "maj":
        return maj(rand_expr(rng, depth + 1), rand_expr(rng, depth + 1),
                   rand_expr(rng, depth + 1))
    a = rand_expr(rng, depth + 1)
    b = rand_expr(rng, depth + 1)
    return {"and": a & b, "or": a | b, "xor": a ^ b}[op]


def check_random_expression_bit_exact(expr, seed):
    rng = np.random.default_rng(seed)
    env = {k: rng.integers(0, 2**64, 2, dtype=np.uint64) for k in VARS}
    comp = compile_expr(expr, VARS, 3, optimize=True)
    sub = AmbitSubarray(words=2)
    for name, row in VARS.items():
        sub.write_row(row, env[name])
    sub.run(comp.program)
    assert np.array_equal(sub.read_row(3), eval_expr(expr, env))


def check_demorgan_equivalence(expr, env):
    """~(a&b) == ~a|~b at the compiled-program level (both bit-exact)."""
    lhs = ~(expr & X)
    rhs = ~expr | ~X
    g1, _ = run_on_sim(lhs, env, True)
    g2, _ = run_on_sim(rhs, env, True)
    assert np.array_equal(g1, g2)


if HAVE_HYPOTHESIS:

    @st.composite
    def exprs(draw, depth=0):
        if depth > 3 or draw(st.booleans()):
            return draw(st.sampled_from([X, Y, Z]))
        op = draw(st.sampled_from(["and", "or", "xor", "not", "maj"]))
        if op == "not":
            return ~draw(exprs(depth=depth + 1))
        if op == "maj":
            return maj(draw(exprs(depth=depth + 1)),
                       draw(exprs(depth=depth + 1)),
                       draw(exprs(depth=depth + 1)))
        a = draw(exprs(depth=depth + 1))
        b = draw(exprs(depth=depth + 1))
        return {"and": a & b, "or": a | b, "xor": a ^ b}[op]

    @settings(max_examples=40, deadline=None)
    @given(exprs(), st.integers(0, 2**32 - 1))
    def test_random_expressions_bit_exact(expr, seed):
        check_random_expression_bit_exact(expr, seed)

    @settings(max_examples=25, deadline=None)
    @given(exprs())
    def test_demorgan_equivalence(expr):
        check_demorgan_equivalence(expr, rand_env())

else:

    @pytest.mark.parametrize("seed", range(40))
    def test_random_expressions_bit_exact(seed):
        rng = np.random.default_rng(1000 + seed)
        check_random_expression_bit_exact(rand_expr(rng), seed)

    @pytest.mark.parametrize("seed", range(25))
    def test_demorgan_equivalence(seed):
        rng = np.random.default_rng(2000 + seed)
        check_demorgan_equivalence(rand_expr(rng), rand_env(rng))
