"""Differential harness: batched AmbitSubarray vs the per-row scalar path.

The batched simulator's contract is that batch row ``i`` behaves exactly
like an independent n_rows=1 subarray executing the same command stream.
This suite proves it differentially:

  * randomized AAP/AP macro programs (including 2- and 3-wordline B-group
    activations, C-group sources/destinations and DCC n-wordlines) and all
    OP_TEMPLATES ops, executed on N scalar subarrays vs one batch-N
    subarray, asserting bit-exact row/cell contents and identical
    CommandStats (counts exact; ns/energy to fp-roundoff);
  * identical AmbitError raising for the two undefined-behaviour cases
    (control-row overwrite, disagreeing 2-cell activation from precharged),
    including when only a single batch row triggers them;
  * engine-level equivalence: BulkBitwiseEngine("ambit_sim") batched vs
    batch_rows=False, plus compile-cache behaviour;
  * device-level equivalence: grouped batched dispatch vs sequential
    per-slot dispatch, including PSM staging and the aliasing-hazard
    fallback.
"""

import numpy as np
import pytest

from repro.core import (AmbitDevice, AmbitError, AmbitSubarray, B, BitVector,
                        BulkBitwiseEngine, C, CommandStats, D, DRAMGeometry,
                        Expr, compile_cache_clear, compile_cache_info, maj)
from repro.core.commands import AAP, AP, OP_ARITY, OP_TEMPLATES

GEOM = DRAMGeometry(rows_per_subarray=32)  # 14 data rows: cheap full-state diff
WORDS = 4
N_ROWS = 5
FULL = np.uint64(0xFFFFFFFFFFFFFFFF)


# -- state injection / comparison helpers ------------------------------------


def _inject_state(sub: AmbitSubarray, d_vals, t_vals, dcc_vals) -> None:
    """Give a subarray fully deterministic cell state (boot content is
    random, and scalar/batched RNG layouts differ by construction)."""
    lo = 0 if sub.n_rows == 1 else None
    for d, val in enumerate(d_vals):
        sub.write_row(d, val if lo is None else val[lo])
    for wl, val in t_vals.items():
        sub.t_rows[wl] = val.copy() if lo is None else val[lo:lo + 1].copy()
    for name, val in dcc_vals.items():
        sub.dcc[name] = val.copy() if lo is None else val[lo:lo + 1].copy()


def _make_state(rng: np.random.Generator):
    """(N_ROWS, WORDS) content for every cell. T/DCC rows are drawn from a
    small per-row pool so 2-cell activations agree often enough to exercise
    both the defined and the undefined path."""
    d_vals = [rng.integers(0, 2**64, (N_ROWS, WORDS), dtype=np.uint64)
              for _ in range(GEOM.data_rows)]
    pool = [np.zeros((N_ROWS, WORDS), np.uint64),
            np.full((N_ROWS, WORDS), FULL, np.uint64),
            rng.integers(0, 2**64, (N_ROWS, WORDS), dtype=np.uint64)]
    t_vals = {wl: pool[rng.integers(3)].copy()
              for wl in ("T0", "T1", "T2", "T3")}
    dcc_vals = {name: pool[rng.integers(3)].copy()
                for name in ("DCC0", "DCC1")}
    return d_vals, t_vals, dcc_vals


def _scalar_for_row(r, d_vals, t_vals, dcc_vals) -> AmbitSubarray:
    sub = AmbitSubarray(GEOM, words=WORDS, n_rows=1)
    for d, val in enumerate(d_vals):
        sub.write_row(d, val[r])
    for wl, val in t_vals.items():
        sub.t_rows[wl] = val[r:r + 1].copy()
    for name, val in dcc_vals.items():
        sub.dcc[name] = val[r:r + 1].copy()
    return sub


def _batched(d_vals, t_vals, dcc_vals) -> AmbitSubarray:
    sub = AmbitSubarray(GEOM, words=WORDS, n_rows=N_ROWS)
    for d, val in enumerate(d_vals):
        sub.write_row(d, val)
    for wl, val in t_vals.items():
        sub.t_rows[wl] = val.copy()
    for name, val in dcc_vals.items():
        sub.dcc[name] = val.copy()
    return sub


def _assert_stats_equal(got: CommandStats, want: CommandStats) -> None:
    assert got.activates == want.activates
    assert got.wordlines == want.wordlines
    assert got.precharges == want.precharges
    assert got.aap_count == want.aap_count
    assert got.ap_count == want.ap_count
    # float accumulation order differs (row-major vs x*n): fp-roundoff only
    assert got.ns == pytest.approx(want.ns, rel=1e-12)
    assert got.energy_nj == pytest.approx(want.energy_nj, rel=1e-12)


def _run_differential(prog) -> None:
    """Execute `prog` on N scalar subarrays and one batch-N subarray with
    identical state; assert identical outcome (error or full final state +
    stats)."""
    rng = np.random.default_rng(hash(tuple(repr(m) for m in prog)) % 2**32)
    d_vals, t_vals, dcc_vals = _make_state(rng)

    scalar_subs = [_scalar_for_row(r, d_vals, t_vals, dcc_vals)
                   for r in range(N_ROWS)]
    scalar_err = False
    scalar_total = CommandStats()
    for sub in scalar_subs:
        try:
            sub.run(prog)
        except AmbitError:
            scalar_err = True
        scalar_total.merge(sub.stats)

    batched = _batched(d_vals, t_vals, dcc_vals)
    batched_err = False
    try:
        batched.run(prog)
    except AmbitError:
        batched_err = True

    assert batched_err == scalar_err, prog
    if scalar_err:
        return  # post-error state is explicitly undefined; outcome matched

    for d in range(GEOM.data_rows):
        got = batched.read_row(d)
        for r, sub in enumerate(scalar_subs):
            assert np.array_equal(got[r], sub.read_row(d)), (d, r, prog)
    for wl in ("T0", "T1", "T2", "T3"):
        for r, sub in enumerate(scalar_subs):
            assert np.array_equal(batched.t_rows[wl][r],
                                  sub.t_rows[wl][0]), (wl, r, prog)
    for name in ("DCC0", "DCC1"):
        for r, sub in enumerate(scalar_subs):
            assert np.array_equal(batched.dcc[name][r],
                                  sub.dcc[name][0]), (name, r, prog)
    _assert_stats_equal(batched.stats, scalar_total)


# -- randomized macro programs ------------------------------------------------


def _rand_addr(rng, kind):
    if kind == "src":
        # biased toward defined behaviour but includes every address space
        roll = rng.integers(10)
        if roll < 5:
            return D(int(rng.integers(GEOM.data_rows)))
        if roll < 7:
            return C(int(rng.integers(2)))
        return B(int(rng.integers(16)))
    if kind == "dst":
        roll = rng.integers(10)
        if roll < 5:
            return B(int(rng.integers(16)))
        if roll < 9:
            return D(int(rng.integers(GEOM.data_rows)))
        return C(int(rng.integers(2)))  # usually a control-row write error
    raise KeyError(kind)


def _rand_program(seed: int):
    rng = np.random.default_rng(seed)
    prog = []
    for _ in range(int(rng.integers(2, 9))):
        if rng.integers(4) == 0:
            prog.append(AP(_rand_addr(rng, "src")))
        else:
            prog.append(AAP(_rand_addr(rng, "src"), _rand_addr(rng, "dst")))
    return prog


@pytest.mark.parametrize("seed", range(60))
def test_random_programs_differential(seed):
    _run_differential(_rand_program(seed))


@pytest.mark.parametrize("op", sorted(OP_TEMPLATES))
def test_op_templates_differential(op):
    n_args = OP_ARITY[op]
    args = [D(i) for i in range(n_args - 1)] + [D(GEOM.data_rows - 2)]
    _run_differential(OP_TEMPLATES[op](*args))


@pytest.mark.parametrize("op", sorted(OP_TEMPLATES))
def test_batched_bbop_matches_oracle(op):
    """Direct numpy-oracle check of batched bbop results for every op."""
    rng = np.random.default_rng(11)
    n_srcs = OP_ARITY[op] - 1
    srcs = [rng.integers(0, 2**64, (N_ROWS, WORDS), dtype=np.uint64)
            for _ in range(n_srcs)]
    sub = AmbitSubarray(GEOM, words=WORDS, n_rows=N_ROWS)
    for i, s in enumerate(srcs):
        sub.write_row(i, s)
    dst = GEOM.data_rows - 2
    sub.bbop(op, dst, *range(n_srcs))
    oracle = {
        "not": lambda a: ~a, "copy": lambda a: a,
        "and": lambda a, b: a & b, "or": lambda a, b: a | b,
        "nand": lambda a, b: ~(a & b), "nor": lambda a, b: ~(a | b),
        "xor": lambda a, b: a ^ b, "xnor": lambda a, b: ~(a ^ b),
        "maj3": lambda a, b, c: (a & b) | (b & c) | (c & a),
        "zero": lambda: np.zeros((N_ROWS, WORDS), np.uint64),
        "one": lambda: np.full((N_ROWS, WORDS), FULL, np.uint64),
    }[op](*srcs)
    assert np.array_equal(sub.read_row(dst), oracle)


# -- the two named undefined-behaviour cases ----------------------------------


def test_control_row_write_raises_in_exactly_matching_rows():
    """AAP(D0, C0) overwrites a control row unless D0 is all-zeros. Flip a
    single bit in a single batch row: that row's scalar run raises, so the
    batched run must raise too."""
    prog = [AAP(D(0), C(0))]
    d_vals = [np.zeros((N_ROWS, WORDS), np.uint64)
              for _ in range(GEOM.data_rows)]
    t_vals = {wl: np.zeros((N_ROWS, WORDS), np.uint64)
              for wl in ("T0", "T1", "T2", "T3")}
    dcc_vals = {n: np.zeros((N_ROWS, WORDS), np.uint64)
                for n in ("DCC0", "DCC1")}

    # all-zero D0: restoring C0's own value is legal on every row
    batched = _batched(d_vals, t_vals, dcc_vals)
    batched.run(prog)

    d_vals[0][2, 1] = np.uint64(1)  # poison one word of one batch row
    scalar = _scalar_for_row(2, d_vals, t_vals, dcc_vals)
    with pytest.raises(AmbitError, match="read-only"):
        scalar.run(prog)
    batched = _batched(d_vals, t_vals, dcc_vals)
    with pytest.raises(AmbitError, match="read-only"):
        batched.run(prog)


def test_disagreeing_two_cell_activate_raises_in_exactly_matching_rows():
    """AP(B10) activates T2+T3 from precharged: defined iff they agree,
    row by row."""
    prog = [AP(B(10))]
    d_vals = [np.zeros((N_ROWS, WORDS), np.uint64)
              for _ in range(GEOM.data_rows)]
    agree = np.full((N_ROWS, WORDS), FULL, np.uint64)
    t_vals = {"T0": agree.copy(), "T1": agree.copy(),
              "T2": agree.copy(), "T3": agree.copy()}
    dcc_vals = {n: agree.copy() for n in ("DCC0", "DCC1")}

    batched = _batched(d_vals, t_vals, dcc_vals)
    batched.run(prog)  # all rows agree: defined everywhere

    t_vals["T3"][4, 0] = np.uint64(0)  # one row now disagrees
    scalar = _scalar_for_row(4, d_vals, t_vals, dcc_vals)
    with pytest.raises(AmbitError, match="disagreeing"):
        scalar.run(prog)
    batched = _batched(d_vals, t_vals, dcc_vals)
    with pytest.raises(AmbitError, match="disagreeing"):
        batched.run(prog)


# -- engine-level equivalence -------------------------------------------------


X, Y, Z = Expr.var("x"), Expr.var("y"), Expr.var("z")
ENGINE_EXPRS = [
    X & Y,
    ~(X ^ Y),
    ((X & Y) | ~Z) ^ (X | Y),           # 6 ops
    maj(X, Y, Z) ^ (~X | (Y & Z)),
]


@pytest.mark.parametrize("expr", ENGINE_EXPRS,
                         ids=[repr(e)[:32] for e in ENGINE_EXPRS])
@pytest.mark.parametrize("rows", [1, 3])
def test_engine_batched_matches_per_row(expr, rows):
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (3, rows, 257)).astype(bool)
    env = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xyz")}
    batched = BulkBitwiseEngine("ambit_sim")
    legacy = BulkBitwiseEngine("ambit_sim", batch_rows=False)
    out_b = batched.eval(expr, env)
    st_b = batched.last_stats
    out_l = legacy.eval(expr, env)
    st_l = legacy.last_stats
    assert np.array_equal(np.asarray(out_b.bits()), np.asarray(out_l.bits()))
    assert st_b.aap_count == st_l.aap_count
    assert st_b.bytes_touched == st_l.bytes_touched
    assert st_b.ns == pytest.approx(st_l.ns, rel=1e-12)
    assert st_b.energy_nj == pytest.approx(st_l.energy_nj, rel=1e-12)


def test_engine_zero_row_operands():
    """Zero-row batches are a no-op in both modes (no subarray is built)."""
    env = {k: BitVector.from_bits(np.zeros((0, 64), bool)) for k in "xy"}
    for eng in (BulkBitwiseEngine("ambit_sim"),
                BulkBitwiseEngine("ambit_sim", batch_rows=False)):
        out = eng.eval(X & Y, env)
        assert np.asarray(out.bits()).shape == (0, 64)
        assert eng.last_stats.aap_count == 0
        assert eng.last_stats.ns == 0.0


def test_compile_cache_hits_across_calls():
    compile_cache_clear()
    eng = BulkBitwiseEngine("ambit_sim")
    rng = np.random.default_rng(9)
    expr = (X & Y) ^ ~Z
    for _ in range(3):
        bits = rng.integers(0, 2, (3, 2, 64)).astype(bool)
        env = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xyz")}
        eng.eval(expr, env)
    info = compile_cache_info()
    assert info.misses == 1 and info.hits == 2
    # different optimize flag is a distinct program shape
    BulkBitwiseEngine("ambit_sim", optimize=False).eval(expr, env)
    assert compile_cache_info().misses == 2


def test_engine_stats_scale_with_rows():
    """A batch of R rows must report exactly R times the 1-row ledger."""
    expr = X ^ Y
    eng = BulkBitwiseEngine("ambit_sim")
    rng = np.random.default_rng(13)

    def stats_for(rows):
        bits = rng.integers(0, 2, (2, rows, 128)).astype(bool)
        env = {k: BitVector.from_bits(bits[i]) for i, k in enumerate("xy")}
        eng.eval(expr, env)
        return eng.last_stats

    one = stats_for(1)
    eight = stats_for(8)
    assert eight.aap_count == 8 * one.aap_count
    assert eight.ns == pytest.approx(8 * one.ns, rel=1e-12)
    assert eight.energy_nj == pytest.approx(8 * one.energy_nj, rel=1e-12)


# -- device-level equivalence -------------------------------------------------


def _fresh_pair(**kw):
    grouped = AmbitDevice(GEOM, banks=2, subarrays=2, words=WORDS, **kw)
    seq = AmbitDevice(GEOM, banks=2, subarrays=2, words=WORDS,
                      batch_groups=False, **kw)
    return grouped, seq


def _alloc_write(dev, rng, n):
    slots = dev.alloc_rows(n)
    data = rng.integers(0, 2**64, (n, dev.words), dtype=np.uint64)
    dev.write(slots, data)
    return slots, data


@pytest.mark.parametrize("op", ["and", "xor", "nand", "maj3", "not"])
@pytest.mark.parametrize("n", [1, 4, 13])
def test_device_grouped_matches_sequential(op, n):
    n_srcs = OP_ARITY[op] - 1
    grouped, seq = _fresh_pair()
    outs = []
    for dev in (grouped, seq):
        rng = np.random.default_rng(42)
        src_slots, src_data = zip(*[_alloc_write(dev, rng, n)
                                    for _ in range(n_srcs)]) \
            if n_srcs else ((), ())
        dst = dev.alloc_rows(n)
        dev.bbop(op, dst, *src_slots)
        outs.append((dev.read(dst), dev.total_stats()))
    (got, st_g), (want, st_s) = outs
    assert np.array_equal(got, want)
    assert st_g.aap_count == st_s.aap_count
    assert st_g.activates == st_s.activates
    assert st_g.ns == pytest.approx(st_s.ns, rel=1e-12)
    assert st_g.energy_nj == pytest.approx(st_s.energy_nj, rel=1e-12)


def test_device_psm_slow_path_grouped_matches_sequential():
    """Force non-co-located sources: slot lists deliberately misaligned so
    every op needs PSM staging into the destination subarray."""
    outs = []
    for batch_groups in (True, False):
        dev = AmbitDevice(GEOM, banks=2, subarrays=2, words=WORDS,
                          batch_groups=batch_groups)
        rng = np.random.default_rng(3)
        a_slots, a_data = _alloc_write(dev, rng, 6)
        b_slots, b_data = _alloc_write(dev, rng, 6)
        d_slots = dev.alloc_rows(6)
        # rotate sources: corresponding slots now live in other subarrays
        dev.bbop("xor", d_slots, a_slots[1:] + a_slots[:1],
                 b_slots[2:] + b_slots[:2])
        expect = np.roll(a_data, -1, 0) ^ np.roll(b_data, -2, 0)
        got = dev.read(d_slots)
        assert np.array_equal(got, expect)
        outs.append((got, dev.total_stats()))
    (g, st_g), (s, st_s) = outs
    assert np.array_equal(g, s)
    assert st_g.aap_count == st_s.aap_count
    assert st_g.ns == pytest.approx(st_s.ns, rel=1e-12)


def test_device_aliasing_hazard_falls_back_to_sequential():
    """dst of slot i feeds src of slot i+1: grouped execution must preserve
    the sequential read-after-write chain (it falls back internally)."""
    for batch_groups in (True, False):
        dev = AmbitDevice(GEOM, banks=1, subarrays=1, words=WORDS,
                          batch_groups=batch_groups)
        rng = np.random.default_rng(8)
        a_slots, a_data = _alloc_write(dev, rng, 3)
        d_slots = dev.alloc_rows(3)
        # d[0] = ~a[0]; d[1] = ~d[0]; d[2] = ~d[1]  (chained dependencies)
        dev.bbop("not", d_slots, [a_slots[0], d_slots[0], d_slots[1]])
        got = dev.read(d_slots)
        assert np.array_equal(got[0], ~a_data[0])
        assert np.array_equal(got[1], a_data[0])
        assert np.array_equal(got[2], ~a_data[0])
