"""End-to-end training driver: train a small LM for a few hundred steps
with the full production loop - BitWeaving-filtered data pipeline, AdamW,
checkpointing, fault-tolerant supervisor, straggler watchdog.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
(the default preset is CPU-friendly ~2M params; --preset 100m builds a
~100M-param model - a few hours of CPU, minutes on one accelerator)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, FilteredSyntheticLM
from repro.models import build_model
from repro.optim.optimizer import OptimizerConfig
from repro.runtime import Supervisor
from repro.train.step import init_state, make_train_step


def build_cfg(preset: str):
    base = get_config("qwen2.5-3b")
    if preset == "100m":
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab=32768)
    return dataclasses.replace(
        base, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=512, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    model = build_model(cfg)
    print(f"model: {cfg.name}-{args.preset} "
          f"N={model.n_params()/1e6:.1f}M params")

    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt, remat=False))
    data = FilteredSyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, noise=0.02),
        n_docs=4096)
    print(f"data: {data.mask.sum()}/{len(data.mask)} docs pass the "
          f"BitWeaving quality filter")

    def batch_at(s):
        b = data.batch_at(s)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    ck = Checkpointer(args.ckpt_dir, keep_n=3)
    start = 0
    if args.resume and ck.latest_step() is not None:
        start, state = ck.restore()
        print(f"resumed from step {start}")
    else:
        state = init_state(model, jax.random.PRNGKey(0))

    sup = Supervisor(ck, checkpoint_every=50)
    t0 = time.time()
    state, hist = sup.run(state, batch_at, step_fn, start, args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist if "loss" in h]
    toks = args.batch * args.seq * len(losses)
    print(f"steps {start}->{args.steps}: loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-10:]):.3f}  ({toks/dt:.0f} tok/s)")
    slow = [h["step"] for h in hist if h.get("slow")]
    if slow:
        print(f"straggler watchdog flagged steps: {slow}")


if __name__ == "__main__":
    main()
