"""Bitmap-index analytics (paper Section 8.1): the weekly-active-users
query on all three engine backends, with DRAM-model timing.

Run:  PYTHONPATH=src python examples/bitmap_analytics.py
"""

import numpy as np

from repro.apps.bitmap_index import BitmapIndex, baseline_cpu_ns
from repro.core import BulkBitwiseEngine


def main():
    rng = np.random.default_rng(0)
    n_users, weeks = 1 << 20, 6

    for backend in ("jnp", "pallas"):
        eng = BulkBitwiseEngine(backend)
        idx = BitmapIndex(n_users, eng)
        for w in range(weeks):
            idx.add(f"week{w}", rng.choice(n_users, n_users // 3,
                                           replace=False))
        idx.add("male", rng.choice(n_users, n_users // 2, replace=False))
        uniq, per_week, _ = idx.weekly_active_query(
            [f"week{w}" for w in range(weeks)], "male")
        print(f"[{backend:7s}] users active all {weeks} weeks: {uniq}; "
              f"male per week: {per_week}")

    # paper-units comparison (DRAM model vs channel-bound CPU)
    n_ops = 2 * weeks - 1
    rows = n_users // 65536
    ambit_ns = n_ops * max(1, rows // 8) * 4 * 49.0
    cpu_ns = baseline_cpu_ns(n_users, n_ops)
    print(f"DRAM model: Ambit {ambit_ns/1e3:.1f} us vs CPU "
          f"{cpu_ns/1e3:.1f} us -> {cpu_ns/ambit_ns:.1f}x "
          f"(paper reports ~6x end-to-end)")


if __name__ == "__main__":
    main()
