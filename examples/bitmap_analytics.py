"""Bitmap-index analytics (paper Section 8.1): the weekly-active-users
query on all engine backends, with the DRAM ledger *measured* by the
device model - host (non-resident) engine path vs the resident PIM
runtime - and compared against the old analytic formula.

Run:  PYTHONPATH=src python examples/bitmap_analytics.py
"""

import numpy as np

from repro.apps.bitmap_index import BitmapIndex, baseline_cpu_ns
from repro.core import BulkBitwiseEngine
from repro.pim import AmbitRuntime


def main():
    rng = np.random.default_rng(0)
    n_users, weeks = 1 << 20, 6
    week_names = [f"week{w}" for w in range(weeks)]

    def populate(idx):
        member_rng = np.random.default_rng(1)
        for w in week_names:
            idx.add(w, member_rng.choice(n_users, n_users // 3,
                                         replace=False))
        idx.add("male", member_rng.choice(n_users, n_users // 2,
                                          replace=False))

    for backend in ("jnp", "pallas"):
        idx = BitmapIndex(n_users, BulkBitwiseEngine(backend))
        populate(idx)
        uniq, per_week, _ = idx.weekly_active_query(week_names, "male")
        print(f"[{backend:8s}] users active all {weeks} weeks: {uniq}; "
              f"male per week: {per_week}")

    # Measured DRAM ledger, host path: every AND round-trips the channel.
    # Run it geometry-faithfully - each bitmap reshaped to (16, 65536) so
    # one logical row = one real 8 KB DRAM row, the same layout the
    # resident path uses (a flat 2^20-bit operand would be modeled as one
    # fictitious 128 KB row and undercount AAPs 16x).
    idx = BitmapIndex(n_users, BulkBitwiseEngine("ambit_sim"))
    populate(idx)
    uniq, per_week, _ = idx.weekly_active_query(week_names, "male")
    print(f"[ambit_sim] users active all {weeks} weeks: {uniq}; "
          f"male per week: {per_week}")

    from repro.core import BitVector
    from repro.core.engine import OpStats
    eng = BulkBitwiseEngine("ambit_sim")
    host_st = OpStats()
    rows = {nm: BitVector.from_bits(
        np.asarray(idx.bitmaps[nm].bits()).reshape(16, 65536))
        for nm in week_names + ["male"]}
    acc = rows[week_names[0]]
    for nm in week_names[1:]:
        acc = eng.and_(acc, rows[nm])
        host_st += eng.last_stats
    for nm in week_names:
        eng.and_(rows[nm], rows["male"])
        host_st += eng.last_stats
    assert int(acc.popcount().sum()) == uniq
    print(f"[ambit_sim] measured host-path ledger: {host_st.ns/1e3:.1f} us "
          f"{host_st.energy_nj/1e3:.2f} uJ aap={host_st.aap_count} "
          f"host_bytes={host_st.bytes_touched}")

    # Measured DRAM ledger, resident path: bitmaps live in DRAM, queries
    # lower as whole expression trees, only popcounts read data back.
    rt = AmbitRuntime(seed=2)
    idx = BitmapIndex(n_users, runtime=rt)
    populate(idx)
    uniq_r, per_week_r, res_st = idx.weekly_active_query(week_names, "male")
    assert (uniq_r, per_week_r) == (uniq, per_week), "paths disagree"
    print(f"[resident ] measured ledger: {res_st.ns/1e3:.1f} us "
          f"{res_st.energy_nj/1e3:.2f} uJ aap={res_st.aap_count} "
          f"host_bytes={res_st.bytes_touched} "
          f"(upload once: {rt.store.bytes_to_device} B, "
          f"read-backs: {rt.host_reads})")

    # Sharded resident path: the same bitmaps over a 4-device PimCluster.
    # Round-robin chunk placement + the near= chain keep co-queried
    # bitmaps chunk-aligned, so each device runs 1/4 of every op (time is
    # max-over-devices) and the measured inter-device traffic stays zero.
    rt4 = AmbitRuntime(devices=4, seed=2)
    idx = BitmapIndex(n_users, runtime=rt4)
    populate(idx)
    uniq_s, per_week_s, sh_st = idx.weekly_active_query(week_names, "male")
    assert (uniq_s, per_week_s) == (uniq, per_week), "sharded disagrees"
    led = rt4.store.ledger
    print(f"[sharded x4] measured ledger: {sh_st.ns/1e3:.1f} us "
          f"{sh_st.energy_nj/1e3:.2f} uJ aap={sh_st.aap_count} "
          f"({res_st.ns/sh_st.ns:.1f}x vs 1 device; inter-device "
          f"{led.inter_device_bytes} B measured)")

    # Accelerator-resident path: the SAME app code on the pallas backend.
    # Bitmaps upload once as device arrays; the whole weekly query drains
    # as fused stacked kernel launches and only popcounts read back -
    # bytes_touched counts just those transfers (vs 3 buffers/op for the
    # non-resident engine path above).
    rt_dev = AmbitRuntime(backend="pallas")
    idx = BitmapIndex(n_users, runtime=rt_dev)
    populate(idx)
    uniq_d, per_week_d, dev_st = idx.weekly_active_query(week_names, "male")
    assert (uniq_d, per_week_d) == (uniq, per_week), "device disagrees"
    print(f"[pallas res] traffic ledger: query host_bytes="
          f"{dev_st.bytes_touched} B (uploads once: "
          f"{rt_dev.store.bytes_to_device} B, read-backs: "
          f"{rt_dev.host_reads}, fused launches: "
          f"{rt_dev.planner.kernel_launches})")

    # Observability: the same ledgers as labeled metric series. Bytes
    # are broken down by WHY they crossed the channel (upload vs
    # fault-in vs spill vs read-back) and per-bank busy ns comes from
    # the planner's bank_busy_ns counter - the series the utilization
    # report and trace exporter consume (see README "Observability").
    snap = rt.metrics_snapshot()
    io = {k: int(v) for k, v in snap["counters"].items()
          if k.startswith("store_io_bytes")}
    busy = {k: v for k, v in snap["counters"].items()
            if k.startswith("bank_busy_ns")}
    print("[metrics  ] bytes by cause:")
    for k in sorted(io):
        print(f"             {k} = {io[k]}")
    total_busy = sum(busy.values())
    print(f"[metrics  ] banks={len(busy)} total_busy_ns={total_busy:.0f}"
          + (f" mean_busy_pct="
             f"{100.0 * total_busy / (len(busy) * res_st.ns):.1f}"
             if busy and res_st.ns else ""))

    # Analytic model (what this example used to print) for comparison.
    n_ops = 2 * weeks - 1
    rows = n_users // 65536
    analytic_ns = n_ops * max(1, rows // 8) * 4 * 49.0
    cpu_ns = baseline_cpu_ns(n_users, n_ops)
    print(f"analytic: Ambit {analytic_ns/1e3:.1f} us (vs measured resident "
          f"{res_st.ns/1e3:.1f} us) | CPU {cpu_ns/1e3:.1f} us -> "
          f"{cpu_ns/res_st.ns:.1f}x measured "
          f"(paper reports ~6x end-to-end)")


if __name__ == "__main__":
    main()
