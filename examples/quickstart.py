"""Quickstart: the Ambit bulk bitwise execution engine in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (BitVector, BulkBitwiseEngine, Expr, compile_expr,
                        maj)


def main():
    rng = np.random.default_rng(0)
    n = 100_000

    # 1) BitVectors + the engine (jnp backend = portable reference)
    a = BitVector.from_bits(rng.integers(0, 2, n).astype(bool))
    b = BitVector.from_bits(rng.integers(0, 2, n).astype(bool))
    c = BitVector.from_bits(rng.integers(0, 2, n).astype(bool))
    eng = BulkBitwiseEngine("jnp")
    result = eng.eval((Expr.var("a") & Expr.var("b")) | ~Expr.var("c"),
                      {"a": a, "b": b, "c": c})
    print(f"(a&b)|~c popcount: {int(eng.popcount(result))} / {n}")

    # 2) The same op on the bit-accurate DRAM device model, with the
    #    paper's timing/energy ledger (Section 7 units)
    sim = BulkBitwiseEngine("ambit_sim")
    small = {k: BitVector.from_bits(rng.integers(0, 2, 2048).astype(bool))
             for k in "abc"}
    out = sim.eval(maj(Expr.var("a"), Expr.var("b"), Expr.var("c")), small)
    st = sim.last_stats
    print(f"MAJ on DRAM model: {st.aap_count} AAPs, {st.ns:.0f} ns, "
          f"{st.energy_nj:.1f} nJ")

    # 3) Compile a bitwise expression to an AAP command program (Fig. 20)
    x, y = Expr.var("x"), Expr.var("y")
    comp = compile_expr(~(x & y), {"x": 0, "y": 1}, dst_row=2)
    print(f"nand program ({comp.n_aap} AAPs, {comp.stats.ns:.0f} ns):")
    for m in comp.program:
        print(f"   {m!r}")

    # 4) Pallas kernel backend (TPU target; interpret mode on CPU)
    pall = BulkBitwiseEngine("pallas")
    r2 = pall.xor(a, b)
    ref = eng.xor(a, b)
    assert np.array_equal(np.asarray(r2.bits()), np.asarray(ref.bits()))
    print("pallas backend == jnp backend: OK")


if __name__ == "__main__":
    main()
