"""Batched serving: prefill + decode with KV caches and slot-based
continuous batching on a reduced model.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch gemma3-1b]
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_seq=128, batch_slots=4,
                      temperature=0.8)

    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(2, 9))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={r.prompt.tolist()} -> {r.out}")

    # Observability: the engine's MetricsRegistry counts the serving
    # loop's work - prefill batches, decode iterations actually executed
    # (the termination-contract number), tokens sampled, and completions
    # broken down by why each request finished.
    snap = eng.metrics.snapshot()
    print("metrics:")
    for k, v in sorted(snap["counters"].items()):
        print(f"  {k} = {int(v)}")


if __name__ == "__main__":
    main()
