"""Binarized compute (paper Section 8.4.5): XNOR-popcount matmul as a
drop-in BitLinear layer, with straight-through-estimator training on a
toy classification task - the paper's ML application of bulk bitwise ops.

Run:  PYTHONPATH=src python examples/binary_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitvector import pack_bits
from repro.kernels import ops


def bitlinear_forward(x, w):
    """Binarize x,w to +-1 with mean-abs scales; packed XNOR-popcount."""
    xs = jnp.abs(x).mean(-1, keepdims=True)
    ws = jnp.abs(w).mean(-1, keepdims=True)
    d = x.shape[-1]
    xp = pack_bits((x > 0).astype(jnp.uint32))[:, :(d + 31) // 32]
    wp = pack_bits((w > 0).astype(jnp.uint32))[:, :(d + 31) // 32]
    return ops.binary_matmul(xp, wp, d) * xs * ws.T


def ste_forward(x, w):
    """Differentiable surrogate: sign() with straight-through gradients."""
    xs = jnp.abs(x).mean(-1, keepdims=True)
    ws = jnp.abs(w).mean(-1, keepdims=True)
    bx = x + jax.lax.stop_gradient(jnp.sign(x) - x)
    bw = w + jax.lax.stop_gradient(jnp.sign(w) - w)
    return (bx @ bw.T) * xs * ws.T


def main():
    rng = np.random.default_rng(0)
    d, classes, n = 256, 8, 2048
    # sign-pattern prototypes: representable exactly by binary weights
    protos = rng.choice([-1.0, 1.0], size=(classes, d))
    y = rng.integers(0, classes, n)
    x = (protos[y] + rng.normal(size=(n, d)) * 2.0).astype(np.float32)

    w = jnp.asarray(rng.normal(size=(classes, d)) * 0.1, jnp.float32)

    def loss_fn(w, xb, yb):
        logits = ste_forward(xb, w)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(yb)),
                                                    yb])

    grad = jax.jit(jax.grad(loss_fn))
    for step in range(150):
        idx = rng.integers(0, n, 256)
        w = w - 0.5 * grad(w, jnp.asarray(x[idx]), jnp.asarray(y[idx]))

    # inference with the REAL packed XNOR-popcount kernel
    logits = bitlinear_forward(jnp.asarray(x), w)
    acc = float((np.asarray(logits).argmax(-1) == y).mean())
    print(f"BitLinear accuracy with packed XNOR-popcount inference: "
          f"{acc:.3f} (chance {1/classes:.3f})")
    assert acc > 0.5


if __name__ == "__main__":
    main()
