"""Summarise a Chrome/Perfetto trace JSON written by the obs layer.

Reads the deterministic trace-event file that ``write_chrome_trace``
emits (``benchmarks/run.py --trace``, ``serve_closed_loop.py --trace``)
and prints a utilization report reconstructed *from the file alone* -
no live Tracer/registry needed, so this works on CI artifacts:

  * epoch timeline: span count, wall ns (epochs tile the drain timeline,
    so wall = sum of epoch ``dur_ns``), queries per epoch and packing
    efficiency (``--max-batch``);
  * per-bank busy: busy ns / busy%% per ``deviceN/bankM`` track from the
    ``bank``-category spans;
  * channel vs compute overlap from the ``channel``-category spans;
  * refresh stall: stolen ns per track from the ``refresh``-category
    spans (the planner's per-bank ``refresh_stall`` ticks and the
    scheduler's ``drain(refresh=True)`` epoch stalls);
  * query-optimizer activity from the ``opt``-category instants on the
    ``scheduler/optimizer`` track: rewrite spans per ticket (which
    ``__cse`` scratch vars each rewritten query now references),
    materializations (shared-subtree ops x consumer count) and
    result-cache hits;
  * event counts per category.

``--json`` emits the same summary as a machine-readable dict (sorted
keys), for diffing across runs.

Usage:  python tools/trace_report.py TRACE.json [--max-batch N] [--json]
"""
import argparse
import json
import sys
from collections import defaultdict


def load_trace(path):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise SystemExit(f"{path}: not a trace-event file "
                         "(missing traceEvents list)")
    return events


def summarise(events, max_batch=None):
    # Reconstruct thread (track) names from the metadata events.
    tnames = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            tnames[(e["pid"], e["tid"])] = e["args"]["name"]

    cats = defaultdict(int)
    epoch_spans = []
    channel_ns = 0.0
    bank_busy = defaultdict(float)
    refresh_stall = defaultdict(float)
    opt = {"rewrites": 0, "materializations": 0, "cache_hits": 0,
           "shared_ops": 0, "consumer_refs": 0, "rewritten_tickets": []}
    for e in events:
        ph = e.get("ph")
        if ph == "M":
            continue
        cats[e.get("cat", "?")] += 1
        if e.get("cat") == "opt" and ph == "i":
            args = e.get("args", {})
            name = e.get("name", "")
            if name.startswith("rewrite#"):
                opt["rewrites"] += 1
                opt["rewritten_tickets"].append(
                    (args.get("ticket"), args.get("cse_vars", [])))
            elif name.startswith("materialize#"):
                opt["materializations"] += 1
                opt["shared_ops"] += args.get("ops", 0)
                opt["consumer_refs"] += args.get("consumers", 0)
            elif name.startswith("cache_hit#"):
                opt["cache_hits"] += 1
        if ph != "X":
            continue
        args = e.get("args", {})
        dur = args.get("dur_ns", e.get("dur", 0.0) * 1000.0)
        cat = e.get("cat")
        if cat == "epoch":
            epoch_spans.append((args.get("ns", e.get("ts", 0.0) * 1000.0),
                                dur, len(args.get("tickets", []))))
        elif cat == "channel":
            channel_ns += dur
        elif cat == "bank":
            bank_busy[tnames.get((e["pid"], e["tid"]),
                                 f"pid{e['pid']}/tid{e['tid']}")] += dur
        elif cat == "refresh":
            refresh_stall[tnames.get((e["pid"], e["tid"]),
                                     f"pid{e['pid']}/tid{e['tid']}")] += dur

    out = {"event_counts": dict(sorted(cats.items()))}
    if opt["rewrites"] or opt["materializations"] or opt["cache_hits"]:
        out["optimizer"] = {
            "rewrites": opt["rewrites"],
            "materializations": opt["materializations"],
            "shared_subtree_ops": opt["shared_ops"],
            "consumer_refs": opt["consumer_refs"],
            "cache_hits": opt["cache_hits"],
            "rewritten_tickets": [
                {"ticket": t, "cse_vars": v}
                for t, v in sorted(opt["rewritten_tickets"],
                                   key=lambda x: (x[0] is None, x[0]))],
        }
    if refresh_stall:
        out["refresh"] = {
            "total_stolen_ns": sum(refresh_stall.values()),
            "tracks": {name: ns
                       for name, ns in sorted(refresh_stall.items())}}
    if epoch_spans:
        wall = sum(d for _, d, _ in epoch_spans)
        n_q = sum(q for _, _, q in epoch_spans)
        out["epochs"] = {
            "count": len(epoch_spans),
            "queries": n_q,
            "wall_ns": wall,
            "queries_per_epoch": n_q / len(epoch_spans),
        }
        if max_batch:
            out["epochs"]["packing_efficiency_pct"] = (
                100.0 * n_q / (len(epoch_spans) * max_batch))
        if channel_ns:
            comp = wall - channel_ns
            out["epochs"]["channel_ns"] = channel_ns
            out["epochs"]["channel_share_pct"] = (
                100.0 * channel_ns / wall if wall else 0.0)
            out["epochs"]["compute_ns"] = comp
        if bank_busy:
            out["banks"] = {
                name: {"busy_ns": ns,
                       "busy_pct": 100.0 * ns / wall if wall else 0.0}
                for name, ns in sorted(bank_busy.items())}
    elif bank_busy:
        out["banks"] = {name: {"busy_ns": ns}
                        for name, ns in sorted(bank_busy.items())}
    return out


def render(summary):
    lines = []
    ep = summary.get("epochs")
    if ep:
        lines.append("== epochs ==")
        row = (f"epochs={ep['count']} queries={ep['queries']} "
               f"wall_ns={ep['wall_ns']:.1f} "
               f"queries_per_epoch={ep['queries_per_epoch']:.2f}")
        if "packing_efficiency_pct" in ep:
            row += f" packing_efficiency={ep['packing_efficiency_pct']:.1f}%"
        lines.append(row)
        if "channel_ns" in ep:
            lines.append(f"channel_ns={ep['channel_ns']:.1f} "
                         f"compute_ns={ep['compute_ns']:.1f} "
                         f"channel_share={ep['channel_share_pct']:.1f}%")
    banks = summary.get("banks")
    if banks:
        lines.append("== per-bank busy ==")
        for name, row in banks.items():
            s = f"{name} busy_ns={row['busy_ns']:.1f}"
            if "busy_pct" in row:
                s += f" busy={row['busy_pct']:.1f}%"
            lines.append(s)
    opt = summary.get("optimizer")
    if opt:
        lines.append("== optimizer ==")
        lines.append(
            f"rewrites={opt['rewrites']} "
            f"materializations={opt['materializations']} "
            f"shared_subtree_ops={opt['shared_subtree_ops']} "
            f"consumer_refs={opt['consumer_refs']} "
            f"cache_hits={opt['cache_hits']}")
        for row in opt["rewritten_tickets"]:
            refs = " ".join(f"__cse{g}" for g in row["cse_vars"])
            lines.append(f"ticket#{row['ticket']} -> {refs or '(folded)'}")
    refresh = summary.get("refresh")
    if refresh:
        lines.append("== refresh ==")
        lines.append(
            f"total_stolen_ns={refresh['total_stolen_ns']:.1f}")
        for name, ns in refresh["tracks"].items():
            lines.append(f"{name} stolen_ns={ns:.1f}")
    lines.append("== events ==")
    lines.append(" ".join(f"{c}={n}"
                          for c, n in summary["event_counts"].items()))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON file")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="frontend max_batch, for packing efficiency")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)
    summary = summarise(load_trace(args.trace), max_batch=args.max_batch)
    if args.json:
        json.dump(summary, sys.stdout, sort_keys=True, indent=1)
        sys.stdout.write("\n")
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
