"""Dry-run profiler: lower a cell, break down traffic/flops by op line."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax
sys.path.insert(0, "src")
from repro.launch.dryrun import build_cell, sharding_rules_for, mesh_shape_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.hloparse import (HloModule, _DEF_RE, _CALLS_RE,
                                   _all_shapes_bytes, _shape_nbytes,
                                   _OPERAND_RE)
from repro.models.sharding_ctx import axis_rules
from repro.configs import SHAPES

def profile(arch, shape_name, top=14, save=None):
    mesh = make_production_mesh()
    fn, shapes, shards = build_cell(arch, shape_name, mesh)
    ms = mesh_shape_dict(mesh)
    rules = sharding_rules_for(shape_name, SHAPES[shape_name].global_batch, ms)
    with mesh, axis_rules(rules, ms):
        compiled = jax.jit(fn, in_shardings=shards).lower(*shapes).compile()
    txt = compiled.as_text()
    if save:
        open(save, "w").write(txt)
    m = HloModule(txt)
    fused = set()
    for lines in m.comps.values():
        for ln in lines:
            for mm in _CALLS_RE.finditer(ln):
                fused.add(mm.group(1))
    items = []
    for cname, lines in m.comps.items():
        if cname in fused: continue
        factor = m.mult[cname]
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm: continue
            rhs = dm.group(2)
            opk = m._op_kind(rhs)
            callee = None
            if opk == "fusion":
                cm = _CALLS_RE.search(rhs)
                callee = cm.group(1) if cm else None
                if callee is None or not m._is_anchor_fusion(callee): continue
            elif opk not in m._ANCHOR_OPS and not any(
                    opk.startswith(c) for c in ("all-", "reduce-sc", "collective")):
                continue
            b = _all_shapes_bytes(rhs.split("(", 1)[0])
            if opk in ("dynamic-slice", "gather"):
                items.append((2*b*factor, factor, ln)); continue
            seen = {}
            if "(" in rhs:
                args = rhs.split("(", 1)[1].split(")", 1)[0]
                for i, op in enumerate(_OPERAND_RE.findall(args)):
                    dt, dims = m.shapes.get(op, ("", []))
                    ob = _shape_nbytes(dt, dims)
                    if callee and ob > 0:
                        ob = m._sliced_read_bytes(callee, i, ob)
                    seen[op] = min(seen.get(op, 1e30), ob)
            items.append(((b + sum(seen.values())) * factor, factor, ln))
    items.sort(key=lambda t: -t[0])
    print(f"== {arch} {shape_name}: flops/dev={m.dot_flops():.3e} "
          f"traffic/dev={m.traffic_bytes():.3e} coll/dev={m.collective_bytes()[0]:.3e}")
    print("   mem term", m.traffic_bytes()/819e9, "s; compute",
          m.dot_flops()/197e12, "s; coll", m.collective_bytes()[0]/50e9, "s")
    for v, f, ln in items[:top]:
        meta = ln.split(", metadata")
        op_name = ""
        if len(meta) > 1 and "op_name=" in meta[1]:
            op_name = meta[1].split('op_name="')[1].split('"')[0][-60:]
        print(f"  {v:9.3e} x{f:4d}  {meta[0][:110]}")
        if op_name: print(f"             ^ {op_name}")

def profile_coll(arch, shape_name, top=12):
    mesh = make_production_mesh()
    fn, shapes, shards = build_cell(arch, shape_name, mesh)
    ms = mesh_shape_dict(mesh)
    rules = sharding_rules_for(shape_name, SHAPES[shape_name].global_batch, ms)
    with mesh, axis_rules(rules, ms):
        compiled = jax.jit(fn, in_shardings=shards).lower(*shapes).compile()
    m = HloModule(compiled.as_text())
    items = []
    for cname, lines in m.comps.items():
        f = m.mult[cname]
        for ln in lines:
            if "-start" in ln: continue
            dm = _DEF_RE.match(ln)
            if not dm: continue
            rhs = dm.group(2)
            opk = m._op_kind(rhs)
            if not any(opk.startswith(c) for c in ("all-", "reduce-scatter", "collective-permute")): continue
            b = _all_shapes_bytes(rhs.split("(", 1)[0])
            items.append((b*f, f, ln))
    items.sort(key=lambda t: -t[0])
    tot = sum(t[0] for t in items)
    print(f"== {arch} {shape_name} collective bytes/dev ~= {tot:.3e}")
    for v, f, ln in items[:top]:
        meta = ln.split(", metadata")
        op_name = meta[1].split('op_name="')[1].split('"')[0][-70:] if len(meta)>1 and 'op_name="' in meta[1] else ""
        print(f"  {v:9.3e} x{f:4d}  {meta[0][:100]}")
        if op_name: print(f"             ^ {op_name}")

if __name__ == "__main__":
    if sys.argv[1] == "coll":
        profile_coll(sys.argv[2], sys.argv[3])
    else:
        profile(sys.argv[1], sys.argv[2], save=(sys.argv[3] if len(sys.argv) > 3 else None))
